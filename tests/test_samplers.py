"""Sampler behaviour: convergence, determinism, relational inference."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core.search_space import intersection_search_space


def sphere(trial):
    return sum(trial.suggest_float(f"x{i}", -3, 3) ** 2 for i in range(3))


def rosenbrock(trial):
    x = trial.suggest_float("x", -2, 2)
    y = trial.suggest_float("y", -2, 2)
    return (1 - x) ** 2 + 100 * (y - x * x) ** 2


class TestTPE:
    def test_beats_random_on_sphere(self):
        def best_after(sampler, n=60):
            s = hpo.create_study(sampler=sampler)
            s.optimize(sphere, n_trials=n)
            return s.best_value

        tpe = np.median([best_after(hpo.TPESampler(seed=i)) for i in range(5)])
        rnd = np.median([best_after(hpo.RandomSampler(seed=i)) for i in range(5)])
        assert tpe < rnd

    def test_seed_determinism(self):
        def run(seed):
            s = hpo.create_study(sampler=hpo.TPESampler(seed=seed))
            s.optimize(sphere, n_trials=25)
            return [t.values[0] for t in s.trials]

        assert run(7) == run(7)
        assert run(7) != run(8)

    def test_categorical_and_conditional_space(self):
        s = hpo.create_study(sampler=hpo.TPESampler(seed=0, n_startup_trials=5))

        def obj(trial):
            kind = trial.suggest_categorical("kind", ["a", "b"])
            if kind == "a":
                return trial.suggest_float("xa", 0, 1)
            return trial.suggest_float("xb", 5, 6)

        s.optimize(obj, n_trials=40)
        # TPE should learn branch 'a' is better
        kinds = [t.params["kind"] for t in s.trials[-10:]]
        assert kinds.count("a") >= 6
        assert s.best_value < 0.6

    def test_log_domain(self):
        s = hpo.create_study(sampler=hpo.TPESampler(seed=3, n_startup_trials=5))
        s.optimize(lambda t: abs(np.log10(t.suggest_float("lr", 1e-6, 1.0, log=True)) + 3), n_trials=50)
        assert s.best_value < 1.0  # found lr near 1e-3 within an order


class TestCMAES:
    def test_converges_on_rosenbrock(self):
        s = hpo.create_study(
            sampler=hpo.CmaEsSampler(warmup_trials=10, seed=0)
        )
        s.optimize(rosenbrock, n_trials=150)
        assert s.best_value < 1.0

    def test_mixture_tpe_cmaes(self):
        # the paper's §5.1 configuration
        s = hpo.create_study(sampler=hpo.make_sampler("tpe+cmaes", seed=0))
        s.optimize(rosenbrock, n_trials=120)
        assert s.best_value < 2.0

    def test_falls_back_to_independent_for_conditionals(self):
        s = hpo.create_study(sampler=hpo.CmaEsSampler(warmup_trials=5, seed=0))

        def obj(trial):
            x = trial.suggest_float("x", -1, 1)
            y = trial.suggest_float("y", -1, 1)
            if trial.number % 2:  # "z" not in every trial -> outside CMA space
                z = trial.suggest_float("z", -1, 1)
                return x * x + y * y + z * z
            return x * x + y * y

        s.optimize(obj, n_trials=40)
        assert len(s.trials) == 40


class TestGP:
    def test_gp_improves_on_random(self):
        s = hpo.create_study(sampler=hpo.GPSampler(seed=0, n_startup_trials=8))
        s.optimize(sphere, n_trials=40)
        r = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        r.optimize(sphere, n_trials=40)
        assert s.best_value < r.best_value * 1.5  # GP at least competitive


class TestGrid:
    def test_grid_covers_all_cells(self):
        grid = {"a": [1, 2, 3], "b": [10.0, 20.0]}
        s = hpo.create_study(sampler=hpo.GridSampler(grid, seed=0))

        def obj(trial):
            a = trial.suggest_int("a", 1, 3)
            b = trial.suggest_float("b", 10.0, 20.0)
            return a * b

        s.optimize(obj, n_trials=6)
        seen = {(t.params["a"], t.params["b"]) for t in s.trials}
        assert len(seen) == 6


class TestSearchSpaceInference:
    def test_intersection_space(self):
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))

        def obj(trial):
            x = trial.suggest_float("x", 0, 1)
            if trial.number % 2 == 0:
                trial.suggest_float("sometimes", 0, 1)
            return x

        s.optimize(obj, n_trials=6)
        space = intersection_search_space(s.get_trials(deepcopy=False))
        assert set(space) == {"x"}  # only the always-present param survives

    def test_enqueue_and_fixed_trial(self):
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        s.enqueue_trial({"x": 0.123})
        s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=1)
        assert abs(s.trials[0].values[0] - 0.123) < 1e-12

        ft = hpo.FixedTrial({"x": 0.5})
        assert abs(ft.suggest_float("x", 0, 1) - 0.5) < 1e-12
        with pytest.raises(ValueError):
            ft.suggest_float("missing", 0, 1)


class TestJitScoringRetraces:
    def test_trace_count_bounded_by_pow2_buckets(self):
        """The device scorer pads Parzen component arrays to power-of-two
        buckets, so XLA retraces O(log n_observations) times, not once per
        ask."""
        pytest.importorskip("jax")
        import repro.core.samplers.tpe as tpe_mod
        from repro.kernels import ops as kops

        tpe_mod._jax_score = None  # fresh jit cache for a clean count
        kops.reset_traces("tpe.score")
        sampler = hpo.TPESampler(seed=3, n_startup_trials=5, engine="jax")
        study = hpo.create_study(sampler=sampler)
        n_asks = 40

        def objective(trial):
            return trial.suggest_float("x", -3, 3) ** 2

        study.optimize(objective, n_trials=n_asks)
        # observation counts sweep 5..39 -> component sizes cross at most a
        # few power-of-two boundaries per estimator side
        traces = kops.trace_count("tpe.score")
        assert 0 < traces <= 8, traces
        assert traces < n_asks - sampler._n_startup
