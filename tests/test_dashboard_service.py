"""HTTP analytics service tests: revision-gated delta endpoint (idle study =
zero storage refetches, pinned via telemetry counters), fANOVA vs Spearman
ranking agreement, scoped-token auth, and the Prometheus exposition."""

import json
import urllib.error
import urllib.request

import pytest

import repro.core as hpo
from repro.core import telemetry
from repro.serve.dashboard_service import DashboardService


@pytest.fixture
def metrics():
    telemetry.reset()
    telemetry.enable()
    yield telemetry
    telemetry.disable()
    telemetry.reset()


def _get(svc, path, token=None, raw=False):
    req = urllib.request.Request(svc.url + path)
    if token:
        req.add_header("Authorization", f"Bearer {token}")
    body = urllib.request.urlopen(req).read()
    return body if raw else json.loads(body)


def _status(svc, path, token=None):
    try:
        req = urllib.request.Request(svc.url + path)
        if token:
            req.add_header("Authorization", f"Bearer {token}")
        return urllib.request.urlopen(req).status
    except urllib.error.HTTPError as e:
        return e.code


def _seed_study(storage, name="svc", n=20, seed=0):
    s = hpo.create_study(
        study_name=name, storage=storage, sampler=hpo.RandomSampler(seed=seed)
    )
    s.optimize(
        lambda t: t.suggest_float("x", -2, 2) ** 2 + 0.05 * t.suggest_float("y", 0, 1),
        n_trials=n,
    )
    return s


class TestDeltaEndpoint:
    def test_idle_poll_zero_storage_refetch(self, metrics):
        """The acceptance pin: an unchanged study answers the delta poll with
        one revision RPC and ZERO trial-data refetches — every refresh
        counter (columnar stores + cached proxy) stays frozen."""
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            _seed_study(hpo.RemoteStorage(server.url), n=15)
            svc = DashboardService(f"remote://{server.url.split('//')[1]}").start()
            try:
                d = _get(svc, "/api/study/svc/delta?since_rev=-1&since_num=-1")
                assert not d["idle"] and len(d["rows"]) == 15

                before = telemetry.snapshot()["counters"]
                for _ in range(5):
                    d2 = _get(
                        svc,
                        f"/api/study/svc/delta?since_rev={d['rev']}&since_num={d['last_number']}",
                    )
                    assert d2 == {"rev": d["rev"], "idle": True}
                after = telemetry.snapshot()["counters"]

                assert after.get("dashboard.delta.idle", 0) == before.get("dashboard.delta.idle", 0) + 5
                for key in after:
                    if ".refresh." in key:  # records.* and cached.* fetch paths
                        assert after[key] == before.get(key, 0), key
            finally:
                svc.stop()

    def test_active_poll_ships_only_new_rows(self, metrics):
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            url = f"remote://{server.url.split('//')[1]}"
            s = _seed_study(hpo.RemoteStorage(server.url), n=10)
            svc = DashboardService(url).start()
            try:
                d = _get(svc, "/api/study/svc/delta?since_rev=-1&since_num=-1")
                assert [r["number"] for r in d["rows"]] == list(range(10))
                s.optimize(lambda t: t.suggest_float("x", -2, 2) ** 2
                           + 0.05 * t.suggest_float("y", 0, 1), n_trials=4)
                d2 = _get(
                    svc,
                    f"/api/study/svc/delta?since_rev={d['rev']}&since_num={d['last_number']}",
                )
                assert not d2["idle"]
                assert [r["number"] for r in d2["rows"]] == [10, 11, 12, 13]
                assert d2["rev"] != d["rev"]
            finally:
                svc.stop()


class TestViewsAndPages:
    def test_views_and_pages_render(self, metrics):
        storage = hpo.InMemoryStorage()
        _seed_study(storage, n=20)
        svc = DashboardService(storage).start()
        try:
            v = _get(svc, "/api/study/svc/views")
            assert v["n_finished"] == 20
            assert len(v["history"]) == 1 and len(v["history"][0]["best"]) == 20
            assert v["contour"] is not None and v["contour"]["x_param"] in ("x", "y")
            assert {s["param"] for s in v["slices"]} == {"x", "y"}
            page = _get(svc, "/study/svc", raw=True).decode()
            assert 'data-study="svc"' in page and "optimization history" in page
            index = _get(svc, "/", raw=True).decode()
            assert "/study/svc" in index
            cluster = _get(svc, "/cluster", raw=True).decode()
            assert "shards" in cluster
            assert _status(svc, "/nope") == 404
        finally:
            svc.stop()

    def test_prometheus_exposition(self, metrics):
        storage = hpo.InMemoryStorage()
        _seed_study(storage, n=5)
        svc = DashboardService(storage).start()
        try:
            _get(svc, "/api/study/svc/delta?since_rev=-1&since_num=-1")
            text = _get(svc, "/metrics", raw=True).decode()
            assert "# TYPE repro_dashboard_http_requests_total counter" in text
            assert "repro_dashboard_delta_active_total 1" in text
            for line in text.strip().splitlines():
                assert line.startswith("#") or " " in line
        finally:
            svc.stop()


class TestAuth:
    def _svc(self, tokens):
        storage = hpo.InMemoryStorage()
        _seed_study(storage, name="mine", n=5)
        _seed_study(storage, name="other", n=5, seed=1)
        return DashboardService(storage, tokens=tokens).start()

    def test_open_when_no_tokens(self, metrics):
        svc = self._svc(None)
        try:
            assert _status(svc, "/") == 200
            assert _status(svc, "/metrics") == 200
        finally:
            svc.stop()

    def test_missing_or_bad_token_401(self, metrics):
        svc = self._svc(["sekrit"])
        try:
            assert _status(svc, "/") == 401
            assert _status(svc, "/api/study/mine/views") == 401
            assert _status(svc, "/", token="wrong") == 401
            assert _status(svc, "/", token="sekrit") == 200
            # query-string token also accepted (browser links)
            assert _status(svc, "/?token=sekrit") == 200
        finally:
            svc.stop()

    def test_readonly_token_accepted_everywhere(self, metrics):
        # all service endpoints are reads, so a readonly storage token grants
        # the same access as a full one
        svc = self._svc([{"token": "ro", "readonly": True}])
        try:
            for path in ("/", "/metrics", "/cluster", "/api/studies",
                         "/api/study/mine/views", "/api/cluster/metrics"):
                assert _status(svc, path, token="ro") == 200, path
        finally:
            svc.stop()

    def test_study_scoped_token_confined(self, metrics):
        svc = self._svc([{"token": "st", "studies": ["mine"]}])
        try:
            assert _status(svc, "/api/study/mine/views", token="st") == 200
            assert _status(svc, "/study/mine", token="st") == 200
            assert _status(svc, "/api/study/other/views", token="st") == 403
            # global endpoints denied for study-scoped tokens
            for path in ("/", "/metrics", "/cluster", "/api/studies",
                         "/api/cluster/metrics"):
                assert _status(svc, path, token="st") == 403, path
        finally:
            svc.stop()


class TestImportanceRankingAgreement:
    def test_fanova_agrees_with_spearman_on_monotone_study(self, metrics):
        """Acceptance pin: on a synthetic study where the objective is
        monotone in x and nearly flat in y, fANOVA and Spearman must agree
        that x dominates."""
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=7))
        s.optimize(
            lambda t: 3.0 * t.suggest_float("x", 0, 1)
            + 0.01 * t.suggest_float("y", 0, 1),
            n_trials=60,
        )
        fan = hpo.fanova_importances(s)
        spear = hpo.spearman_importances(s)
        assert max(fan, key=fan.get) == max(spear, key=spear.get) == "x"
        assert fan["x"] > 0.8 and spear["x"] > 0.8
        assert sum(fan.values()) == pytest.approx(1.0)
        # ranking order identical, not just the top-1
        assert sorted(fan, key=fan.get) == sorted(spear, key=spear.get)

    def test_fanova_fallback_small_study(self, metrics):
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=3))
        s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=4)
        # below the tree-fit floor: falls back to Spearman exactly
        assert hpo.fanova_importances(s) == hpo.spearman_importances(s)
