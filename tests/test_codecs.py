"""Vectorized model-space codec round-trips (`to_internal`/`from_internal`)
on every distribution kind, plus bounds and uniform-sampling invariants.

These are seeded randomized property tests that always run; a hypothesis
variant lives in ``test_codecs_hypothesis.py`` (skipped when hypothesis is
absent)."""

import math

import numpy as np
import pytest

from repro.core.distributions import (
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
    round_to_step,
)

RNG = np.random.RandomState(20260726)

FLOAT_DISTS = [
    FloatDistribution(-5.0, 5.0),
    FloatDistribution(0.0, 1.0, step=0.25),
    FloatDistribution(1e-6, 1.0, log=True),
    FloatDistribution(2.5, 2.5),
    FloatDistribution(-1e6, 1e6),
]
INT_DISTS = [
    IntDistribution(1, 100),
    IntDistribution(-50, 50, step=5),
    IntDistribution(1, 1024, log=True),
    IntDistribution(7, 7),
]
CAT_DISTS = [
    CategoricalDistribution(["a", "b", "c"]),
    CategoricalDistribution([None, True, 0, 1.5, "x"]),
    CategoricalDistribution([1, True]),  # int/bool must not conflate
]


def _domain_samples(dist, n=200):
    if isinstance(dist, FloatDistribution):
        if dist.step is not None:
            k = int(np.floor((dist.high - dist.low) / dist.step + 1e-12)) + 1
            return dist.low + RNG.randint(k, size=n) * dist.step
        if dist.log:
            return np.exp(RNG.uniform(np.log(dist.low), np.log(dist.high), size=n))
        return RNG.uniform(dist.low, dist.high, size=n)
    if isinstance(dist, IntDistribution):
        k = (dist.high - dist.low) // dist.step + 1
        return dist.low + RNG.randint(k, size=n) * dist.step
    return [dist.choices[i] for i in RNG.randint(len(dist.choices), size=n)]


@pytest.mark.parametrize("dist", FLOAT_DISTS + INT_DISTS)
def test_numeric_roundtrip_is_identity_on_domain(dist):
    xs = _domain_samples(dist)
    back = dist.from_internal(dist.to_internal(xs))
    assert np.allclose(back, np.asarray(xs, dtype=float), rtol=1e-12, atol=1e-9)
    # external conversion lands exactly on domain values
    for b in back:
        ext = dist.to_external_repr(float(b))
        assert dist._contains(dist.to_internal_repr(ext))


@pytest.mark.parametrize("dist", CAT_DISTS)
def test_categorical_roundtrip(dist):
    xs = _domain_samples(dist)
    internal = dist.to_internal(xs)
    back = [dist.to_external_repr(v) for v in dist.from_internal(internal)]
    for orig, b in zip(xs, back):
        assert type(orig) is type(b) and orig == b


@pytest.mark.parametrize("dist", FLOAT_DISTS + INT_DISTS + CAT_DISTS)
def test_vectorized_matches_scalar_codec(dist):
    """to_internal must agree with the scalar storage repr composed with the
    model transform (log for log domains)."""
    xs = _domain_samples(dist, n=50)
    vec = dist.to_internal(xs)
    for x, v in zip(xs, vec):
        scalar = dist.to_internal_repr(x)
        if getattr(dist, "log", False):
            scalar = math.log(max(scalar, 1e-12))
        assert v == scalar


@pytest.mark.parametrize("dist", FLOAT_DISTS + INT_DISTS + CAT_DISTS)
def test_from_internal_maps_arbitrary_reals_into_domain(dist):
    lo, hi = dist.internal_bounds(expand_int=True)
    zs = RNG.uniform(lo - 1.0, hi + 1.0, size=200)
    back = dist.from_internal(zs)
    for b in back:
        assert dist._contains(dist.to_internal_repr(dist.to_external_repr(float(b))))


@pytest.mark.parametrize("dist", FLOAT_DISTS + INT_DISTS)
def test_internal_bounds_contain_observations(dist):
    xs = _domain_samples(dist)
    internal = dist.to_internal(xs)
    lo, hi = dist.internal_bounds(expand_int=True)
    assert np.all(internal >= lo - 1e-9) and np.all(internal <= hi + 1e-9)
    lo2, hi2 = dist.internal_bounds()
    assert lo2 <= hi2


@pytest.mark.parametrize("dist", FLOAT_DISTS + INT_DISTS + CAT_DISTS)
def test_sample_uniform_within_domain(dist):
    rng = np.random.RandomState(1)
    vals = dist.sample_uniform(rng, 300)
    assert len(vals) == 300
    for v in vals:
        assert dist._contains(float(v))
        ext = dist.to_external_repr(float(v))
        assert dist._contains(dist.to_internal_repr(ext))


def test_sample_uniform_stream_matches_scalar_draws():
    """size=1 draws consume the RNG exactly like the historical scalar path,
    so seeded studies reproduce across the refactor."""
    for dist in FLOAT_DISTS + INT_DISTS + CAT_DISTS:
        r1, r2 = np.random.RandomState(5), np.random.RandomState(5)
        a = [float(dist.sample_uniform(r1, 1)[0]) for _ in range(20)]
        b = list(map(float, dist.sample_uniform(r2, 20)))
        assert a == b


def test_internal_to_unit_roundtrip():
    for dist in FLOAT_DISTS + INT_DISTS:
        if dist.single():
            continue
        xs = _domain_samples(dist, n=100)
        u = dist.internal_to_unit(dist.to_internal(xs))
        assert np.all(u >= -1e-12) and np.all(u <= 1 + 1e-12)


def test_round_to_step_array_matches_scalar():
    xs = RNG.uniform(-10, 10, 100)
    arr = round_to_step(xs, -10.0, 10.0, 0.3)
    for x, a in zip(xs, arr):
        assert a == round_to_step(float(x), -10.0, 10.0, 0.3)
