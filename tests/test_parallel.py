"""Multi-device tests (subprocess with XLA_FLAGS device-count override):
pjit train step on a host mesh, pipeline parallelism, gradient compression,
trial-slice scheduling."""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(script: str, n_devices: int = 8, timeout: int = 420) -> str:
    full = (
        f"import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        f"import sys\nsys.path.insert(0, {SRC!r})\n" + script
    )
    out = subprocess.run(
        [sys.executable, "-c", full], capture_output=True, text=True, timeout=timeout
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_runs_on_host_mesh():
    out = run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import build_step
from repro.models import init_model_params
from repro.models.sharding import TRAIN_RULES, tree_shardings
from repro.models import abstract_params, params_logical
from repro.train import SyntheticLM
from repro.train.train_loop import make_optimizer_for, TrainConfig

cfg = configs.get_smoke_config("tinyllama-1.1b")
mesh = make_host_mesh((2, 4), ("data", "model"))
cell = build_step(cfg, "train_4k", mesh)  # shape only defines kind; args rebuilt below
# real (small) inputs with the cell's shardings
params = init_model_params(cfg, jax.random.PRNGKey(0))
opt = make_optimizer_for(cfg, TrainConfig())
opt_state = opt.init(params)
data = SyntheticLM(cfg, batch=8, seq=32, seed=0)
batch = data.next_batch()
with mesh:
    jitted = jax.jit(cell.step)
    p, o, m = jitted(params, opt_state, jnp.int32(0), batch)
    loss1 = float(m["loss"])
    p, o, m = jitted(p, o, jnp.int32(1), batch)
    loss2 = float(m["loss"])
assert np.isfinite(loss1) and np.isfinite(loss2)
assert loss2 < loss1 + 1.0
print("PJIT_TRAIN_OK", loss1, loss2)
"""
    )
    assert "PJIT_TRAIN_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline_parallel import pipelined_apply
mesh = jax.make_mesh((4,), ("stage",))
S, M, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
params = jax.random.normal(key, (S, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
out = pipelined_apply(stage_fn, params, x, mesh)
# sequential reference
ref = x
for i in range(S):
    ref = stage_fn(params[i], ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

# gradients flow through the pipeline
def loss(p):
    return jnp.sum(pipelined_apply(stage_fn, p, x, mesh) ** 2)
g = jax.grad(loss)(params)
assert float(jnp.abs(g).sum()) > 0
print("PP_OK")
"""
    )
    assert "PP_OK" in out


def test_gradient_compression_psum():
    out = run_sub(
        """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.train.compression import compressed_psum, int8_compress, int8_decompress

mesh = jax.make_mesh((8,), ("data",))
x = jnp.arange(64.0).reshape(8, 8) / 64.0

def body(xs):
    return compressed_psum(xs[0], "data", codec="int8")

out = shard_map(body, mesh=mesh, in_specs=(P("data"),), out_specs=P(), check_rep=False)(x)
expect = x.sum(axis=0)
err = float(jnp.abs(out - expect).max()) / float(jnp.abs(expect).max())
assert err < 0.05, err  # int8 quantization error bound

q, s = int8_compress(jnp.asarray([0.5, -1.0, 0.25]))
back = int8_decompress(q, s)
np.testing.assert_allclose(np.asarray(back), [0.5, -1.0, 0.25], atol=0.02)
print("COMPRESS_OK", err)
"""
    )
    assert "COMPRESS_OK" in out


def test_trial_slice_scheduler_backfills():
    out = run_sub(
        """
import jax
import repro.core as hpo
from repro.launch.mesh import make_host_mesh, slice_mesh
from repro.tune.scheduler import TrialSliceScheduler

mesh = make_host_mesh((4, 2), ("data", "model"))
slices = slice_mesh(mesh, 4, axis="data")
assert len(slices) == 4 and all(s.devices.size == 2 for s in slices)

study = hpo.create_study(sampler=hpo.RandomSampler(seed=0),
                         pruner=hpo.SuccessiveHalvingPruner(1, 2, 0))

import time

def run_trial(trial, mesh):
    x = trial.suggest_float("x", 0, 1)
    for step in (1, 2, 4):
        time.sleep(0.02)  # simulated train epochs so slices overlap
        trial.report(x + step * 0.001, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return x

sched = TrialSliceScheduler(study, slices, run_trial)
sched.run(n_trials=16)
trials = study.trials
assert len(trials) == 16
done = [t for t in trials if t.state.name == "COMPLETE"]
pruned = [t for t in trials if t.state.name == "PRUNED"]
assert len(done) >= 1 and len(pruned) >= 1
slices_used = {e[1] for e in sched.events}
assert len(slices_used) >= 2, slices_used  # concurrent slices got work (backfill)
print("SCHED_OK", len(done), len(pruned))
"""
    )
    assert "SCHED_OK" in out


def test_dryrun_single_cell_multi_pod():
    """End-to-end mini dry-run: the real dryrun module, 512 fake devices,
    multi-pod mesh, smallest arch cell."""
    out = run_sub(
        """
import sys
from repro.launch.dryrun import run_cell
rec = run_cell("smollm-135m", "decode_32k", multi_pod=True, out_dir="/tmp/dryrun_test")
assert rec["n_chips"] == 512
assert rec["memory"]["per_device_total"] < 16 * 2**30
assert rec["hlo_stats"]["flops"] > 0
print("DRYRUN_OK")
""",
        n_devices=512,
        timeout=560,
    )
    assert "DRYRUN_OK" in out
