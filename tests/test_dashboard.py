"""Dashboard SVG golden-shape tests + live metrics panel (ISSUE 6 satellite).

"Golden shape" = assert on the structural skeleton of the generated SVG
(element counts, axis labels, highlighted-point counts) for seeded studies,
not on brittle pixel coordinates.
"""

import re

import pytest

import repro.core as hpo
from repro.core.dashboard import (
    _history_svg,
    _importance_svg,
    _metrics_panel_html,
    _pareto_svg,
    _throughput_svg,
    render_dashboard,
)


def _seeded_study(n_trials=20):
    s = hpo.create_study(sampler=hpo.RandomSampler(seed=11))

    def obj(t):
        x = t.suggest_float("x", 0, 1)
        y = t.suggest_float("y", 0, 1)
        return 5 * x + 0.1 * y

    s.optimize(obj, n_trials=n_trials)
    return s


def _seeded_moo_study(n_trials=20):
    s = hpo.create_study(
        directions=["minimize", "minimize"], sampler=hpo.RandomSampler(seed=11)
    )

    def obj(t):
        x = t.suggest_float("x", 0, 1)
        return x, 1 - x

    s.optimize(obj, n_trials=n_trials)
    return s


class TestHistorySvg:
    def test_shape(self):
        svg = _history_svg(_seeded_study(20))
        assert svg.startswith("<svg")
        # one dot per completed trial + the best-so-far polyline + axis frame
        assert svg.count("<circle") == 20
        assert svg.count("<polyline") == 1
        assert svg.count("<line") == 2
        assert "trial #" in svg

    def test_empty_study(self):
        s = hpo.create_study()
        assert "no completed trials" in _history_svg(s)


class TestParetoSvg:
    def test_shape(self):
        s = _seeded_moo_study(20)
        svg = _pareto_svg(s)
        assert svg.count("<circle") == 20
        n_front = len(s.pareto_front()[1])
        assert f"Pareto front ({n_front} trials)" in svg
        # front points are the big red ones
        assert svg.count('r="3.5"') == n_front
        assert svg.count('fill="#c0392b"') == n_front + 1  # circles + legend text

    def test_empty(self):
        s = hpo.create_study(directions=["minimize", "minimize"])
        assert "no completed trials" in _pareto_svg(s)


class TestImportanceSvg:
    def test_shape(self):
        svg = _importance_svg(_seeded_study(30))
        # one bar + name label + value label per parameter
        assert svg.count("<rect") == 2
        assert ">x<" in svg and ">y<" in svg
        vals = [float(v) for v in re.findall(r'font-size="10">([0-9.]+)</text>', svg)]
        assert len(vals) == 2 and abs(sum(vals) - 1.0) < 0.02

    def test_multi_objective_grouped(self):
        # MO studies render one labelled bar group per objective
        svg = _importance_svg(_seeded_moo_study(10))
        assert "objective 0" in svg and "objective 1" in svg
        assert svg.count("<rect") >= 2

    def test_unavailable(self):
        s = hpo.create_study()
        assert "importances unavailable" in _importance_svg(s)


class TestLivePanel:
    def test_throughput_sparkline(self):
        svg = _throughput_svg([0.0, 1.0, 4.0, 2.0])
        assert svg.count("<polyline") == 1
        assert svg.count("<polygon") == 1  # the filled area
        assert "now 2.00" in svg and "peak 4.00" in svg
        assert "no samples yet" in _throughput_svg([])

    def test_metrics_panel(self):
        metrics = {
            "uptime_s": 12.0,
            "active_connections": 3,
            "frames_in": 10,
            "frames_out": 10,
            "bytes_in": 2048,
            "bytes_out": 4096,
            "spec_cache_hits": 1,
            "methods": {
                "get_trial": {
                    "calls": 7, "errors": 0, "bytes_out": 700,
                    "p50": 0.001, "p95": 0.002, "p99": 0.003, "max": 0.004,
                },
            },
        }
        htm = _metrics_panel_html(metrics)
        assert "3 active" in htm
        assert "2.0 KiB in / 4.0 KiB out" in htm
        assert "<td>get_trial</td><td>7</td>" in htm
        assert "<td>1.00</td><td>2.00</td><td>3.00</td>" in htm  # ms columns
        assert "unavailable" in _metrics_panel_html(None)

    def test_render_dashboard_live_section(self):
        s = _seeded_study(5)
        plain = render_dashboard(s)
        assert "Live server metrics" not in plain
        live = render_dashboard(s, server_metrics={}, throughput=[1.0, 2.0])
        assert "Live server metrics" in live
        assert "trials/s" in live

    def test_live_panel_from_real_server(self):
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            remote = hpo.RemoteStorage(server.url)
            s = hpo.create_study(
                study_name="live", storage=remote, sampler=hpo.RandomSampler(seed=0)
            )
            s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=5)
            html = render_dashboard(s, server_metrics=remote.get_server_metrics())
        assert "Live server metrics" in html
        assert "<td>create_new_trial</td><td>5</td>" in html


class TestImportanceEdgeCases:
    """Pins the ISSUE-6 fix: degrade to {} instead of raising / misranking."""

    def test_multi_objective_per_objective_dicts(self):
        # since the analytics-service PR: one importance dict per objective,
        # keyed by objective index
        s = _seeded_moo_study(20)
        for res in (hpo.param_importances(s), hpo.spearman_importances(s)):
            assert sorted(res) == [0, 1]
            for d in res.values():
                assert sorted(d) == ["x"]
                assert abs(sum(d.values()) - 1.0) < 1e-9

    def test_single_objective_unchanged(self):
        # objective=0 on a single-objective study is the flat dict, identical
        # to calling with no objective argument
        s = _seeded_study(25)
        assert hpo.param_importances(s, objective=0) == hpo.param_importances(s)
        assert hpo.spearman_importances(s, objective=0) == hpo.spearman_importances(s)

    def test_fewer_than_two_complete_trials(self):
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        assert hpo.param_importances(s) == {}
        assert hpo.spearman_importances(s) == {}
        s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=1)
        assert hpo.param_importances(s) == {}
        assert hpo.spearman_importances(s) == {}

    def test_two_and_three_trials_zero_scores(self):
        s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=3)
        assert hpo.param_importances(s) == {"x": 0.0}
        assert hpo.spearman_importances(s) == {"x": 0.0}

    def test_failed_trials_only(self):
        s = hpo.create_study()

        def boom(t):
            t.suggest_float("x", 0, 1)
            raise ValueError("nope")

        s.optimize(boom, n_trials=3, catch=(ValueError,))
        assert hpo.param_importances(s) == {}
