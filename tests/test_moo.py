"""Multi-objective engine: vectorized dominance/rank/crowding/hypervolume
pinned against brute-force pairwise references (randomized: both directions,
duplicates, NaN rows), and the engine-backed ``Study.best_trials`` pinned
bit-identical to the frozen pure-Python pairwise loop."""

import itertools

import numpy as np
import pytest

import repro.core as hpo
from repro.core import moo
from repro.core.frozen import StudyDirection, TrialState
from repro.core.study import _pairwise_best_trials


# -- brute-force references -------------------------------------------------------


def dominates(a, b) -> bool:
    """Scalar pairwise dominance (loss orientation), NaN-safe per IEEE."""
    better = False
    for av, bv in zip(a, b):
        if av > bv:
            return False
        if av < bv:
            better = True
    return better


def brute_ranks(V) -> np.ndarray:
    n = len(V)
    ranks = np.full(n, -1)
    remaining = set(range(n))
    rank = 0
    while remaining:
        front = [
            i for i in remaining
            if not any(dominates(V[j], V[i]) for j in remaining if j != i)
        ]
        for i in front:
            ranks[i] = rank
            remaining.discard(i)
        rank += 1
    return ranks


def brute_hypervolume(points, ref, samples=200_000, seed=0) -> float:
    """Monte-Carlo hypervolume (used only to sanity-check exact values)."""
    rng = np.random.RandomState(seed)
    points = np.asarray(points, float)
    lo = points.min(axis=0)
    box = np.prod(ref - lo)
    u = lo + rng.uniform(size=(samples, len(ref))) * (ref - lo)
    hit = (u[:, None, :] >= points[None, :, :]).all(axis=2).any(axis=1)
    return float(box * hit.mean())


def grid_hypervolume(points, ref) -> float:
    """Exact hypervolume for integer-coordinate points by unit-cell counting."""
    points = np.asarray(points, float)
    lo = points.min(axis=0).astype(int)
    axes = [range(int(l), int(r)) for l, r in zip(lo, ref)]
    count = 0
    for cell in itertools.product(*axes):
        c = np.asarray(cell, float)
        if ((points <= c).all(axis=1)).any():
            count += 1
    return float(count)


def random_values(rng, n, m, duplicates=True, nan_rows=False):
    if duplicates:
        V = rng.randint(0, 4, size=(n, m)).astype(float)
    else:
        V = rng.uniform(-5, 5, size=(n, m))
    if nan_rows and n > 2:
        V[rng.choice(n, size=max(1, n // 8), replace=False), rng.randint(m)] = np.nan
    return V


# -- dominance / ranks --------------------------------------------------------------


class TestDominance:
    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("m", [2, 3, 4])
    def test_ranks_match_brute_force(self, seed, m):
        rng = np.random.RandomState(seed)
        V = random_values(rng, 40, m, duplicates=seed % 2 == 0)
        assert np.array_equal(moo.nondomination_ranks(V), brute_ranks(V))

    def test_ranks_with_nan_rows(self):
        rng = np.random.RandomState(7)
        V = random_values(rng, 30, 3, nan_rows=True)
        assert np.array_equal(moo.nondomination_ranks(V), brute_ranks(V))

    def test_ranks_with_mask(self):
        rng = np.random.RandomState(3)
        V = random_values(rng, 25, 2)
        mask = rng.uniform(size=25) < 0.6
        ranks = moo.nondomination_ranks(V, mask=mask)
        assert (ranks[~mask] == moo.EXCLUDED).all()
        # included rows rank exactly as if the excluded rows never existed
        sub = brute_ranks(V[mask])
        assert np.array_equal(ranks[mask], sub)

    def test_front_mask_is_rank_zero(self):
        rng = np.random.RandomState(11)
        V = random_values(rng, 50, 3)
        assert np.array_equal(moo.pareto_front_mask(V), moo.nondomination_ranks(V) == 0)

    def test_duplicates_share_the_front(self):
        V = np.asarray([[1.0, 2.0], [1.0, 2.0], [0.5, 3.0]])
        assert moo.pareto_front_mask(V).all()

    def test_single_objective_ranks_are_sorted_order(self):
        V = np.asarray([[3.0], [1.0], [2.0], [1.0]])
        assert np.array_equal(moo.nondomination_ranks(V), [2, 0, 1, 0])

    def test_chunked_path_matches_small(self):
        # force multiple chunks through the chunked numpy reduction
        old = moo._DOM_CHUNK
        moo._DOM_CHUNK = 7
        try:
            rng = np.random.RandomState(5)
            V = random_values(rng, 40, 2)
            assert np.array_equal(moo.nondomination_ranks(V), brute_ranks(V))
        finally:
            moo._DOM_CHUNK = old

    def test_prefilter_path_matches_full_reduction(self):
        # above _PREFILTER_MIN rows the NaN-free path thins the field with
        # strong dominators first; the front must be exactly the full one
        rng = np.random.RandomState(21)
        for m in (2, 3):
            V = rng.uniform(size=(moo._PREFILTER_MIN + 100, m))
            V[:5] = V[5:10]  # duplicated rows survive together
            fast = moo.pareto_front_mask(V)
            old = moo._PREFILTER_MIN
            moo._PREFILTER_MIN = 10**9
            try:
                full = moo.pareto_front_mask(V)
            finally:
                moo._PREFILTER_MIN = old
            assert np.array_equal(fast, full)

    def test_jax_path_matches_numpy(self):
        pytest.importorskip("jax")
        rng = np.random.RandomState(13)
        V = random_values(rng, 33, 3)
        assert np.array_equal(
            moo.dominance_matrix(V, jit=True), moo.dominance_matrix(V)
        )

    def test_jax_trace_count_stays_bounded(self):
        pytest.importorskip("jax")
        from repro.kernels import ops as kops

        before = kops.trace_count("moo.dominance")
        for n in range(20, 30):  # all pad to the same pow2 bucket
            V = np.random.RandomState(n).uniform(size=(n, 2))
            moo.dominance_matrix(V, jit=True)
        assert kops.trace_count("moo.dominance") - before <= 1


class TestLossMatrix:
    def test_sign_flip_on_maximize(self):
        V = np.asarray([[1.0, 2.0], [3.0, 4.0]])
        L = moo.loss_matrix(V, [StudyDirection.MINIMIZE, StudyDirection.MAXIMIZE])
        assert np.array_equal(L, [[1.0, -2.0], [3.0, -4.0]])
        assert np.array_equal(V, [[1.0, 2.0], [3.0, 4.0]])  # input untouched

    def test_arity_mismatch_raises(self):
        with pytest.raises(ValueError):
            moo.loss_matrix(np.zeros((3, 2)), [StudyDirection.MINIMIZE])


# -- crowding -----------------------------------------------------------------------


class TestCrowding:
    def test_boundary_points_are_infinite(self):
        V = np.asarray([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
        d = moo.crowding_distance(V)
        assert np.isinf(d[0]) and np.isinf(d[3])
        assert np.isfinite(d[1]) and np.isfinite(d[2])

    def test_matches_reference_implementation(self):
        def brute_crowding(V):
            n, m = V.shape
            if n <= 2:
                return np.full(n, np.inf)
            out = np.zeros(n)
            for j in range(m):
                order = np.argsort(V[:, j], kind="stable")
                span = V[order[-1], j] - V[order[0], j]
                out[order[0]] = out[order[-1]] = np.inf
                for k in range(1, n - 1):
                    if span > 0:
                        out[order[k]] += (V[order[k + 1], j] - V[order[k - 1], j]) / span
            return out

        rng = np.random.RandomState(2)
        V = rng.uniform(size=(20, 3))
        assert np.allclose(moo.crowding_distance(V), brute_crowding(V))

    def test_constant_objective_contributes_nothing(self):
        V = np.asarray([[1.0, 0.0], [1.0, 0.5], [1.0, 1.0]])
        d = moo.crowding_distance(V)
        assert np.isinf(d[0]) and np.isinf(d[2]) and d[1] == 1.0


# -- hypervolume --------------------------------------------------------------------


class TestHypervolume:
    def test_2d_staircase_closed_form(self):
        # the WFG reference staircase: hv == n^2 - n(n-1)/2
        for n in (2, 5, 17):
            ref = n * np.ones(2)
            pts = np.asarray([[n - 1 - i, i] for i in range(n)], dtype=float)
            assert moo.hypervolume(pts, ref) == n * n - n * (n - 1) // 2

    @pytest.mark.parametrize("m", [2, 3, 4])
    def test_unit_corners_closed_form(self, m):
        # unit vectors against ref=2: hv == 2^m - 1
        pts = np.eye(m)
        assert moo.hypervolume(pts, 2.0 * np.ones(m)) == 2**m - 1

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("m", [2, 3])
    def test_matches_grid_counting(self, seed, m):
        rng = np.random.RandomState(seed)
        pts = rng.randint(0, 5, size=(8, m)).astype(float)
        ref = 6 * np.ones(m)
        assert moo.hypervolume(pts, ref) == pytest.approx(grid_hypervolume(pts, ref))

    def test_dominated_and_outside_points_are_free(self):
        ref = np.asarray([4.0, 4.0])
        base = np.asarray([[1.0, 1.0]])
        noisy = np.asarray([[1.0, 1.0], [2.0, 2.0], [5.0, 0.0], [1.0, 1.0]])
        assert moo.hypervolume(base, ref) == moo.hypervolume(noisy, ref)

    def test_empty_and_outside_only(self):
        ref = np.asarray([1.0, 1.0])
        assert moo.hypervolume(np.empty((0, 2)), ref) == 0.0
        assert moo.hypervolume(np.asarray([[2.0, 2.0]]), ref) == 0.0

    def test_monte_carlo_agreement_4d(self):
        rng = np.random.RandomState(9)
        pts = rng.uniform(0, 1, size=(10, 4))
        ref = np.ones(4) * 1.2
        exact = moo.hypervolume(pts, ref)
        mc = brute_hypervolume(pts, ref, samples=400_000)
        assert exact == pytest.approx(mc, rel=0.05)


class TestHSSP:
    def test_selects_all_when_k_is_n(self):
        pts = np.asarray([[0.0, 2.0], [1.0, 1.0], [2.0, 0.0]])
        sel = moo.solve_hssp(pts, 3, np.asarray([3.0, 3.0]))
        assert sorted(sel.tolist()) == [0, 1, 2]

    def test_greedy_picks_largest_contributor_first(self):
        pts = np.asarray([[0.0, 2.9], [1.0, 1.0], [2.9, 0.0]])
        sel = moo.solve_hssp(pts, 1, np.asarray([3.0, 3.0]))
        assert sel.tolist() == [1]  # the knee dominates the most volume

    def test_subset_hv_close_to_best_pair(self):
        rng = np.random.RandomState(4)
        pts = rng.uniform(size=(7, 2))
        ref = np.ones(2) * 1.1
        sel = moo.solve_hssp(pts, 2, ref)
        got = moo.hypervolume(pts[sel], ref)
        best = max(
            moo.hypervolume(pts[list(pair)], ref)
            for pair in itertools.combinations(range(7), 2)
        )
        assert got >= 0.6 * best  # greedy 1-1/e guarantee with headroom


# -- store + study integration ------------------------------------------------------


def _mo_study(directions, values_list, storage=None):
    study = hpo.create_study(
        directions=directions, sampler=hpo.RandomSampler(seed=0), storage=storage
    )
    for vals in values_list:
        t = study.ask()
        t.suggest_float("x", 0, 1)
        study.tell(t, vals)
    return study


class TestValuesMatrix:
    def test_matrix_and_arity(self):
        study = _mo_study(["minimize", "maximize"], [[1.0, 2.0], [3.0, 4.0]])
        store = study.observations()
        assert store.n_objectives == 2
        assert np.array_equal(store.values_matrix, [[1.0, 2.0], [3.0, 4.0]])
        assert np.array_equal(store.values_arity, [2, 2])

    def test_wrong_arity_row_is_nan(self):
        study = _mo_study(["minimize", "maximize"], [[1.0, 2.0]])
        t = study.ask()
        t.suggest_float("x", 0, 1)
        # storage-level write bypasses Study.tell's normalization
        study._storage.set_trial_state_values(
            t._trial_id, TrialState.COMPLETE, [5.0]
        )
        store = study.observations()
        assert np.array_equal(store.values_arity, [2, 1])
        assert np.isnan(store.values_matrix[1]).all()

    def test_failed_trials_carry_no_values(self):
        study = _mo_study(["minimize", "minimize"], [[1.0, 2.0]])
        t = study.ask()
        t.suggest_float("x", 0, 1)
        study.tell(t, state=TrialState.FAIL)
        store = study.observations()
        assert np.array_equal(store.values_arity, [2, 0])

    def test_single_objective_matrix_matches_values(self):
        study = _mo_study(["minimize"], [[3.0], [1.0], [2.0]])
        store = study.observations()
        assert store.values_matrix.shape == (3, 1)
        assert np.array_equal(store.values_matrix[:, 0], store.values)


class TestBestTrialsParity:
    @pytest.mark.parametrize("seed", range(6))
    def test_engine_bit_identical_to_pairwise_loop(self, seed):
        rng = np.random.RandomState(seed)
        m = 2 + seed % 3
        dirs = [
            "minimize" if rng.uniform() < 0.5 else "maximize" for _ in range(m)
        ]
        values = rng.randint(0, 4, size=(30, m)).astype(float).tolist()
        study = _mo_study(dirs, values)
        # sprinkle pruned/failed trials: they must not affect the front
        for _ in range(3):
            t = study.ask()
            t.suggest_float("x", 0, 1)
            study.tell(t, state=TrialState.PRUNED)
        engine = study.best_trials
        completed = study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        reference = _pairwise_best_trials(completed, study.directions)
        assert [t.number for t in engine] == [t.number for t in reference]
        assert [t.values for t in engine] == [t.values for t in reference]

    def test_infinite_values_match_pairwise_loop(self):
        study = _mo_study(
            ["minimize", "minimize"],
            [[np.inf, 0.0], [0.0, np.inf], [1.0, 1.0], [np.inf, np.inf]],
        )
        engine = [t.number for t in study.best_trials]
        completed = study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,))
        reference = [t.number for t in _pairwise_best_trials(completed, study.directions)]
        assert engine == reference

    def test_pareto_front_arrays_match_best_trials(self):
        study = _mo_study(
            ["minimize", "maximize"],
            [[1.0, 1.0], [2.0, 2.0], [0.5, 0.5], [1.0, 3.0]],
        )
        vals, nums = study.pareto_front()
        assert nums.tolist() == [t.number for t in study.best_trials]
        assert vals.tolist() == [t.values for t in study.best_trials]

    def test_single_objective_front_is_best_trial(self):
        study = _mo_study(["minimize"], [[3.0], [1.0], [2.0]])
        assert [t.number for t in study.best_trials] == [1]
        assert study.best_trial.number == 1
