"""Joint (block) sampling across the sampler stack: group decomposition of
the observed search space, one ``sample_joint`` call per group per batched
``ask(n)``, the define-by-run shim that slices precomputed blocks, and the
multivariate TPE quality/throughput acceptance bars."""

import logging

import numpy as np
import pytest

import repro.core as hpo
from repro.core.distributions import (
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from repro.core.frozen import TrialState
from repro.core.search_space import ParamGroup, observed_groups


# -- helpers ---------------------------------------------------------------------


def seed_trials(study, rows, value=1.0):
    """Write finished trials straight to storage; ``rows`` is a list of
    {name: (internal_value, distribution)} dicts."""
    storage, sid = study._storage, study._study_id
    for i, row in enumerate(rows):
        tid = storage.create_new_trial(sid)
        for name, (internal, dist) in row.items():
            storage.set_trial_param(tid, name, internal, dist)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [value + 0.1 * i])


def f(lo=0.0, hi=1.0, **kw):
    return FloatDistribution(lo, hi, **kw)


def brute_force_groups(trials):
    """Union-find reference implementation over FrozenTrial lists."""
    parent: dict = {}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[rb] = ra

    seen = set()
    for t in trials:
        if t.state not in (TrialState.COMPLETE, TrialState.PRUNED):
            continue
        names = sorted(t.distributions)
        for n in names:
            parent.setdefault(n, n)
            seen.add(n)
        for a, b in zip(names, names[1:]):
            union(a, b)
    comps: dict = {}
    for n in seen:
        comps.setdefault(find(n), []).append(n)
    return sorted(tuple(sorted(c)) for c in comps.values())


# -- group decomposition ----------------------------------------------------------


class TestGroupDecomposition:
    def groups_of(self, study):
        return [g.names for g in observed_groups(study.observations())]

    def test_disjoint_groups(self):
        s = hpo.create_study()
        seed_trials(s, [
            {"a": (0.1, f()), "b": (0.2, f())},
            {"c": (0.3, f()), "d": (0.4, f())},
            {"a": (0.5, f()), "b": (0.6, f())},
        ])
        assert self.groups_of(s) == [("a", "b"), ("c", "d")]

    def test_chained_overlap_merges(self):
        s = hpo.create_study()
        seed_trials(s, [
            {"a": (0.1, f()), "b": (0.2, f())},
            {"b": (0.3, f()), "c": (0.4, f())},
            {"c": (0.5, f()), "d": (0.6, f())},
        ])
        assert self.groups_of(s) == [("a", "b", "c", "d")]

    def test_singleton_params(self):
        s = hpo.create_study()
        seed_trials(s, [{"a": (0.1, f())}, {"b": (0.2, f())}])
        assert self.groups_of(s) == [("a",), ("b",)]

    def test_all_joint(self):
        s = hpo.create_study()
        seed_trials(s, [
            {"a": (0.1, f()), "b": (0.2, f()), "c": (0.3, f())},
            {"a": (0.4, f()), "b": (0.5, f()), "c": (0.6, f())},
        ])
        assert self.groups_of(s) == [("a", "b", "c")]

    def test_running_trials_do_not_group(self):
        s = hpo.create_study()
        seed_trials(s, [{"a": (0.1, f())}])
        t = s.ask()
        t.suggest_float("a", 0, 1)
        t.suggest_float("zz", 0, 1)  # RUNNING co-occurrence must not count
        assert self.groups_of(s) == [("a",)]

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_randomized_against_union_find_reference(self, seed):
        rng = np.random.RandomState(seed)
        names = [f"p{i}" for i in range(rng.randint(2, 10))]
        rows = []
        for _ in range(rng.randint(1, 12)):
            k = rng.randint(1, len(names) + 1)
            subset = rng.choice(names, size=k, replace=False)
            rows.append({n: (float(rng.uniform()), f()) for n in subset})
        s = hpo.create_study()
        seed_trials(s, rows)
        got = [g.names for g in observed_groups(s.observations())]
        assert got == brute_force_groups(s.trials)

    def test_group_dists_are_latest(self):
        s = hpo.create_study()
        seed_trials(s, [
            {"a": (0.1, f(0, 1)), "b": (0.2, f())},
            {"a": (1.5, f(0, 2)), "b": (0.2, f())},  # bounds drifted
        ])
        (group,) = observed_groups(s.observations())
        assert group.dists["a"].high == 2.0

    def test_groups_memoized_per_store_version(self):
        s = hpo.create_study()
        seed_trials(s, [{"a": (0.1, f())}])
        g1 = s.observed_param_groups()
        assert s.observed_param_groups() is g1  # same store version -> cached
        seed_trials(s, [{"b": (0.2, f())}])
        assert len(s.observed_param_groups()) == 2


# -- the ask(n) presample contract -------------------------------------------------


class _CountingTPE(hpo.TPESampler):
    def __init__(self, **kw):
        super().__init__(**kw)
        self.joint_calls = []

    def sample_joint(self, study, group, n, trial_ids=None):
        self.joint_calls.append((group.names, n))
        return super().sample_joint(study, group, n, trial_ids=trial_ids)


class TestJointAsk:
    def test_one_sample_joint_call_per_group(self):
        sampler = _CountingTPE(seed=0, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [
            {"a": (0.1, f()), "b": (0.2, f())},
            {"c": (0.3, f()), "d": (0.4, f())},
            {"a": (0.5, f()), "b": (0.6, f())},
            {"c": (0.7, f()), "d": (0.8, f())},
        ])
        trials = study.ask(16)
        assert len(trials) == 16
        # exactly one joint call per group for the whole 16-trial wave
        assert sorted(sampler.joint_calls) == [(("a", "b"), 16), (("c", "d"), 16)]
        for t in trials:
            assert 0 <= t.suggest_float("a", 0, 1) <= 1
            assert 0 <= t.suggest_float("b", 0, 1) <= 1
        assert sorted(sampler.joint_calls) == [(("a", "b"), 16), (("c", "d"), 16)]
        study.tell_batch([(t, 1.0) for t in trials])

    def test_scalar_ask_never_presamples(self):
        sampler = _CountingTPE(seed=0, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (0.1, f())}, {"a": (0.2, f())}])
        t = study.ask()
        t.suggest_float("a", 0, 1)
        assert sampler.joint_calls == []

    def test_multivariate_false_never_presamples(self):
        sampler = hpo.TPESampler(seed=0, multivariate=False)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (0.1, f())}] * 12)
        trials = study.ask(4)
        assert all(t._joint is None for t in trials)

    def test_joint_values_respect_bounds_and_types(self):
        sampler = hpo.TPESampler(seed=3, n_startup_trials=4, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        cat = CategoricalDistribution(["u", "v", "w"])
        rows = []
        rng = np.random.RandomState(0)
        for _ in range(12):
            rows.append({
                "x": (float(rng.uniform(-2, 2)), f(-2, 2)),
                "lr": (float(np.exp(rng.uniform(np.log(1e-4), 0))), f(1e-4, 1.0, log=True)),
                "n": (float(rng.randint(1, 9)), IntDistribution(1, 8)),
                "k": (float(rng.randint(3)), cat),
            })
        study.seeded = seed_trials(study, rows)
        trials = study.ask(8)
        for t in trials:
            assert -2 <= t.suggest_float("x", -2, 2) <= 2
            assert 1e-4 <= t.suggest_float("lr", 1e-4, 1.0, log=True) <= 1.0
            assert t.suggest_int("n", 1, 8) in range(1, 9)
            assert t.suggest_categorical("k", ["u", "v", "w"]) in ("u", "v", "w")
        study.tell_batch([(t, 0.5) for t in trials])

    def test_fixed_params_win_over_joint_block(self):
        sampler = hpo.TPESampler(seed=1, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (0.1, f())}, {"a": (0.2, f())}])
        study.enqueue_trial({"a": 0.77})
        study.optimize(lambda t: t.suggest_float("a", 0, 1), n_trials=2, ask_batch=2)
        assert any(t.params.get("a") == 0.77 for t in study.trials)


# -- divergence fallback (dynamic define-by-run branches) ---------------------------


class TestJointFallback:
    def test_unpredicted_param_logged_once_per_study(self, caplog):
        sampler = hpo.TPESampler(seed=0, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (0.3, f())}, {"a": (0.4, f())}, {"a": (0.5, f())}])
        with caplog.at_level(logging.INFO, logger="repro.core.study"):
            for _ in range(2):  # two waves, every trial misses on "fresh"
                wave = study.ask(4)
                results = []
                for t in wave:
                    v = t.suggest_float("a", 0, 1) + t.suggest_float("fresh", 0, 1)
                    results.append((t, v))
                study.tell_batch(results)
        misses = [r for r in caplog.records if "joint block missed" in r.message]
        assert len(misses) == 1  # once per study, not per trial or per wave
        for t in study.trials:
            if "fresh" in t.params:
                assert 0 <= t.params["fresh"] <= 1

    def test_branching_objective_conditional_suggest_int(self, caplog):
        """Define-by-run branch: a conditional suggest_int inside an
        ``if suggest_categorical(...)`` that history never observed."""
        sampler = hpo.TPESampler(seed=5, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        cat = CategoricalDistribution([0, 1])
        # history only ever saw the k=0 branch
        seed_trials(study, [
            {"k": (0.0, cat), "lo_n": (float(i % 8 + 1), IntDistribution(1, 8))}
            for i in range(6)
        ])

        def objective(trial):
            if trial.suggest_categorical("k", [0, 1]) == 0:
                return trial.suggest_int("lo_n", 1, 8) * 0.1
            return trial.suggest_int("hi_n", 1, 8) * 0.2  # unpredicted branch

        with caplog.at_level(logging.INFO, logger="repro.core.study"):
            study.optimize(objective, n_trials=24, ask_batch=8)
        misses = [r for r in caplog.records if "joint block missed" in r.message]
        assert len(misses) <= 1
        hi = [t for t in study.trials if "hi_n" in t.params]
        assert hi, "seed must exercise the unobserved branch"
        assert len(misses) == 1
        for t in hi:
            assert t.params["hi_n"] in range(1, 9)
            assert t.state == TrialState.COMPLETE

    def test_drifted_bounds_fall_back_to_scalar(self, caplog):
        sampler = hpo.TPESampler(seed=2, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (5.0, f(0, 10))}, {"a": (6.0, f(0, 10))}])
        with caplog.at_level(logging.INFO, logger="repro.core.study"):
            wave = study.ask(4)
            for t in wave:
                v = t.suggest_float("a", 100, 101)  # domain moved entirely
                assert 100 <= v <= 101
        # the block value (model space ~[0, 10]) must be REJECTED, not
        # clipped into the new domain: exactly one miss log proves it
        assert sum("bounds drifted" in r.message for r in caplog.records) == 1
        study.tell_batch([(t, 1.0) for t in wave])

    def test_log_flag_change_falls_back_to_scalar(self, caplog):
        """Same type, different coordinate system: a log=True history must
        not feed ln-space block values into a linear runtime domain."""
        sampler = hpo.TPESampler(seed=2, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        log_dist = f(1e-6, 1.0, log=True)
        seed_trials(study, [
            {"lr": (1e-3, log_dist)}, {"lr": (1e-4, log_dist)}, {"lr": (1e-2, log_dist)},
        ])
        with caplog.at_level(logging.INFO, logger="repro.core.study"):
            wave = study.ask(4)
            values = [t.suggest_float("lr", 1e-6, 1.0) for t in wave]  # log dropped
        assert sum("log flag changed" in r.message for r in caplog.records) == 1
        assert all(1e-6 <= v <= 1.0 for v in values)
        study.tell_batch([(t, 1.0) for t in wave])

    def test_changed_type_falls_back_to_scalar(self):
        sampler = hpo.TPESampler(seed=2, n_startup_trials=2, multivariate=True)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"a": (0.2, f())}, {"a": (0.4, f())}])
        wave = study.ask(3)
        for t in wave:
            assert t.suggest_categorical("a2", ["p", "q"]) in ("p", "q")
            assert 0 <= t.suggest_float("a", 0, 1) <= 1
        study.tell_batch([(t, 1.0) for t in wave])


# -- native joint blocks of the other samplers --------------------------------------


class TestSamplerBlocks:
    def _group(self, study):
        (group,) = observed_groups(study.observations())
        return group

    def test_random_block_shape_and_bounds(self):
        sampler = hpo.RandomSampler(seed=0)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{
            "x": (0.5, f(-1, 1)),
            "lr": (0.01, f(1e-4, 1.0, log=True)),
            "k": (1.0, CategoricalDistribution(["a", "b", "c"])),
        }])
        group = self._group(study)
        block = sampler.sample_joint(study, group, 7)
        assert block.shape == (7, 3)
        names = list(group.names)
        lr_col = block[:, names.index("lr")]
        assert np.all(lr_col <= 0.0)  # model space: log(lr) <= log(1.0)
        k_col = block[:, names.index("k")]
        assert set(np.unique(k_col)) <= {0.0, 1.0, 2.0}

    def test_random_ask_wave_end_to_end(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=4))

        def objective(t):
            return t.suggest_float("x", -1, 1) ** 2 + t.suggest_int("n", 1, 4)

        study.optimize(objective, n_trials=4)  # history -> one group
        wave = study.ask(6)
        study.tell_batch([(t, objective(t)) for t in wave])
        assert sum(t.state == TrialState.COMPLETE for t in study.trials) == 10

    def test_grid_block_claims_distinct_cells(self):
        grid = {"a": [1, 2, 3], "b": [10.0, 20.0]}
        sampler = hpo.GridSampler(grid, seed=0)
        study = hpo.create_study(sampler=sampler)

        def objective(t):
            return t.suggest_int("a", 1, 3) * t.suggest_float("b", 10.0, 20.0)

        study.optimize(objective, n_trials=2)  # seed co-occurrence
        wave = study.ask(4)
        study.tell_batch([(t, objective(t)) for t in wave])
        gids = [
            t.system_attrs["grid_sampler:grid_id"]
            for t in study.trials if t.state == TrialState.COMPLETE
        ]
        assert len(gids) == 6 and len(set(gids)) == 6  # grid fully covered, no dup

    def test_cmaes_block_covers_numeric_space(self):
        sampler = hpo.CmaEsSampler(warmup_trials=5, seed=7)
        study = hpo.create_study(sampler=sampler)

        def objective(t):
            return (t.suggest_float("x", -2, 2) - 1) ** 2 + t.suggest_float("y", -2, 2) ** 2

        study.optimize(objective, n_trials=8)
        group = self._group(study)
        block = sampler.sample_joint(study, group, 5)
        assert block is not None and block.shape == (5, 2)
        assert np.isfinite(block).all()
        assert np.all((block >= -2) & (block <= 2))

    def test_gp_block_takes_distinct_top_ei_rows(self):
        sampler = hpo.GPSampler(seed=3, n_startup_trials=4, n_candidates=64)
        study = hpo.create_study(sampler=sampler)

        def objective(t):
            return t.suggest_float("x", 0, 1) ** 2 + t.suggest_float("y", 0, 1)

        study.optimize(objective, n_trials=6)
        group = self._group(study)
        block = sampler.sample_joint(study, group, 4)
        assert block is not None and block.shape == (4, 2)
        assert len({tuple(row) for row in np.round(block, 12)}) == 4

    def test_grid_enqueued_trials_never_claim_cells(self):
        """An enqueued fixed-params trial must not consume a grid cell at
        ask(n) time — its fixed params win over any block, so a claimed cell
        would be marked taken yet never evaluated."""
        grid = {"a": [1, 2], "b": [10.0, 20.0]}
        sampler = hpo.GridSampler(grid, seed=0)
        study = hpo.create_study(sampler=sampler)

        def objective(t):
            return t.suggest_int("a", 1, 2) * t.suggest_float("b", 10.0, 20.0)

        study.optimize(objective, n_trials=1)  # seed co-occurrence
        study.enqueue_trial({"a": 2, "b": 20.0})
        study.optimize(objective, n_trials=4, ask_batch=4)
        enqueued = [t for t in study.trials if t.system_attrs.get("fixed_params")]
        assert len(enqueued) == 1
        assert "grid_sampler:grid_id" not in enqueued[0].system_attrs
        # the sweep still covers all 4 distinct cells via non-enqueued trials
        gids = {
            t.system_attrs.get("grid_sampler:grid_id")
            for t in study.trials if not t.system_attrs.get("fixed_params")
        }
        assert len(gids - {None}) == 4

    def test_cmaes_declines_during_warmup(self):
        sampler = hpo.CmaEsSampler(warmup_trials=50, seed=7)
        study = hpo.create_study(sampler=sampler)
        seed_trials(study, [{"x": (0.1, f()), "y": (0.2, f())}] * 3)
        group = self._group(study)
        assert sampler.sample_joint(study, group, 4) is None


# -- multivariate TPE quality + smoke ----------------------------------------------


def correlated_objective(trial):
    x = trial.suggest_float("x", -5, 5)
    y = trial.suggest_float("y", -5, 5)
    # narrow valley along x = y: structure univariate marginals cannot see
    return (x - y) ** 2 + 0.1 * (x + y - 2) ** 2


class TestMultivariateQuality:
    def _best(self, multivariate, seed, n=200, batch=16):
        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=seed, n_startup_trials=10, multivariate=multivariate)
        )
        done = 0
        while done < n:
            k = min(batch, n - done)
            wave = study.ask(k)
            study.tell_batch([(t, correlated_objective(t)) for t in wave])
            done += k
        return study.best_value

    @pytest.mark.parametrize("seed", [0, 1])
    def test_multivariate_beats_univariate_on_correlated_objective(self, seed):
        assert self._best(True, seed) < self._best(False, seed)

    def test_multivariate_smoke_50_trials_inmemory(self):
        """Tier-1 smoke: a 50-trial multivariate study end-to-end on the
        in-memory backend — batched waves, pruning, mixed param types."""
        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=0, n_startup_trials=8, multivariate=True),
            pruner=hpo.MedianPruner(n_startup_trials=4),
        )

        def objective(trial):
            x = trial.suggest_float("x", -3, 3)
            lr = trial.suggest_float("lr", 1e-4, 1.0, log=True)
            n = trial.suggest_int("n", 1, 16)
            k = trial.suggest_categorical("k", ["a", "b"])
            loss = x * x + abs(np.log10(lr) + 2) + 0.01 * n + (0.5 if k == "b" else 0.0)
            for step in range(3):
                trial.report(loss * (3 - step), step)
                if trial.should_prune():
                    raise hpo.TrialPruned()
            return loss

        study.optimize(objective, n_trials=50, ask_batch=8)
        states = [t.state for t in study.trials]
        assert len(states) == 50
        assert all(s in (TrialState.COMPLETE, TrialState.PRUNED) for s in states)
        assert study.best_value < 10.0
        assert any(g.names == ("k", "lr", "n", "x") for g in study.observed_param_groups())

    def test_jit_scoring_joint_samples_in_bounds(self):
        jax = pytest.importorskip("jax")  # noqa: F841
        sampler = hpo.TPESampler(
            seed=0, n_startup_trials=6, multivariate=True, jit_scoring=True
        )
        study = hpo.create_study(sampler=sampler)

        def objective(trial):
            return trial.suggest_float("x", -2, 2) ** 2 + trial.suggest_float("y", -2, 2) ** 2

        study.optimize(objective, n_trials=8)
        wave = study.ask(8)
        results = []
        for t in wave:
            x, y = t.suggest_float("x", -2, 2), t.suggest_float("y", -2, 2)
            assert -2 <= x <= 2 and -2 <= y <= 2
            results.append((t, x * x + y * y))
        study.tell_batch(results)


# -- scheduler backfill waves ------------------------------------------------------


class TestSchedulerBackfill:
    def test_backfill_batch_completes_all_trials(self):
        from repro.tune.scheduler import TrialSliceScheduler

        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=0, n_startup_trials=4, multivariate=True)
        )

        def run_trial(trial, mesh):
            return trial.suggest_float("x", 0, 1) + trial.suggest_float("y", 0, 1)

        sched = TrialSliceScheduler(study, meshes=[0, 1], run_trial=run_trial,
                                    backfill_batch=3)
        sched.run(n_trials=11)
        done = [t for t in study.trials if t.state == TrialState.COMPLETE]
        assert len(done) == 11
        # surplus prefetched claims were released back to the queue, not leaked
        running = [t for t in study.trials if t.state == TrialState.RUNNING]
        assert not running
