"""Columnar observation store, storage revision counters, and the batched
ask/tell lifecycle."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core.frozen import TrialState
from repro.core.records import ObservationStore


def _finish(storage, sid, params=None, value=0.0, state=TrialState.COMPLETE):
    from repro.core.distributions import FloatDistribution

    tid = storage.create_new_trial(sid)
    for name, v in (params or {}).items():
        storage.set_trial_param(tid, name, v, FloatDistribution(-10, 10))
    vals = [value] if state == TrialState.COMPLETE else None
    storage.set_trial_state_values(tid, state, vals)
    return tid


class TestObservationStore:
    def test_incremental_ingest_and_order(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        store = ObservationStore(storage, sid)
        store.refresh()
        assert store.n_observations == 0

        for i in range(5):
            _finish(storage, sid, {"x": float(i)}, value=float(i))
        store.refresh()
        assert store.n_observations == 5
        assert list(store.numbers) == [0, 1, 2, 3, 4]
        assert np.allclose(store.column("x"), [0, 1, 2, 3, 4])
        assert np.allclose(store.values, [0, 1, 2, 3, 4])

        v0 = store.version
        store.refresh()  # no change -> no version bump
        assert store.version == v0

    def test_out_of_order_finishes_sorted_by_number(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        from repro.core.distributions import FloatDistribution

        t0 = storage.create_new_trial(sid)
        t1 = storage.create_new_trial(sid)
        storage.set_trial_param(t1, "x", 1.0, FloatDistribution(-10, 10))
        storage.set_trial_state_values(t1, TrialState.COMPLETE, [1.0])
        store = ObservationStore(storage, sid)
        store.refresh()
        assert list(store.numbers) == [1]  # trial 0 still running

        storage.set_trial_param(t0, "x", 0.0, FloatDistribution(-10, 10))
        storage.set_trial_state_values(t0, TrialState.COMPLETE, [0.0])
        store.refresh()
        assert list(store.numbers) == [0, 1]
        assert np.allclose(store.column("x"), [0.0, 1.0])

    def test_conditional_params_are_nan(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        _finish(storage, sid, {"x": 1.0, "cond": 5.0}, value=1.0)
        _finish(storage, sid, {"x": 2.0}, value=2.0)
        store = ObservationStore(storage, sid)
        store.refresh()
        cond = store.column("cond")
        assert np.isnan(cond[1]) and cond[0] == 5.0

    def test_failed_and_pruned_rows_kept_with_state(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        _finish(storage, sid, {"x": 1.0}, value=1.0)
        _finish(storage, sid, {"x": 2.0}, state=TrialState.FAIL)
        tid = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(tid, 0, 7.5)
        storage.set_trial_state_values(tid, TrialState.PRUNED)
        store = ObservationStore(storage, sid)
        store.refresh()
        assert list(store.states) == [
            int(TrialState.COMPLETE), int(TrialState.FAIL), int(TrialState.PRUNED),
        ]
        assert np.isnan(store.values[1]) and np.isnan(store.values[2])
        assert store.last_intermediate_values[2] == 7.5

    def test_model_space_encoding_log(self):
        from repro.core.distributions import FloatDistribution

        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        tid = storage.create_new_trial(sid)
        storage.set_trial_param(tid, "lr", 1e-3, FloatDistribution(1e-6, 1.0, log=True))
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [0.0])
        store = ObservationStore(storage, sid)
        store.refresh()
        assert np.isclose(store.column("lr")[0], np.log(1e-3))

    def test_design_matrix(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        _finish(storage, sid, {"a": 1.0, "b": 2.0}, value=3.0)
        _finish(storage, sid, {"a": 4.0}, value=5.0)  # missing b -> excluded
        _finish(storage, sid, {"a": 6.0, "b": 7.0}, state=TrialState.FAIL)
        store = ObservationStore(storage, sid)
        store.refresh()
        X, y = store.design_matrix(["a", "b"])
        assert X.shape == (1, 2)
        assert list(X[0]) == [1.0, 2.0] and list(y) == [3.0]
        X2, y2 = store.design_matrix(["a", "never_seen"])
        assert X2.shape == (0, 2) and len(y2) == 0

    def test_views_are_read_only(self):
        storage = hpo.InMemoryStorage()
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        _finish(storage, sid, {"x": 1.0}, value=1.0)
        store = ObservationStore(storage, sid)
        store.refresh()
        with pytest.raises(ValueError):
            store.values[0] = 99.0

    def test_study_observations_composes_with_cached_storage(self):
        backend = hpo.InMemoryStorage()
        storage = hpo.CachedStorage(backend)
        study = hpo.create_study(storage=storage, sampler=hpo.RandomSampler(seed=0))
        study.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=6)
        store = study.observations()
        assert store.n_observations == 6
        assert store.column("x") is not None


class TestRevisionCounter:
    def _check(self, storage):
        sid = storage.create_new_study([hpo.StudyDirection.MINIMIZE], "rev-study")
        r0 = storage.get_trials_revision(sid)
        tid = storage.create_new_trial(sid)
        r1 = storage.get_trials_revision(sid)
        assert r1 > r0
        from repro.core.distributions import FloatDistribution

        storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        r2 = storage.get_trials_revision(sid)
        assert r2 > r1
        # in-place update to a RUNNING trial is visible (the ROADMAP gap a
        # number-based since= poll could not see)
        storage.set_trial_intermediate_value(tid, 0, 1.0)
        r3 = storage.get_trials_revision(sid)
        assert r3 > r2
        storage.set_trial_system_attr(tid, "k", "v")
        r4 = storage.get_trials_revision(sid)
        assert r4 > r3
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
        assert storage.get_trials_revision(sid) > r4

    def test_inmemory(self):
        self._check(hpo.InMemoryStorage())

    def test_sqlite(self, tmp_sqlite):
        self._check(hpo.get_storage(tmp_sqlite))

    def test_journal(self, tmp_journal):
        self._check(hpo.get_storage(tmp_journal))

    def test_remote(self):
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            remote = hpo.RemoteStorage(server.url)
            self._check(remote)
            remote.close()

    def test_cached_refresh_skips_fetch_when_unchanged(self):
        class CountingStorage(hpo.InMemoryStorage):
            def __init__(self):
                super().__init__()
                self.full_reads = 0

            def get_all_trials(self, *a, **k):
                self.full_reads += 1
                return super().get_all_trials(*a, **k)

        backend = CountingStorage()
        cached = hpo.CachedStorage(backend)
        sid = cached.create_new_study([hpo.StudyDirection.MINIMIZE], "s")
        _finish(backend, sid, {"x": 1.0}, value=1.0)
        cached.get_all_trials(sid)
        reads = backend.full_reads
        for _ in range(5):  # nothing changed -> revision short-circuits
            cached.get_all_trials(sid)
        assert backend.full_reads == reads
        _finish(backend, sid, {"x": 2.0}, value=2.0)
        cached.get_all_trials(sid)
        assert backend.full_reads > reads
        assert len(cached.get_all_trials(sid)) == 2


class TestBatchedAskTell:
    def test_ask_n_returns_n_trials(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        trials = study.ask(4)
        assert len(trials) == 4
        assert len({t._trial_id for t in trials}) == 4
        assert study.ask(0) == []
        with pytest.raises(ValueError):
            study.ask(-1)

    def test_ask_n_claims_enqueued_first(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        study.enqueue_trial({"x": 0.25})
        trials = study.ask(3)
        assert len(trials) == 3
        fixed = [
            t for t in trials
            if t.study._storage.get_trial(t._trial_id).system_attrs.get("fixed_params")
        ]
        assert len(fixed) == 1

    def test_tell_batch(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        trials = study.ask(3)
        for t in trials:
            t.suggest_float("x", 0, 1)
        study.tell_batch([(trials[0], 1.0), (trials[1], 2.0),
                          (trials[2], None, TrialState.FAIL)])
        states = [t.state for t in study.trials]
        assert states == [TrialState.COMPLETE, TrialState.COMPLETE, TrialState.FAIL]
        assert study.best_value == 1.0

    def test_tell_batch_feeds_observation_store(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
        trials = study.ask(2)
        for i, t in enumerate(trials):
            t.suggest_float("x", 0, 1)
        study.tell_batch([(trials[0], 5.0), (trials[1], 6.0)])
        assert study.observations().n_observations == 2

    def test_optimize_ask_batch(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=1))
        study.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=7, ask_batch=3)
        assert len(study.trials) == 7
        assert all(t.state == TrialState.COMPLETE for t in study.trials)

    def test_optimize_ask_batch_releases_unconsumed_on_stop(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=1))

        def objective(trial):
            trial.suggest_float("x", 0, 1)
            if trial.number == 0:
                study.stop()
            return 0.0

        study.optimize(objective, n_trials=9, ask_batch=3)
        states = [t.state for t in study.trials]
        assert TrialState.COMPLETE in states
        # batch-asked but unevaluated trials must not linger RUNNING; they go
        # back to WAITING so a later ask can claim them
        assert TrialState.RUNNING not in states
        assert TrialState.WAITING in states

    def test_ask_batch_release_preserves_enqueued_configs(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=1))
        study.enqueue_trial({"x": 0.123})
        study.enqueue_trial({"x": 0.456})

        def stop_immediately(trial):
            trial.suggest_float("x", 0, 1)
            study.stop()
            return 0.0

        # batch claims both enqueued configs; only the first runs
        study.optimize(stop_immediately, n_trials=4, ask_batch=4)
        # the unevaluated warm-start config survives and runs on resume
        study.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=1)
        done = [t.params["x"] for t in study.trials if t.state == TrialState.COMPLETE]
        assert sorted(done) == [0.123, 0.456]

    def test_optimize_ask_batch_threaded(self):
        study = hpo.create_study(sampler=hpo.RandomSampler(seed=2))
        study.optimize(
            lambda t: t.suggest_float("x", 0, 1), n_trials=8, n_jobs=2, ask_batch=3
        )
        done = [t for t in study.trials if t.state == TrialState.COMPLETE]
        assert len(done) == 8
        assert all(t.state != TrialState.RUNNING for t in study.trials)

    def test_worker_main_ask_batch(self, tmp_path):
        url = f"sqlite:///{tmp_path}/s.db"
        study = hpo.create_study(study_name="batched", storage=url)
        from repro.core.distributed import worker_main

        worker_main(
            url, "batched", lambda t: t.suggest_float("x", 0, 1) ** 2,
            n_trials=6, seed_offset=0, heartbeat_interval=None, ask_batch=3,
        )
        study2 = hpo.load_study("batched", url)
        done = [t for t in study2.trials if t.state == TrialState.COMPLETE]
        assert len(done) == 6


class TestMakeSamplerGrid:
    def test_grid_registered(self):
        sampler = hpo.make_sampler("grid", seed=0, search_space={"a": [1, 2], "b": [0.5, 1.5]})
        assert isinstance(sampler, hpo.GridSampler)
        study = hpo.create_study(sampler=sampler)

        def objective(trial):
            return trial.suggest_int("a", 1, 2) * trial.suggest_float("b", 0.5, 1.5)

        study.optimize(objective, n_trials=4)
        seen = {(t.params["a"], t.params["b"]) for t in study.trials}
        assert len(seen) == 4  # all cells covered exactly once

    def test_grid_without_space_raises(self):
        with pytest.raises(ValueError, match="search_space"):
            hpo.make_sampler("grid")


class TestIntermediateValueStore:
    """The pruner-side columnar backbone: the (n_trials, n_steps) matrix,
    step side table, best-so-far caches, and the revision gate."""

    def _store(self, study):
        from repro.core.records import IntermediateValueStore

        return IntermediateValueStore(study._storage, study._study_id)

    def test_matrix_rows_are_trial_numbers_and_steps_sorted(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        t0 = storage.create_new_trial(sid)
        t1 = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(t1, 8, 1.5)   # sparse rungs,
        storage.set_trial_intermediate_value(t0, 2, 0.5)   # out of order
        storage.set_trial_intermediate_value(t1, 2, 2.5)
        store = self._store(study)
        store.refresh()
        assert store.steps.tolist() == [2, 8]
        m = store.matrix
        assert m.shape == (2, 2)
        assert m[0, 0] == 0.5 and np.isnan(m[0, 1])
        assert m[1, 0] == 2.5 and m[1, 1] == 1.5
        assert store.step_index(8) == 1 and store.step_index(3) is None
        assert store.index_upto(7) == 0 and store.index_upto(1) == -1

    def test_running_rows_are_rewritten_on_refresh(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        tid = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(tid, 1, 3.0)
        store = self._store(study)
        store.refresh()
        assert store.matrix[0, 0] == 3.0
        storage.set_trial_intermediate_value(tid, 2, 1.0)  # live trial grows
        store.refresh()
        assert store.matrix[0].tolist() == [3.0, 1.0]
        assert store.states[0] == int(TrialState.RUNNING)

    def test_best_so_far_ignores_nan_and_caches(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        tid = storage.create_new_trial(sid)
        for step, v in ((1, 5.0), (2, float("nan")), (3, 2.0), (4, 4.0)):
            storage.set_trial_intermediate_value(tid, step, v)
        store = self._store(study)
        store.refresh()
        lo = store.best_so_far(minimize=True)
        hi = store.best_so_far(minimize=False)
        assert lo[0].tolist() == [5.0, 5.0, 2.0, 2.0]
        assert hi[0].tolist() == [5.0, 5.0, 5.0, 5.0]
        assert store.best_so_far(minimize=True) is lo  # cached until refresh
        storage.set_trial_intermediate_value(tid, 5, 1.0)
        store.refresh()
        assert store.best_so_far(minimize=True) is not lo  # invalidated

    def test_revision_gate_skips_refetch(self):
        calls = {"n": 0}
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        tid = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(tid, 1, 1.0)
        store = self._store(study)
        orig = storage.get_all_trials

        def counting(*a, **kw):
            calls["n"] += 1
            return orig(*a, **kw)

        storage.get_all_trials = counting
        store.refresh()
        assert calls["n"] == 1
        store.refresh()  # unchanged revision -> no trial fetch
        store.refresh()
        assert calls["n"] == 1
        storage.set_trial_intermediate_value(tid, 2, 2.0)
        store.refresh()
        assert calls["n"] == 2

    def test_watermark_skips_finished_prefix(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        for v in (1.0, 2.0):
            tid = storage.create_new_trial(sid)
            storage.set_trial_intermediate_value(tid, 1, v)
            storage.set_trial_state_values(tid, TrialState.COMPLETE, [v])
        store = self._store(study)
        store.refresh()
        live = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(live, 1, 9.0)
        fetched = []
        orig = storage.get_all_trials

        def spy(study_id, deepcopy=True, states=None, since=None):
            fetched.append(since)
            return orig(study_id, deepcopy=deepcopy, states=states, since=since)

        storage.get_all_trials = spy
        store.refresh()
        assert fetched == [2]  # only the unfinished suffix is re-read
        assert store.matrix[2, 0] == 9.0

    def test_study_accessor_and_version(self):
        study = hpo.create_study(pruner=hpo.MedianPruner())
        store = study.intermediate_values()
        assert store is study.intermediate_values()  # one instance per study
        v0 = store.version
        t = study.ask()
        t.report(1.0, 1)
        study.intermediate_values()
        assert store.version > v0


class TestVectorizedIntersectionSpace:
    def test_matches_scalar_function(self):
        from repro.core.distributions import FloatDistribution, IntDistribution
        from repro.core.search_space import (
            IntersectionSearchSpace,
            intersection_search_space,
        )

        study = hpo.create_study()
        storage, sid = study._storage, study._study_id

        def add(params, state=TrialState.COMPLETE):
            tid = storage.create_new_trial(sid)
            for name, (v, dist) in params.items():
                storage.set_trial_param(tid, name, v, dist)
            storage.set_trial_state_values(
                tid, state, [0.0] if state == TrialState.COMPLETE else None
            )

        f, i = FloatDistribution(-1, 1), IntDistribution(1, 10)
        add({"a": (0.5, f), "b": (3.0, i)})
        add({"a": (0.2, f), "b": (4.0, i), "c": (0.1, f)})     # c not in trial 1
        add({"a": (0.9, f), "b": (0.3, FloatDistribution(0, 1))},  # b type flips
            state=TrialState.PRUNED)

        trials = study.get_trials(deepcopy=False)
        for include_pruned in (False, True):
            want = intersection_search_space(trials, include_pruned=include_pruned)
            got = IntersectionSearchSpace(include_pruned).calculate(study)
            assert got == want
        # COMPLETE-only keeps a and b; with the PRUNED type-flip only a survives
        assert set(IntersectionSearchSpace(False).calculate(study)) == {"a", "b"}
        assert set(IntersectionSearchSpace(True).calculate(study)) == {"a"}

    def test_latest_distribution_wins(self):
        from repro.core.distributions import FloatDistribution
        from repro.core.search_space import IntersectionSearchSpace

        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        for low, high in ((-1.0, 1.0), (-2.0, 2.0)):
            tid = storage.create_new_trial(sid)
            storage.set_trial_param(tid, "x", 0.0, FloatDistribution(low, high))
            storage.set_trial_state_values(tid, TrialState.COMPLETE, [0.0])
        space = IntersectionSearchSpace().calculate(study)
        assert space["x"].low == -2.0 and space["x"].high == 2.0


class TestIVStoreDirtySet:
    """Hosted intermediate-value stores re-encode only changed rows: each
    report dirties exactly one trial, so a refresh is O(changed trials)
    instead of O(RUNNING rows past the watermark)."""

    def test_reencode_count_is_linear_in_reports(self):
        study = hpo.create_study(pruner=hpo.MedianPruner(n_startup_trials=1))
        n_trials, n_steps = 12, 6
        trials = [study.ask() for _ in range(n_trials)]
        for step in range(n_steps):
            for t in trials:
                t.report(float(t.number + step), step)
                t.should_prune()
        store = study._storage._iv_stores[study._study_id]
        reports = n_trials * n_steps
        # one re-encode per report (+ the first-refresh ingest of each row);
        # the pre-dirty-set behavior was ~O(n_trials) per report (~864 here)
        assert store.reencode_count <= reports + 2 * n_trials, store.reencode_count
        # decisions saw every row: the matrix really holds all reports
        with store.lock():
            assert store.n_rows == n_trials
            assert np.isfinite(store.matrix).sum() == reports
        study.tell_batch([(t, 1.0) for t in trials])

    def test_dirty_refresh_still_sees_foreign_report_counts(self):
        """A writer bypassing note_dirty (same backend, raw storage call) is
        still picked up: the row's report count changed."""
        study = hpo.create_study(pruner=hpo.MedianPruner(n_startup_trials=1))
        a, b = study.ask(), study.ask()
        a.report(1.0, 1)
        a.should_prune()
        store = study._storage._iv_stores[study._study_id]
        # simulate a writer the notes cannot see (another process against
        # the same backing store): suppress the dirty note for this write
        study._storage._note_iv_dirty = lambda tid, sid=None: None
        study._storage.set_trial_intermediate_value(b._trial_id, 1, 99.0)
        store.refresh()
        with store.lock():
            col = store.step_column(1)
            assert 99.0 in col

    def test_skipped_rows_keep_values_intact(self):
        study = hpo.create_study(pruner=hpo.MedianPruner(n_startup_trials=1))
        trials = [study.ask() for _ in range(5)]
        for t in trials:
            t.report(float(t.number), 0)
            t.should_prune()
        store = study._storage._iv_stores[study._study_id]
        before = store.matrix.copy()
        # one more report on a single trial: only that row re-encodes
        count0 = store.reencode_count
        trials[2].report(42.0, 1)
        trials[2].should_prune()
        assert store.reencode_count - count0 <= 2
        after = store.matrix
        assert np.array_equal(before[:, 0], after[:, 0], equal_nan=True)
        assert after[trials[2].number, 1] == 42.0


class TestVectorIntermediateValues:
    """The (n_trials, n_steps, n_objectives) widening: vector reports ride
    the ``iv_vec:<step>`` system attr, scalar studies stay byte-identical on
    the wire, and ``objective_matrix`` exposes per-objective slices."""

    def _store(self, study):
        from repro.core.records import IntermediateValueStore

        return IntermediateValueStore(study._storage, study._study_id)

    def test_scalar_study_unchanged(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        tid = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(tid, 0, 1.0)
        storage.set_trial_intermediate_value(tid, 1, 2.0)
        store = self._store(study)
        store.refresh()
        assert store.n_objectives == 1
        assert store.iv_arity.tolist() == [0]
        np.testing.assert_array_equal(store.objective_matrix(0), store.matrix)
        assert np.isnan(store.objective_matrix(1)).all()

    def test_scalar_study_block_has_no_vec_columns(self):
        from repro.core.storage.serde import build_iv_block

        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        tid = storage.create_new_trial(sid)
        storage.set_trial_intermediate_value(tid, 0, 1.0)
        block = build_iv_block(storage.get_all_trials(sid, deepcopy=False))
        assert not any(k.startswith("vec_") for k in block)

    def test_vector_reports_fill_tensor(self):
        study = hpo.create_study()
        storage, sid = study._storage, study._study_id
        t0 = storage.create_new_trial(sid)
        t1 = storage.create_new_trial(sid)
        storage.set_trial_intermediate_vector(t0, 0, [1.0, 10.0])
        storage.set_trial_intermediate_vector(t0, 1, [2.0, 20.0])
        storage.set_trial_intermediate_value(t1, 0, 5.0)  # scalar row mixes in
        store = self._store(study)
        store.refresh()
        assert store.n_objectives == 2
        assert store.iv_arity.tolist() == [2, 0]
        # scalar (pruner-facing) matrix carries objective 0
        assert store.matrix[0].tolist() == [1.0, 2.0]
        assert store.objective_matrix(0)[0].tolist() == [1.0, 2.0]
        assert store.objective_matrix(1)[0].tolist() == [10.0, 20.0]
        # the scalar-only row has objective 0 from the matrix, NaN above
        assert store.objective_matrix(0)[1, 0] == 5.0
        assert np.isnan(store.objective_matrix(1)[1]).all()

    def test_trial_report_vector_with_nop_pruner(self):
        study = hpo.create_study(
            directions=["minimize", "maximize"], pruner=hpo.NopPruner()
        )
        t = study.ask()
        t.suggest_float("x", 0, 1)
        for step in range(3):
            t.report([float(step), 100.0 - step], step)
        study.tell(t, [0.0, 100.0])
        m0 = study.intermediate_values(objective=0)
        m1 = study.intermediate_values(objective=1)
        assert m0[0].tolist() == [0.0, 1.0, 2.0]
        assert m1[0].tolist() == [100.0, 99.0, 98.0]
        frozen = study.get_trials(deepcopy=False)[0]
        assert frozen.intermediate_value_vectors == {
            0: [0.0, 100.0], 1: [1.0, 99.0], 2: [2.0, 98.0]
        }

    def test_vector_round_trip_over_the_wire(self):
        with hpo.StorageServer(hpo.InMemoryStorage()) as server:

            def run(storage):
                study = hpo.create_study(
                    study_name="vec",
                    storage=storage,
                    directions=["minimize", "minimize"],
                    pruner=hpo.NopPruner(),
                    sampler=hpo.RandomSampler(seed=0),
                )
                for _ in range(4):
                    t = study.ask()
                    x = t.suggest_float("x", 0, 1)
                    for step in range(3):
                        t.report([x + step, x - step], step)
                    study.tell(t, [x, -x])
                store = self._store(study)
                store.refresh()
                return store

            remote = run(hpo.RemoteStorage(server.url))
            local = run(hpo.InMemoryStorage())
            assert remote.n_objectives == local.n_objectives == 2
            np.testing.assert_array_equal(remote.iv_arity, local.iv_arity)
            for k in range(2):
                np.testing.assert_array_equal(
                    remote.objective_matrix(k), local.objective_matrix(k)
                )
