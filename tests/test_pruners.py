"""Pruner semantics — including exact Algorithm 1 behaviour from the paper."""

import math

import pytest

import repro.core as hpo
from repro.core.frozen import FrozenTrial, TrialState


def _study_with(pruner=None, direction="minimize"):
    return hpo.create_study(
        sampler=hpo.RandomSampler(seed=0), pruner=pruner, direction=direction
    )


def _add_trial(study, ivs, state=TrialState.COMPLETE, value=None):
    tid = study._storage.create_new_trial(study._study_id)
    for s, v in ivs.items():
        study._storage.set_trial_intermediate_value(tid, s, v)
    if state.is_finished():
        study._storage.set_trial_state_values(
            tid, state, [value if value is not None else list(ivs.values())[-1]]
        )
    return tid


class TestSuccessiveHalving:
    """Pins down paper Algorithm 1 (r=min_resource, eta, s)."""

    def test_only_acts_at_rung_boundaries(self):
        # r=1, eta=2, s=0: rungs at steps 1,2,4,8,...
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0))
        _add_trial(study, {1: 0.0, 2: 0.0, 3: 0.0, 4: 0.0})
        t = study.ask()
        t.report(1.0, 3)  # step 3 is not a rung boundary -> never prune
        assert not t.should_prune()
        t.report(1.0, 4)  # rung boundary, worse than peer -> prune
        assert t.should_prune()

    def test_min_resource_gates_first_rung(self):
        study = _study_with(hpo.SuccessiveHalvingPruner(min_resource=4, reduction_factor=2))
        _add_trial(study, {4: 0.0})
        t = study.ask()
        t.report(5.0, 1)
        assert not t.should_prune()  # below min resource
        t.report(5.0, 4)
        assert t.should_prune()

    def test_top_1_over_eta_survives(self):
        # eta=4: with 8 peers at a rung, top-2 survive
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 4, 0))
        for v in range(8):
            _add_trial(study, {1: float(v)})
        good = study.ask()
        good.report(0.5, 1)  # rank 1 of 9
        assert not good.should_prune()
        bad = study.ask()
        bad.report(7.5, 1)  # rank last
        assert bad.should_prune()

    def test_single_trial_promoted_when_fewer_than_eta(self):
        # lines 8-10: top_k empty -> best single trial survives
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 4, 0))
        t = study.ask()
        t.report(123.0, 1)
        assert not t.should_prune()  # alone at the rung: promoted

    def test_min_early_stopping_rate_delays_pruning(self):
        # s=2, r=1, eta=2: first rung at step r*eta^s = 4
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 2))
        _add_trial(study, {1: 0.0, 2: 0.0, 4: 0.0})
        t = study.ask()
        t.report(9.0, 1)
        assert not t.should_prune()
        t.report(9.0, 2)
        assert not t.should_prune()
        t.report(9.0, 4)
        assert t.should_prune()

    def test_maximize_direction(self):
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0), direction="maximize")
        for v in range(4):
            _add_trial(study, {1: float(v)})
        t = study.ask()
        t.report(5.0, 1)  # best
        assert not t.should_prune()
        t2 = study.ask()
        t2.report(-1.0, 1)  # worst
        assert t2.should_prune()

    def test_nan_is_pruned(self):
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0))
        _add_trial(study, {1: 0.0})
        t = study.ask()
        t.report(float("nan"), 1)
        assert t.should_prune()

    def test_asynchronous_no_waiting(self):
        """ASHA property: decision uses whatever peers exist *now* — a lone
        leader is promoted immediately even though future trials might beat it
        (no rung barrier; paper §3.2)."""
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0))
        t = study.ask()
        for step in (1, 2, 4, 8):
            t.report(1.0, step)
            assert not t.should_prune()  # never blocks, never killed while best


class TestMedianPruner:
    def test_median_prunes_below_median(self):
        study = _study_with(hpo.MedianPruner(n_startup_trials=2, n_warmup_steps=0))
        for v in (1.0, 2.0, 3.0):
            _add_trial(study, {0: v, 1: v})
        t = study.ask()
        t.report(10.0, 1)
        assert t.should_prune()
        t2 = study.ask()
        t2.report(0.5, 1)
        assert not t2.should_prune()

    def test_startup_trials_protect(self):
        study = _study_with(hpo.MedianPruner(n_startup_trials=5))
        _add_trial(study, {0: 0.0})
        t = study.ask()
        t.report(99.0, 0)
        assert not t.should_prune()  # only 1 completed peer < 5

    def test_warmup_steps(self):
        study = _study_with(hpo.MedianPruner(n_startup_trials=1, n_warmup_steps=5))
        for v in (0.0, 0.1):
            _add_trial(study, {6: v})
        t = study.ask()
        t.report(9.0, 3)
        assert not t.should_prune()
        t.report(9.0, 6)
        assert t.should_prune()


class TestOtherPruners:
    def test_nop(self):
        study = _study_with(hpo.NopPruner())
        t = study.ask()
        t.report(1e9, 1)
        assert not t.should_prune()

    def test_threshold(self):
        study = _study_with(hpo.ThresholdPruner(upper=10.0))
        t = study.ask()
        t.report(5.0, 1)
        assert not t.should_prune()
        t.report(50.0, 2)
        assert t.should_prune()
        t2 = study.ask()
        t2.report(float("inf"), 1)
        assert t2.should_prune()

    def test_patient_wrapper(self):
        study = _study_with(hpo.PatientPruner(None, patience=2))
        t = study.ask()
        t.report(5.0, 0)
        t.report(4.0, 1)
        t.report(3.0, 2)
        assert not t.should_prune()  # improving
        t.report(3.0, 3)
        t.report(3.1, 4)
        t.report(3.2, 5)
        assert t.should_prune()  # no improvement for `patience` reports

    def test_hyperband_brackets_deterministic(self):
        pruner = hpo.HyperbandPruner(min_resource=1, max_resource=16, reduction_factor=2)
        assert pruner.n_brackets >= 3
        t = FrozenTrial(number=7, state=TrialState.RUNNING)
        assert pruner.bracket_of(t) == pruner.bracket_of(t)

    def test_hyperband_prunes_within_bracket(self):
        pruner = hpo.HyperbandPruner(min_resource=1, max_resource=8, reduction_factor=2)
        study = _study_with(pruner)
        # fill every bracket with good peers at every rung
        for _ in range(40):
            _add_trial(study, {1: 0.0, 2: 0.0, 4: 0.0, 8: 0.0})
        t = study.ask()
        pruned = False
        for step in (1, 2, 4, 8):  # a bad trial must die at its bracket's first rung
            t.report(9.0, step)
            if t.should_prune():
                pruned = True
                break
        assert pruned


class TestPeerSetSemantics:
    """Pins the documented (Optuna-matching) peer-visibility split:
    percentile/median rank against COMPLETE peers only, while ASHA — being
    asynchronous by design — also ranks against RUNNING (and PRUNED) peers."""

    def test_percentile_ignores_running_and_pruned_peers(self):
        study = _study_with(hpo.MedianPruner(n_startup_trials=1))
        # two terrible COMPLETE peers set the median; excellent RUNNING and
        # PRUNED peers must not drag the cutoff down
        for v in (100.0, 100.0):
            _add_trial(study, {1: v})
        for _ in range(8):
            _add_trial(study, {1: 0.0}, state=TrialState.RUNNING)
        for _ in range(8):
            _add_trial(study, {1: 0.0}, state=TrialState.PRUNED, value=0.0)
        t = study.ask()
        t.report(50.0, 1)  # far better than every COMPLETE peer
        assert not t.should_prune()

    def test_asha_sees_running_peers(self):
        study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0))
        # only RUNNING peers exist at the rung — ASHA must rank against them
        for _ in range(8):
            _add_trial(study, {1: 0.0}, state=TrialState.RUNNING)
        t = study.ask()
        t.report(9.0, 1)  # worst of 9 at rung 0, eta=2 -> pruned
        assert t.should_prune()


def test_pruned_trials_recorded_with_state():
    study = _study_with(hpo.SuccessiveHalvingPruner(1, 2, 0))

    def obj(trial):
        x = trial.suggest_float("x", 0, 1)
        for step in range(1, 17):
            trial.report(x + step * 0.01, step)
            if trial.should_prune():
                raise hpo.TrialPruned()
        return x

    study.optimize(obj, n_trials=30)
    states = [t.state for t in study.trials]
    assert states.count(TrialState.PRUNED) > 5
    assert states.count(TrialState.COMPLETE) >= 1
    # pruned trials keep their last intermediate value as final value
    pruned = [t for t in study.trials if t.state == TrialState.PRUNED]
    assert all(t.values is not None for t in pruned)
