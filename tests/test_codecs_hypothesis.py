"""Hypothesis property tests for the vectorized model-space codecs.

Complements ``test_codecs.py`` (which always runs); this module is skipped
when hypothesis is not installed, mirroring ``test_distributions.py``."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.distributions import (
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)


@settings(deadline=None, max_examples=50)
@given(
    low=st.floats(-1e6, 1e6, allow_nan=False),
    width=st.floats(1e-6, 1e6, allow_nan=False),
    data=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=16),
)
def test_float_roundtrip(low, width, data):
    d = FloatDistribution(low, low + width)
    xs = np.asarray([low + u * width for u in data])
    back = d.from_internal(d.to_internal(xs))
    assert np.all(back >= d.low) and np.all(back <= d.high)
    assert np.allclose(back, xs, rtol=1e-12, atol=1e-9)


@settings(deadline=None, max_examples=50)
@given(
    low=st.floats(1e-8, 1e3),
    mult=st.floats(1.5, 1e3),
    data=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=16),
)
def test_float_log_roundtrip(low, mult, data):
    d = FloatDistribution(low, low * mult, log=True)
    xs = np.exp(np.log(low) + np.asarray(data) * np.log(mult))
    back = d.from_internal(d.to_internal(xs))
    assert np.all(back >= d.low) and np.all(back <= d.high)
    assert np.allclose(back, xs, rtol=1e-9)


@settings(deadline=None, max_examples=50)
@given(
    low=st.integers(-1000, 1000),
    width=st.integers(0, 1000),
    step=st.integers(1, 7),
    data=st.lists(st.integers(0, 10**6), min_size=1, max_size=16),
)
def test_int_roundtrip(low, width, step, data):
    d = IntDistribution(low, low + width, step=step)
    n_cells = (d.high - d.low) // d.step + 1
    xs = [d.low + (v % n_cells) * d.step for v in data]
    back = d.from_internal(d.to_internal(xs))
    assert list(back.astype(int)) == xs


@settings(deadline=None, max_examples=50)
@given(
    st.lists(
        st.one_of(st.integers(), st.text(max_size=6), st.booleans(), st.none()),
        min_size=1, max_size=8, unique_by=lambda x: (type(x).__name__, x),
    ),
    st.lists(st.integers(0, 10**6), min_size=1, max_size=16),
)
def test_categorical_roundtrip(choices, picks, ):
    d = CategoricalDistribution(choices)
    xs = [choices[p % len(choices)] for p in picks]
    back = [d.to_external_repr(v) for v in d.from_internal(d.to_internal(xs))]
    assert all(type(a) is type(b) and a == b for a, b in zip(xs, back))
