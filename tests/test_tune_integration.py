"""End-to-end HPO-over-training integration (the paper's full loop):
define-by-run model/optimizer spaces, ASHA pruning of real JAX train runs,
dashboard artifact, deploy-best-with-FixedTrial."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core.frozen import TrialState
from repro.tune import LMTuneSpec, make_lm_objective
from repro.tune.objective import suggest_model_config, suggest_train_config

SPEC = LMTuneSpec(
    vocab=64, seq=32, batch=4, total_steps=12, eval_every=3,
    max_layers=2, max_width=64,
)


def test_define_by_run_space_is_conditional():
    """Different families produce different parameter sets (paper Fig. 3)."""
    seen_params = {}
    study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
    for _ in range(12):
        t = study.ask()
        cfg = suggest_model_config(t, SPEC)
        seen_params[cfg.name] = set(t.params)
        study.tell(t, 0.0)
    families = {t.params["family"] for t in study.trials}
    assert len(families) >= 2
    # moe trials carry expert params, dense trials don't
    moe_sets = [v for k, v in seen_params.items() if "moe" in k]
    dense_sets = [v for k, v in seen_params.items() if "dense" in k]
    if moe_sets and dense_sets:
        assert any("n_experts" in s for s in moe_sets)
        assert all("n_experts" not in s for s in dense_sets)


def test_full_study_with_pruning_and_deploy(tmp_path):
    study = hpo.create_study(
        sampler=hpo.TPESampler(seed=0, n_startup_trials=3),
        pruner=hpo.SuccessiveHalvingPruner(min_resource=3, reduction_factor=2),
    )
    objective = make_lm_objective(SPEC)
    study.optimize(objective, n_trials=8, catch=(Exception,))

    states = [t.state for t in study.trials]
    assert states.count(TrialState.COMPLETE) >= 1
    assert np.isfinite(study.best_value)

    # every completed trial reported intermediate values at eval steps
    for t in study.trials:
        if t.state == TrialState.COMPLETE:
            assert len(t.intermediate_values) >= 2

    # deploy: re-run the best config through the SAME objective via FixedTrial
    best = study.best_trial
    value = objective(hpo.FixedTrial(best.params))
    assert np.isfinite(value)

    # dashboard renders with learning curves
    html = hpo.render_dashboard(study)
    assert "Learning curves" in html
    (tmp_path / "dash.html").write_text(html)


def test_train_config_space(tmp_path):
    study = hpo.create_study(sampler=hpo.RandomSampler(seed=1))
    t = study.ask()
    tcfg = suggest_train_config(t, SPEC)
    assert 1e-4 <= tcfg.lr <= 1e-1
    assert 0 <= tcfg.warmup_steps <= 20
    assert tcfg.total_steps == SPEC.total_steps
