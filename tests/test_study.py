"""Study API: optimize loop, ask/tell, distributed workers, fault tolerance,
dashboard, importances."""

import math
import os

import numpy as np
import pytest

import repro.core as hpo
from repro.core.frozen import TrialState


def test_optimize_minimize_and_best():
    s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
    s.optimize(lambda t: (t.suggest_float("x", -5, 5) - 1) ** 2, n_trials=50)
    assert s.best_value < 1.0
    assert abs(s.best_params["x"] - 1.0) < 1.5
    assert s.best_trial.state == TrialState.COMPLETE


def test_optimize_maximize():
    s = hpo.create_study(direction="maximize", sampler=hpo.RandomSampler(seed=0))
    s.optimize(lambda t: -(t.suggest_float("x", -5, 5) ** 2), n_trials=30)
    assert s.best_value > -1.5


def test_failed_trials_recorded_and_raised():
    s = hpo.create_study()

    def obj(trial):
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        s.optimize(obj, n_trials=1)
    assert s.trials[0].state == TrialState.FAIL

    # catch= suppresses
    s.optimize(obj, n_trials=2, catch=(RuntimeError,))
    assert len(s.trials) == 3


def test_nan_objective_fails_trial():
    s = hpo.create_study()
    s.optimize(lambda t: float("nan"), n_trials=1, catch=(Exception,))
    assert s.trials[0].state == TrialState.FAIL


def test_ask_tell():
    s = hpo.create_study(sampler=hpo.TPESampler(seed=0))
    for _ in range(10):
        t = s.ask()
        x = t.suggest_float("x", 0, 1)
        s.tell(t, x * x)
    assert len(s.trials) == 10
    assert s.best_value >= 0


def test_tell_pruned_and_fail_states():
    s = hpo.create_study()
    t = s.ask()
    t.report(1.0, 0)
    s.tell(t, state=TrialState.PRUNED)
    assert s.trials[0].state == TrialState.PRUNED
    t2 = s.ask()
    s.tell(t2, state=TrialState.FAIL)
    assert s.trials[1].state == TrialState.FAIL


def test_n_jobs_threaded():
    s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))
    s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=24, n_jobs=4)
    assert len(s.trials) == 24
    assert sorted(t.number for t in s.trials) == list(range(24))


def test_timeout_stops_loop():
    import time

    s = hpo.create_study()

    def slow(trial):
        time.sleep(0.02)
        return 1.0

    s.optimize(slow, timeout=0.2)
    assert 1 <= len(s.trials) <= 30


def test_stop_from_callback():
    s = hpo.create_study()

    def cb(study, trial):
        if trial.number >= 4:
            study.stop()

    s.optimize(lambda t: 0.0, n_trials=100, callbacks=[cb])
    assert len(s.trials) <= 6


def test_multiobjective_pareto():
    s = hpo.create_study(directions=["minimize", "minimize"])

    def obj(t):
        x = t.suggest_float("x", 0, 1)
        return x, 1 - x

    s.optimize(obj, n_trials=20)
    front = s.best_trials
    assert len(front) == 20  # all on the Pareto front of (x, 1-x)


def test_trials_dataframe_export():
    s = hpo.create_study()
    s.optimize(lambda t: t.suggest_float("x", 0, 1), n_trials=5)
    rows = s.trials_dataframe()
    assert len(rows) == 5
    assert {"number", "state", "value", "params_x"} <= set(rows[0])


def test_study_user_attrs_and_system_attrs(tmp_sqlite):
    s = hpo.create_study(study_name="attrs", storage=tmp_sqlite)
    s.set_user_attr("dataset", "svhn")
    s.set_system_attr("version", 2)
    s2 = hpo.load_study("attrs", tmp_sqlite)
    assert s2.user_attrs["dataset"] == "svhn"
    assert s2.system_attrs["version"] == 2


def test_distributed_processes_sqlite(tmp_path):
    url = f"sqlite:///{tmp_path}/dist.db"
    hpo.create_study(study_name="dist", storage=url)

    dur = hpo.run_workers(
        3, url, "dist", _sphere, n_trials_per_worker=8,
    )
    s = hpo.load_study("dist", url)
    assert len(s.trials) == 24
    assert sorted(t.number for t in s.trials) == list(range(24))
    assert s.best_value < 10.0


def test_distributed_processes_journal(tmp_path):
    url = f"journal://{tmp_path}/dist.journal"
    hpo.create_study(study_name="dist", storage=url)
    hpo.run_workers(3, url, "dist", _sphere, n_trials_per_worker=6)
    s = hpo.load_study("dist", url)
    assert len(s.trials) == 18
    assert sorted(t.number for t in s.trials) == list(range(18))


def _sphere(trial):
    return sum(trial.suggest_float(f"x{i}", -3, 3) ** 2 for i in range(3))


def test_retry_failed_trial_callback():
    s = hpo.create_study()
    cb = hpo.RetryFailedTrialCallback(max_retry=1)

    calls = {"n": 0}

    def flaky(trial):
        trial.suggest_float("x", 0, 1)
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("node died")
        return 1.0

    s.optimize(flaky, n_trials=2, catch=(RuntimeError,), callbacks=[cb])
    states = [t.state for t in s.trials]
    assert TrialState.FAIL in states
    assert TrialState.COMPLETE in states
    retried = [t for t in s.trials if t.user_attrs.get("retry_of") is not None]
    assert retried, "failed trial must be re-enqueued"


def test_importances_and_dashboard(tmp_path):
    s = hpo.create_study(sampler=hpo.RandomSampler(seed=0))

    def obj(t):
        x = t.suggest_float("important", 0, 1)
        y = t.suggest_float("noise", 0, 1)
        return 10 * x + 0.01 * y

    s.optimize(obj, n_trials=60)
    imps = hpo.param_importances(s)
    assert imps["important"] > imps["noise"]
    sp = hpo.spearman_importances(s)
    assert sp["important"] > sp["noise"]

    html = hpo.render_dashboard(s)
    assert "<svg" in html and "important" in html
    out = hpo.save_dashboard(s, str(tmp_path / "dash.html"))
    assert os.path.getsize(out) > 1000


def test_heartbeat_failover_via_study():
    st = hpo.InMemoryStorage()
    s = hpo.create_study(study_name="hb", storage=st)
    s.failed_trial_grace = 0.01
    tid = st.create_new_trial(s._study_id)
    st.record_heartbeat(tid)
    import time

    time.sleep(0.05)
    assert s.fail_stale_trials() == [tid]
    assert st.get_trial(tid).state == TrialState.FAIL
    # retry re-enqueues the params of failed trials
    n = s.retry_failed_trials()
    assert n == 1
    waiting = s.get_trials(states=(TrialState.WAITING,))
    assert len(waiting) == 1
