"""Storage backends: API contract, concurrency, crash recovery."""

import os
import threading

import pytest

import repro.core as hpo
from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState
from repro.core.storage import InMemoryStorage, JournalStorage, SQLiteStorage, get_storage

BACKENDS = ["memory", "sqlite", "journal"]


def make_storage(kind, tmp_path):
    if kind == "memory":
        return InMemoryStorage()
    if kind == "sqlite":
        return SQLiteStorage(str(tmp_path / f"s.db"))
    return JournalStorage(str(tmp_path / "s.journal"))


@pytest.mark.parametrize("kind", BACKENDS)
class TestContract:
    def test_study_lifecycle(self, kind, tmp_path):
        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s1")
        assert st.get_study_id_from_name("s1") == sid
        assert st.get_study_name_from_id(sid) == "s1"
        assert st.get_study_directions(sid) == [StudyDirection.MINIMIZE]
        with pytest.raises(hpo.DuplicatedStudyError):
            st.create_new_study([StudyDirection.MINIMIZE], "s1")
        st.set_study_user_attr(sid, "k", {"nested": [1, 2]})
        assert st.get_study_user_attrs(sid)["k"] == {"nested": [1, 2]}
        st.delete_study(sid)
        with pytest.raises(KeyError):
            st.get_study_id_from_name("s1")

    def test_trial_lifecycle(self, kind, tmp_path):
        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = st.create_new_trial(sid)
        st.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        st.set_trial_intermediate_value(tid, 1, 10.0)
        st.set_trial_intermediate_value(tid, 1, 9.0)  # overwrite
        st.set_trial_user_attr(tid, "note", "hi")
        assert st.set_trial_state_values(tid, TrialState.COMPLETE, [1.5])
        t = st.get_trial(tid)
        assert t.params["x"] == 0.5
        assert t.intermediate_values == {1: 9.0}
        assert t.user_attrs["note"] == "hi"
        assert t.values == [1.5]
        assert t.state == TrialState.COMPLETE
        assert t.datetime_complete is not None
        # finished trials reject writes
        with pytest.raises(RuntimeError):
            st.set_trial_param(tid, "y", 0.1, FloatDistribution(0, 1))
        with pytest.raises(RuntimeError):
            st.set_trial_intermediate_value(tid, 2, 0.0)

    def test_trial_numbers_dense(self, kind, tmp_path):
        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        tids = [st.create_new_trial(sid) for _ in range(10)]
        numbers = [st.get_trial(t).number for t in tids]
        assert numbers == list(range(10))

    def test_waiting_claim_race(self, kind, tmp_path):
        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        from repro.core.frozen import FrozenTrial

        tid = st.create_new_trial(
            sid, template_trial=FrozenTrial(number=-1, state=TrialState.WAITING)
        )
        assert st.set_trial_state_values(tid, TrialState.RUNNING)
        assert not st.set_trial_state_values(tid, TrialState.RUNNING)  # second claim loses

    def test_threaded_writers(self, kind, tmp_path):
        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        errs = []

        def worker(i):
            try:
                for _ in range(10):
                    tid = st.create_new_trial(sid)
                    st.set_trial_param(tid, "x", 0.1, FloatDistribution(0, 1))
                    st.set_trial_state_values(tid, TrialState.COMPLETE, [float(i)])
            except Exception as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        trials = st.get_all_trials(sid)
        assert len(trials) == 40
        assert sorted(t.number for t in trials) == list(range(40))


class TestJournalSpecifics:
    def test_two_handles_share_state(self, tmp_path):
        path = str(tmp_path / "j.journal")
        a = JournalStorage(path)
        b = JournalStorage(path)
        sid = a.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = a.create_new_trial(sid)
        a.set_trial_state_values(tid, TrialState.COMPLETE, [3.0])
        # b sees a's writes after sync
        assert b.get_trial(tid).values == [3.0]
        # and b can extend
        tid2 = b.create_new_trial(sid)
        assert a.get_trial(tid2).number == 1

    def test_torn_tail_line_ignored(self, tmp_path):
        path = str(tmp_path / "j.journal")
        a = JournalStorage(path)
        sid = a.create_new_study([StudyDirection.MINIMIZE], "s")
        a.create_new_trial(sid)
        with open(path, "a") as f:
            f.write('{"op": "create_trial", "trial_id": 99')  # torn write, no newline
        b = JournalStorage(path)
        assert len(b.get_all_trials(sid)) == 1  # torn line invisible

    def test_replay_after_restart(self, tmp_path):
        path = str(tmp_path / "j.journal")
        a = JournalStorage(path)
        sid = a.create_new_study([StudyDirection.MAXIMIZE], "s")
        for i in range(5):
            tid = a.create_new_trial(sid)
            a.set_trial_state_values(tid, TrialState.COMPLETE, [float(i)])
        del a
        b = JournalStorage(path)
        sid2 = b.get_study_id_from_name("s")
        assert sid2 == sid
        assert len(b.get_all_trials(sid)) == 5


class TestHeartbeat:
    @pytest.mark.parametrize("kind", BACKENDS)
    def test_stale_detection_and_failover(self, kind, tmp_path):
        import time

        st = make_storage(kind, tmp_path)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = st.create_new_trial(sid)
        st.record_heartbeat(tid)
        time.sleep(0.03)
        assert st.get_stale_trial_ids(sid, grace_seconds=0.01) == [tid]
        assert st.get_stale_trial_ids(sid, grace_seconds=60.0) == []
        failed = st.fail_stale_trials(sid, grace_seconds=0.01)
        assert failed == [tid]
        assert st.get_trial(tid).state == TrialState.FAIL


def test_get_storage_url_routing(tmp_path):
    assert isinstance(get_storage(None), InMemoryStorage)
    assert isinstance(get_storage(f"sqlite:///{tmp_path}/a.db"), SQLiteStorage)
    assert isinstance(get_storage(f"journal://{tmp_path}/a.journal"), JournalStorage)
    assert isinstance(get_storage(str(tmp_path / "b.db")), SQLiteStorage)
    with pytest.raises(ValueError):
        get_storage("mysterious://x")
