"""Chaos harness + fault-tolerance tests: injector primitives, replication,
promotion, client failover, stale-trial reclamation, journal crash recovery,
cached-flush outage survival — and the acceptance storm: a seeded 100-worker
run that loses a shard primary mid-flight and must converge bit-identical to
an uninterrupted run with zero lost tells."""

import os
import threading
import time

import pytest

from repro.core import telemetry
from repro.core.distributions import FloatDistribution
from repro.core.exceptions import RetryableStorageError, StorageUnavailableError
from repro.core.frozen import StudyDirection, TrialState
from repro.core.storage import (
    CachedStorage,
    InMemoryStorage,
    JournalStorage,
    RemoteStorage,
    StorageServer,
)
from repro.core.storage.chaos import ChaosCluster, FaultInjector

MIN = StudyDirection.MINIMIZE

# a pruner spec that never prunes — exercises the fused path under chaos
NOP_SPEC = {"name": "median", "n_startup_trials": 10_000}


class TestFaultInjector:
    def test_counted_rules_fire_in_order_then_disarm(self):
        fi = FaultInjector(seed=0)
        fi.drop_next_frames(1).blackhole_next(1).delay_next(1, 0.5)
        assert fi.on_frame() == "drop_conn"
        assert fi.on_frame() == "blackhole"
        assert fi.on_frame() == ("delay", 0.5)
        assert fi.on_frame() is None
        assert not fi.armed

    def test_accept_rule(self):
        fi = FaultInjector(seed=0)
        fi.drop_connects(2)
        assert fi.on_accept() and fi.on_accept() and not fi.on_accept()
        assert fi.stats["dropped_connects"] == 2

    def test_random_drop_is_deterministic_under_seed(self):
        a, b = FaultInjector(seed=123).random_drop(0.3), FaultInjector(seed=123).random_drop(0.3)
        seq_a = [a.on_frame() for _ in range(50)]
        seq_b = [b.on_frame() for _ in range(50)]
        assert seq_a == seq_b
        assert "drop_conn" in seq_a and None in seq_a

    def test_clear_disarms_everything(self):
        fi = FaultInjector(seed=0)
        fi.drop_next_frames(5).blackhole_next(5).delay_next(5).drop_connects(5)
        fi.random_drop(1.0)
        fi.clear()
        assert not fi.armed
        assert fi.on_frame() is None and not fi.on_accept()

    def test_counted_rules_take_precedence_over_random(self):
        fi = FaultInjector(seed=0)
        fi.random_drop(1.0)
        fi.blackhole_next(1)
        assert fi.on_frame() == "blackhole"
        assert fi.on_frame() == "drop_conn"  # random takes over after


class TestInjectedFaults:
    def test_blackhole_executes_once_via_dedup(self):
        """The nastiest failure: a tell that executed but whose response was
        swallowed.  The retransmitted frame carries the same op id, so the
        server answers from its dedup window — exactly one execution."""
        fi = FaultInjector(seed=0)
        with StorageServer(InMemoryStorage(), journal=True, fault_injector=fi) as srv:
            st = RemoteStorage(srv.url, timeout=1.0, retries=10, rpc_deadline=15.0)
            sid = st.create_new_study([MIN], "bh")
            tid = st.create_new_trial(sid)
            fi.blackhole_next(1)
            assert st.set_trial_state_values(tid, TrialState.COMPLETE, [1.0]) is True
            trials = srv.storage.get_all_trials(0)
            assert len(trials) == 1 and trials[0].state == TrialState.COMPLETE
            assert srv.get_server_metrics()["dedup_hits"] >= 1
            # the journal recorded the op exactly once
            ops = [m for _, _, m, _ in srv.journal.since(0)]
            assert ops.count("set_trial_state_values") == 1

    def test_drop_conn_loses_request_before_execution(self):
        fi = FaultInjector(seed=0)
        with StorageServer(InMemoryStorage(), fault_injector=fi) as srv:
            st = RemoteStorage(srv.url, timeout=2.0, retries=10, rpc_deadline=15.0)
            sid = st.create_new_study([MIN], "dc")
            fi.drop_next_frames(1)
            assert st.get_n_trials(sid) == 0  # idempotent read retried
            assert fi.stats["dropped_frames"] == 1

    def test_dropped_connects_then_recover(self):
        fi = FaultInjector(seed=0)
        with StorageServer(InMemoryStorage(), fault_injector=fi) as srv:
            st = RemoteStorage(srv.url, timeout=2.0, retries=10, rpc_deadline=15.0)
            st.close()  # force the next call to dial fresh
            fi.drop_connects(2)
            assert st._call("ping") == "pong"
            assert fi.stats["dropped_connects"] == 2

    def test_delay_holds_response(self):
        fi = FaultInjector(seed=0)
        with StorageServer(InMemoryStorage(), fault_injector=fi) as srv:
            st = RemoteStorage(srv.url, timeout=5.0)
            sid = st.create_new_study([MIN], "dl")
            fi.delay_next(1, 0.3)
            t0 = time.monotonic()
            st.get_n_trials(sid)
            assert time.monotonic() - t0 >= 0.25


class TestReplication:
    def test_replica_tails_and_matches_ids(self):
        with StorageServer(InMemoryStorage(), journal=True) as prim:
            rep = StorageServer(InMemoryStorage(), replicate_from=prim.url).start()
            try:
                st = RemoteStorage(prim.url)
                sid = st.create_new_study([MIN], "rep")
                tids = st.create_new_trials(sid, 3)
                st.set_trial_param(tids[0], "x", 0.5, FloatDistribution(0, 1))
                st.set_trial_state_values(tids[0], TrialState.COMPLETE, [1.0])
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline:
                    if rep.replication_state()["applied_seq"] >= prim.replication_state()["seq"]:
                        break
                    time.sleep(0.01)
                mirror = rep.storage.get_all_trials(0)
                original = prim.storage.get_all_trials(0)
                assert [t.trial_id for t in mirror] == [t.trial_id for t in original]
                assert [t.state for t in mirror] == [t.state for t in original]
                assert mirror[0].params == original[0].params
            finally:
                rep.stop()

    def test_replica_refuses_writes_until_promoted(self):
        with StorageServer(InMemoryStorage(), journal=True) as prim:
            rep = StorageServer(InMemoryStorage(), replicate_from=prim.url).start()
            try:
                RemoteStorage(prim.url).create_new_study([MIN], "ro")
                # explicit single-node URL to the replica: reads fine
                direct = RemoteStorage(
                    rep.url, retries=2, rpc_deadline=5.0, timeout=2.0
                )
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline and not direct.get_all_studies():
                    time.sleep(0.01)
                assert [s.study_name for s in direct.get_all_studies()] == ["ro"]
                with pytest.raises(StorageUnavailableError):
                    direct.create_new_study([MIN], "nope")
                rep.promote()
                assert rep.role == "primary" and rep.epoch == 2
                direct2 = RemoteStorage(rep.url)
                assert direct2.create_new_study([MIN], "yes") >= 0
            finally:
                rep.stop()

    def test_promote_is_idempotent(self):
        with StorageServer(InMemoryStorage(), journal=True) as prim:
            rep = StorageServer(InMemoryStorage(), replicate_from=prim.url).start()
            try:
                rep.promote()
                e1 = rep.epoch
                rep.promote()
                assert rep.epoch == e1  # second promote is a no-op
            finally:
                rep.stop()

    def test_client_fails_over_to_promoted_replica(self):
        cc = ChaosCluster(n_shards=1, replicated=(0,), seed=1)
        try:
            st = cc.storage(timeout=2.0, retries=40, rpc_deadline=30.0, backoff_seed=3)
            sid = st.create_new_study([MIN], "fo")
            tid = st.create_new_trial(sid)
            cc.wait_replicated(0)
            cc.kill_primary(0)
            cc.promote_replica(0)
            assert st.set_trial_state_values(tid, TrialState.COMPLETE, [4.0])
            trials = st.get_all_trials(sid)
            assert len(trials) == 1 and trials[0].values == [4.0]
        finally:
            cc.stop()

    def test_fenced_old_primary_is_refused(self):
        cc = ChaosCluster(n_shards=1, replicated=(0,), seed=1)
        try:
            st = cc.storage(timeout=2.0, retries=40, rpc_deadline=30.0, backoff_seed=3)
            sid = st.create_new_study([MIN], "fence")
            cc.wait_replicated(0)
            cc.kill_primary(0)
            cc.promote_replica(0)
            assert st.get_n_trials(sid) == 0  # failed over
            # the dead primary restarts with its stale epoch: cluster-aware
            # clients must keep talking to the promoted replica
            cc.restart_primary(0)
            tid = st.create_new_trial(sid)
            assert st.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
            # the write landed on the promoted node, not the stale restart
            assert len(cc.replicas[0].storage.get_all_trials(0)) == 1
            assert len(cc.primaries[0].storage.get_all_trials(0)) == 0
        finally:
            cc.stop()


class TestReclaim:
    def test_server_sweep_fails_stale_running_trials(self):
        with StorageServer(
            InMemoryStorage(), reclaim_grace=0.2, reclaim_interval=0.05
        ) as srv:
            st = RemoteStorage(srv.url)
            sid = st.create_new_study([MIN], "sweep")
            tid = st.create_new_trial(sid)  # RUNNING
            st.record_heartbeat(tid)
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                if st.get_trial(tid).state == TrialState.FAIL:
                    break
                time.sleep(0.05)
            assert st.get_trial(tid).state == TrialState.FAIL
            assert srv.get_server_metrics()["reclaimed_trials"] >= 1

    def test_server_sweep_requeues_behind_flag(self):
        with StorageServer(
            InMemoryStorage(), reclaim_grace=0.2, reclaim_requeue=True,
            reclaim_interval=0.05,
        ) as srv:
            st = RemoteStorage(srv.url)
            sid = st.create_new_study([MIN], "requeue")
            tid = st.create_new_trial(sid)
            st.record_heartbeat(tid)
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                if st.get_trial(tid).state == TrialState.WAITING:
                    break
                time.sleep(0.05)
            assert st.get_trial(tid).state == TrialState.WAITING
            # the requeued trial is claimable again (its heartbeat clock was
            # re-armed, so it is not instantly re-swept as stale)
            assert st.set_trial_state_values(tid, TrialState.RUNNING)

    def test_reclaim_ops_are_journaled_for_replicas(self):
        with StorageServer(
            InMemoryStorage(), journal=True, reclaim_grace=0.2, reclaim_interval=0.05
        ) as prim:
            rep = StorageServer(InMemoryStorage(), replicate_from=prim.url).start()
            try:
                st = RemoteStorage(prim.url)
                sid = st.create_new_study([MIN], "rj")
                tid = st.create_new_trial(sid)
                st.record_heartbeat(tid)
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline:
                    mirror = rep.storage.get_all_trials(0) if rep.storage.get_all_studies() else []
                    if mirror and mirror[0].state == TrialState.FAIL:
                        break
                    time.sleep(0.05)
                assert rep.storage.get_all_trials(0)[0].state == TrialState.FAIL
            finally:
                rep.stop()


class TestJournalCrashRecovery:
    def test_torn_tail_is_ignored_then_truncated_on_append(self, tmp_path):
        path = str(tmp_path / "study.journal")
        st = JournalStorage(path)
        sid = st.create_new_study([MIN], "crash")
        tid = st.create_new_trial(sid)
        # a worker dies mid-append: half a JSON line, no newline
        with open(path, "a") as f:
            f.write('{"op":"set_state","trial_id":0,"TORN')
        # readers never see the torn line
        st2 = JournalStorage(path)
        assert st2.get_trial(tid).state == TrialState.RUNNING
        # the next append repairs the tail (truncate + warn) before writing
        with pytest.warns(RuntimeWarning, match="torn final line"):
            st2.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
        with open(path, "rb") as f:
            data = f.read()
        assert data.endswith(b"\n") and b"TORN" not in data
        # a fresh replay sees a clean history
        st3 = JournalStorage(path)
        assert st3.get_trial(tid).state == TrialState.COMPLETE
        assert st3.get_trial(tid).values == [1.0]

    def test_corrupt_interior_line_warns_and_skips(self, tmp_path):
        path = str(tmp_path / "corrupt.journal")
        st = JournalStorage(path)
        st.create_new_study([MIN], "c0")
        with open(path, "a") as f:
            f.write("NOT JSON AT ALL\n")
        with pytest.warns(RuntimeWarning, match="corrupt line"):
            st.create_new_study([MIN], "c1")
        with pytest.warns(RuntimeWarning, match="corrupt line"):
            st2 = JournalStorage(path)
        assert {s.study_name for s in st2.get_all_studies()} == {"c0", "c1"}

    def test_fsync_flag_and_url_form(self, tmp_path):
        path = str(tmp_path / "nf.journal")
        st = JournalStorage(f"journal://{path}?fsync=0")
        assert st._fsync is False
        sid = st.create_new_study([MIN], "nf")
        assert JournalStorage(path).get_study_id_from_name("nf") == sid
        assert JournalStorage(path)._fsync is True  # default stays durable


class TestCachedFlushOutage:
    def test_buffered_ops_survive_a_server_bounce(self):
        srv = StorageServer(InMemoryStorage()).start()
        try:
            st = CachedStorage(
                RemoteStorage(srv.url, timeout=1.0, retries=2, rpc_deadline=3.0)
            )
            sid = st.create_new_study([MIN], "bounce")
            tid = st.create_new_trial(sid)  # RUNNING -> owned, writes buffer
            st.set_trial_user_attr(tid, "k1", "v1")
            st.set_trial_user_attr(tid, "k2", "v2")
            assert st.pending_ops == 2
            srv.kill()
            with pytest.raises(RetryableStorageError):
                st.flush()
            # nothing was dropped: the buffer survives the failed flush
            assert st.pending_ops == 2
            srv.restart()
            st.flush()
            assert st.pending_ops == 0
            attrs = srv.storage.get_trial(0).user_attrs
            assert attrs == {"k1": "v1", "k2": "v2"}
        finally:
            srv.stop()

    def test_close_during_outage_does_not_raise(self):
        srv = StorageServer(InMemoryStorage()).start()
        st = CachedStorage(
            RemoteStorage(srv.url, timeout=1.0, retries=1, rpc_deadline=2.0)
        )
        sid = st.create_new_study([MIN], "dead")
        tid = st.create_new_trial(sid)
        st.set_trial_user_attr(tid, "k", "v")
        srv.kill()
        st.close()  # buffered op is unflushable; close must still succeed


# -- the acceptance storm -----------------------------------------------------


def _chaos_worker(storage, sid, results, idx, per_worker):
    try:
        for k in range(per_worker):
            tid = storage.create_new_trial(sid)
            storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
            pruned = storage.report_and_prune(
                sid, tid, 0, float(idx), NOP_SPEC, MIN
            )
            assert pruned is False
            value = idx * 1000.0 + k  # deterministic, unique per (worker, k)
            assert storage.set_trial_state_values(tid, TrialState.COMPLETE, [value])
        results[idx] = None
    except Exception as e:  # pragma: no cover - surfaced by asserts below
        results[idx] = e


def _run_storm(kill_mid_run, n_workers=100, per_worker=2, seed=7):
    """Run the seeded storm on a 2-shard cluster (shard of study 'storm'
    replicated); optionally kill that shard's primary mid-run and promote.
    Returns (values multiset, states, best, events) read from the node that
    ends up serving the study."""
    # figure out which shard the storm study hashes to, then build the
    # cluster with the replica on that shard
    from repro.core.storage.cluster import HashRing

    storm_shard = HashRing(2).lookup("storm")
    cc = ChaosCluster(n_shards=2, replicated=(storm_shard,), seed=seed)
    try:
        st = cc.storage(
            timeout=2.0, retries=200, rpc_deadline=60.0, backoff_seed=seed
        )
        sid = st.create_new_study([MIN], "storm")
        assert sid % 2 == storm_shard

        killer = None
        if kill_mid_run:
            trigger_seq = (n_workers * per_worker * 3) // 4  # mid-storm

            def _killer():
                while cc.journal_seq(storm_shard) < trigger_seq:
                    time.sleep(0.005)
                cc.kill_primary(storm_shard)
                time.sleep(0.2)  # workers spin against a headless shard
                cc.promote_replica(storm_shard)

            killer = threading.Thread(target=_killer)
            killer.start()

        results = [RuntimeError("never ran")] * n_workers
        threads = [
            threading.Thread(
                target=_chaos_worker, args=(st, sid, results, i, per_worker)
            )
            for i in range(n_workers)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
        if killer is not None:
            killer.join(timeout=30)
        errors = [e for e in results if e is not None]
        assert not errors, errors[:3]

        # read the surviving node's backend directly (bit-exact, no client)
        node = cc.replicas[storm_shard] if kill_mid_run else cc.primaries[storm_shard]
        local_sid = sid // 2
        trials = node.storage.get_all_trials(local_sid)
        values = sorted(t.values[0] for t in trials)
        states = [t.state for t in trials]
        best = min(values)
        events = node.storage.get_trial_events(local_sid)
        return values, states, best, events
    finally:
        cc.stop()


class TestChaosStormAcceptance:
    @pytest.mark.slow
    def test_failover_storm_zero_lost_tells(self):
        n_workers, per_worker = 100, 2
        expected_values = sorted(
            float(i * 1000 + k) for i in range(n_workers) for k in range(per_worker)
        )

        chaos_values, chaos_states, chaos_best, chaos_events = _run_storm(
            kill_mid_run=True, n_workers=n_workers, per_worker=per_worker
        )
        calm_values, calm_states, calm_best, _ = _run_storm(
            kill_mid_run=False, n_workers=n_workers, per_worker=per_worker
        )

        # zero lost tells: every deterministic value is present exactly once
        assert chaos_values == expected_values
        # no double executions: trial count is exact, all COMPLETE
        assert len(chaos_states) == n_workers * per_worker
        assert all(s == TrialState.COMPLETE for s in chaos_states)
        # bit-identical to the uninterrupted run
        assert chaos_values == calm_values
        assert chaos_states == calm_states
        assert chaos_best == calm_best
        # exactly one COMPLETED lifecycle event per trial on the survivor
        completed = [
            n for kind, n in zip(chaos_events["kind"], chaos_events["number"])
            if kind == telemetry.EV_COMPLETED
        ]
        assert sorted(completed) == list(range(n_workers * per_worker))
