"""Networked storage service: server round-trips, claim races, cache
consistency, and an end-to-end served distributed study."""

import threading

import pytest

import repro.core as hpo
from repro.core.distributions import CategoricalDistribution, FloatDistribution, IntDistribution
from repro.core.frozen import FrozenTrial, StudyDirection, TrialState
from repro.core.storage import (
    CachedStorage,
    InMemoryStorage,
    RemoteStorage,
    SQLiteStorage,
    StorageServer,
    get_storage,
    get_trials_since,
)


# every test in this module runs against both wire protocols: v1 pins the
# server to legacy JSON frames (clients transparently fall back), v2
# negotiates the binary framing via hello
@pytest.fixture(params=[1, 2], ids=["v1", "v2"])
def server(request):
    srv = StorageServer(InMemoryStorage(), max_protocol=request.param).start()
    yield srv
    srv.stop()


@pytest.fixture
def remote(server):
    return RemoteStorage(server.url)


class TestProtocolRoundTrip:
    """Every BaseStorage method crosses the wire and comes back intact."""

    def test_study_methods(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE, StudyDirection.MAXIMIZE], "s1")
        assert remote.get_study_id_from_name("s1") == sid
        assert remote.get_study_name_from_id(sid) == "s1"
        assert remote.get_study_directions(sid) == [
            StudyDirection.MINIMIZE, StudyDirection.MAXIMIZE,
        ]
        remote.set_study_user_attr(sid, "u", {"nested": [1, "two"]})
        remote.set_study_system_attr(sid, "s", 3.5)
        assert remote.get_study_user_attrs(sid) == {"u": {"nested": [1, "two"]}}
        assert remote.get_study_system_attrs(sid) == {"s": 3.5}
        summaries = remote.get_all_studies()
        assert len(summaries) == 1 and summaries[0].study_name == "s1"
        with pytest.raises(hpo.DuplicatedStudyError):
            remote.create_new_study([StudyDirection.MINIMIZE], "s1")
        remote.delete_study(sid)
        with pytest.raises(KeyError):
            remote.get_study_id_from_name("s1")

    def test_trial_methods(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = remote.create_new_trial(sid)
        remote.set_trial_param(tid, "f", 0.25, FloatDistribution(0, 1, log=False))
        remote.set_trial_param(tid, "i", 3.0, IntDistribution(1, 10))
        remote.set_trial_param(tid, "c", 1.0, CategoricalDistribution([None, "b", 4]))
        remote.set_trial_intermediate_value(tid, 1, 5.0)
        remote.set_trial_intermediate_value(tid, 2, 4.0)
        remote.set_trial_user_attr(tid, "k", [1, 2])
        remote.set_trial_system_attr(tid, "sys", "v")
        assert remote.set_trial_state_values(tid, TrialState.COMPLETE, [0.5])
        t = remote.get_trial(tid)
        assert t.params == {"f": 0.25, "i": 3, "c": "b"}
        assert isinstance(t.distributions["c"], CategoricalDistribution)
        assert t.intermediate_values == {1: 5.0, 2: 4.0}
        assert t.user_attrs == {"k": [1, 2]}
        assert t.system_attrs == {"sys": "v"}
        assert t.values == [0.5] and t.state == TrialState.COMPLETE
        assert t.datetime_start is not None and t.datetime_complete is not None
        assert remote.get_trial_id_from_study_and_number(sid, t.number) == tid
        assert remote.get_n_trials(sid) == 1
        assert remote.get_n_trials(sid, states=(TrialState.FAIL,)) == 0
        # server-side errors surface as the right client-side exception types
        with pytest.raises(KeyError):
            remote.get_trial(tid + 999)
        with pytest.raises(RuntimeError):
            remote.set_trial_param(tid, "f", 0.1, FloatDistribution(0, 1))

    def test_template_trial_and_states_filter(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "s")
        template = FrozenTrial(
            number=-1, state=TrialState.WAITING, system_attrs={"fixed_params": {"x": 1.0}},
        )
        remote.create_new_trial(sid, template_trial=template)
        remote.create_new_trial(sid)  # RUNNING
        waiting = remote.get_all_trials(sid, states=(TrialState.WAITING,))
        assert len(waiting) == 1
        assert waiting[0].system_attrs["fixed_params"] == {"x": 1.0}

    def test_heartbeat_failover(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = remote.create_new_trial(sid)
        remote.record_heartbeat(tid)
        assert remote.get_stale_trial_ids(sid, grace_seconds=3600) == []
        assert remote.fail_stale_trials(sid, grace_seconds=-1) == [tid]
        assert remote.get_trial(tid).state == TrialState.FAIL

    def test_batched_requests(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = remote.create_new_trial(sid)
        results = remote.call_batch(
            [
                ("set_trial_param", (tid, "x", 0.5, FloatDistribution(0, 1))),
                ("set_trial_user_attr", (tid, "a", 1)),
                ("get_trial", (tid,)),
            ]
        )
        assert results[2].params == {"x": 0.5}
        assert results[2].user_attrs == {"a": 1}

    def test_reconnect_after_dropped_connection(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "s")
        # sever this thread's socket out from under the client
        remote._local.sock.close()
        remote._local.sock = None
        assert remote.get_study_id_from_name("s") == sid

    def test_bad_url_fails_fast(self):
        from repro.core.exceptions import RetryableStorageError

        with pytest.raises(RetryableStorageError):
            RemoteStorage("remote://127.0.0.1:1", retries=1)
        with pytest.raises(ValueError):
            RemoteStorage("remote://noport")


class TestClaimRace:
    def test_exactly_one_client_wins_waiting_claim(self, server):
        c1 = RemoteStorage(server.url)
        c2 = RemoteStorage(server.url)
        sid = c1.create_new_study([StudyDirection.MINIMIZE], "s")
        results = []
        for _ in range(10):
            tid = c1.create_new_trial(
                sid, template_trial=FrozenTrial(number=-1, state=TrialState.WAITING)
            )
            barrier = threading.Barrier(2)
            wins = []

            def claim(client):
                barrier.wait()
                wins.append(client.set_trial_state_values(tid, TrialState.RUNNING))

            ts = [threading.Thread(target=claim, args=(c,)) for c in (c1, c2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            results.append(sorted(wins))
        assert all(r == [False, True] for r in results), results

    def test_cached_clients_claim_through_backend(self, server):
        """The cache must not short-circuit the compare-and-set."""
        c1 = CachedStorage(RemoteStorage(server.url))
        c2 = CachedStorage(RemoteStorage(server.url))
        sid = c1.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = c1.create_new_trial(
            sid, template_trial=FrozenTrial(number=-1, state=TrialState.WAITING)
        )
        for c in (c1, c2):  # both observe the WAITING trial
            assert [t.trial_id for t in c.get_all_trials(sid, states=(TrialState.WAITING,))] == [tid]
        wins = [c.set_trial_state_values(tid, TrialState.RUNNING) for c in (c1, c2)]
        assert sorted(wins) == [False, True]


class TestSinceFetch:
    @pytest.mark.parametrize("kind", ["memory", "sqlite", "journal", "remote"])
    def test_since_matches_filtered_full_read(self, kind, tmp_path, server):
        if kind == "memory":
            st = InMemoryStorage()
        elif kind == "sqlite":
            st = SQLiteStorage(str(tmp_path / "s.db"))
        elif kind == "journal":
            st = hpo.JournalStorage(str(tmp_path / "s.journal"))
        else:
            st = RemoteStorage(server.url)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "s")
        for i in range(7):
            tid = st.create_new_trial(sid)
            if i < 4:
                st.set_trial_state_values(tid, TrialState.COMPLETE, [float(i)])
        full = st.get_all_trials(sid, deepcopy=False)
        suffix = st.get_all_trials(sid, deepcopy=False, since=4)
        assert [t.number for t in suffix] == [4, 5, 6]
        assert [t.number for t in full] == list(range(7))
        # helper falls back cleanly for backends without native support
        assert [t.number for t in get_trials_since(st, sid, 5, deepcopy=False)] == [5, 6]

    def test_cached_storage_stops_refetching_finished_trials(self, server):
        probe = RemoteStorage(server.url)
        cs = CachedStorage(RemoteStorage(server.url))
        sid = cs.create_new_study([StudyDirection.MINIMIZE], "s")
        for i in range(20):
            tid = cs.create_new_trial(sid)
            cs.set_trial_state_values(tid, TrialState.COMPLETE, [float(i)])
        cs.get_all_trials(sid, deepcopy=False)
        assert cs._studies[sid].watermark == 20  # nothing left to re-read
        # and the cache still sees new work from other clients
        other = probe.create_new_trial(sid)
        assert [t.trial_id for t in cs.get_all_trials(sid, states=(TrialState.RUNNING,))] == [other]


class TestCachedConsistency:
    def test_interleaved_writes_match_backend(self, server):
        """Writes through the proxy and direct backend writes interleave;
        the proxy's view must converge to the backend's."""
        backend = RemoteStorage(server.url)
        cs = CachedStorage(RemoteStorage(server.url))
        sid = cs.create_new_study([StudyDirection.MINIMIZE], "s")

        t_own = cs.create_new_trial(sid)  # owned by the proxy
        t_other = backend.create_new_trial(sid)  # some other worker's trial

        cs.set_trial_param(t_own, "x", 0.5, FloatDistribution(0, 1))  # buffered
        backend.set_trial_param(t_other, "x", 0.9, FloatDistribution(0, 1))
        cs.set_trial_intermediate_value(t_own, 1, 3.0)  # forces a flush
        backend.set_trial_state_values(t_other, TrialState.COMPLETE, [9.0])
        cs.set_trial_user_attr(t_own, "note", "mine")  # buffered again
        cs.set_trial_state_values(t_own, TrialState.COMPLETE, [1.0])  # flush + finish

        ours = {t.number: t for t in cs.get_all_trials(sid)}
        theirs = {t.number: t for t in backend.get_all_trials(sid)}
        assert ours.keys() == theirs.keys()
        for n in ours:
            a, b = ours[n], theirs[n]
            assert (a.state, a.values, a.params, a.intermediate_values, a.user_attrs) == (
                b.state, b.values, b.params, b.intermediate_values, b.user_attrs,
            )

    def test_explicit_flush_pushes_buffered_writes(self, server):
        backend = RemoteStorage(server.url)
        cs = CachedStorage(RemoteStorage(server.url))
        sid = cs.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = cs.create_new_trial(sid)
        cs.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        assert backend.get_trial(tid).params == {}  # write-behind: not yet visible
        cs.flush()
        assert backend.get_trial(tid).params == {"x": 0.5}

    def test_own_reads_never_hit_backend_midtrial(self, server):
        cs = CachedStorage(RemoteStorage(server.url))
        sid = cs.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = cs.create_new_trial(sid)
        cs.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
        t = cs.get_trial(tid)  # served from the local copy, incl. unflushed param
        assert t.params == {"x": 0.5}


class TestStudyOverRemote:
    def test_optimize_through_remote_url(self, server):
        study = hpo.create_study(study_name="remote-study", storage=get_storage(server.url))
        study.optimize(lambda tr: (tr.suggest_float("x", -5, 5) - 1) ** 2, n_trials=15)
        assert len(study.trials) == 15
        assert study.best_value is not None

    def test_optimize_through_cached_remote(self, server):
        storage = get_storage(server.url, cache=True)
        study = hpo.create_study(study_name="cached-study", storage=storage)
        study.optimize(lambda tr: (tr.suggest_float("x", -5, 5) - 1) ** 2, n_trials=15)
        trials = study.get_trials(states=(TrialState.COMPLETE,))
        assert len(trials) == 15
        # invariants also hold on the server's authoritative copy
        raw = RemoteStorage(server.url)
        sid = raw.get_study_id_from_name("cached-study")
        backend_trials = raw.get_all_trials(sid)
        assert [t.number for t in backend_trials] == list(range(15))
        assert all(t.state == TrialState.COMPLETE for t in backend_trials)
        assert all("x" in t.params for t in backend_trials)


def _served_objective(trial):
    x = trial.suggest_float("x", -5, 5)
    trial.report(x * x, 1)
    return (x - 1) ** 2


class TestServedDistributedStudy:
    def test_run_workers_serve_storage_end_to_end(self, tmp_path):
        """>= 2 worker processes through remote:// (server wrapping SQLite)
        keep the single-process storage invariants: dense trial numbers and
        exactly one claim per enqueued WAITING trial."""
        url = f"sqlite:///{tmp_path}/served.db"
        study = hpo.create_study(study_name="fleet", storage=url)
        study.enqueue_trial({"x": 1.0})
        study.enqueue_trial({"x": -1.0})
        hpo.run_workers(
            2, url, "fleet", _served_objective,
            n_trials_per_worker=5,
            sampler_factory=lambda: hpo.RandomSampler(),
            serve_storage=True,
        )
        trials = study.get_trials()
        assert len(trials) == 10
        assert [t.number for t in trials] == list(range(10))  # dense numbering
        finished = [t for t in trials if t.state == TrialState.COMPLETE]
        assert len(finished) == 10
        # the two enqueued WAITING trials were each claimed exactly once
        fixed = [t for t in trials if "fixed_params" in t.system_attrs]
        assert sorted(t.params["x"] for t in fixed) == [-1.0, 1.0]
        assert study.best_value == pytest.approx(0.0)


class TestAuthToken:
    """Shared-secret handshake on the remote protocol."""

    @pytest.fixture
    def auth_server(self):
        srv = StorageServer(InMemoryStorage(), auth_token="sekrit").start()
        yield srv
        srv.stop()

    def test_authenticated_client_works(self, auth_server):
        client = RemoteStorage(auth_server.url, auth_token="sekrit")
        sid = client.create_new_study([StudyDirection.MINIMIZE], "a")
        assert client.get_study_id_from_name("a") == sid
        client.close()

    def test_token_in_url(self, auth_server):
        url = f"remote://sekrit@{auth_server.host}:{auth_server.port}"
        client = get_storage(url)
        sid = client.create_new_study([StudyDirection.MINIMIZE], "u")
        assert client.get_study_name_from_id(sid) == "u"
        # the secret never leaks through the url property
        assert "sekrit" not in client.url
        client.close()

    def test_unauthenticated_client_rejected(self, auth_server):
        with pytest.raises(PermissionError):
            RemoteStorage(auth_server.url)

    def test_wrong_token_rejected(self, auth_server):
        with pytest.raises(PermissionError):
            RemoteStorage(auth_server.url, auth_token="wrong")

    def test_token_ignored_when_server_open(self, server):
        # an auth frame against an open server is accepted idempotently
        client = RemoteStorage(server.url, auth_token="whatever")
        client.create_new_study([StudyDirection.MINIMIZE], "open")
        client.close()

    def test_reconnect_reauthenticates(self, auth_server):
        client = RemoteStorage(auth_server.url, auth_token="sekrit")
        sid = client.create_new_study([StudyDirection.MINIMIZE], "r")
        client.close()  # drop this thread's socket; next call re-dials + re-auths
        assert client.get_study_id_from_name("r") == sid
        client.close()


class TestBatchedCreateOverRemote:
    def test_create_new_trials_single_round_trip(self, server, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "batch")
        tids = remote.create_new_trials(sid, 5)
        assert len(tids) == 5 and len(set(tids)) == 5
        assert remote.get_n_trials(sid) == 5

    def test_ask_n_over_cached_remote(self, remote):
        cached = CachedStorage(remote)
        study = hpo.create_study(
            study_name="askn", storage=cached, sampler=hpo.RandomSampler(seed=0)
        )
        trials = study.ask(4)
        assert len(trials) == 4
        for t in trials:
            t.suggest_float("x", 0, 1)
        study.tell_batch([(t, float(i)) for i, t in enumerate(trials)])
        assert study.observations().n_observations == 4

    def test_remote_revision_counter(self, remote):
        sid = remote.create_new_study([StudyDirection.MINIMIZE], "rev")
        r0 = remote.get_trials_revision(sid)
        remote.create_new_trial(sid)
        assert remote.get_trials_revision(sid) > r0


class TestFusedReportPrune:
    """The fused report_and_prune storage op: one wire frame per
    report+should_prune, with the prune decision computed server-side."""

    def _count_frames(self, remote):
        counter = {"n": 0}
        orig = remote._roundtrip

        def counting(request, payloads):
            counter["n"] += 1
            return orig(request, payloads)

        remote._roundtrip = counting
        return counter

    def test_report_plus_should_prune_is_one_round_trip(self, server):
        remote = RemoteStorage(server.url)
        counter = self._count_frames(remote)
        storage = CachedStorage(remote)
        study = hpo.create_study(
            study_name="fused", storage=storage,
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.MedianPruner(n_startup_trials=1),
        )
        # two finished peers so the pruner has a cutoff
        for v in (1.0, 2.0):
            t = study.ask()
            t.suggest_float("x", 0, 1)
            t.report(v, 1)
            study.tell(t, v)
        trial = study.ask()
        trial.suggest_float("x", 0, 1)
        counter["n"] = 0
        trial.report(100.0, 1)       # fused frame: write + decision
        assert trial.should_prune()  # answered from the cached decision
        assert counter["n"] == 1

    def test_fused_decision_uses_server_side_peers(self, server):
        """A second worker's reports are visible to the first worker's fused
        decision without any client-side peer fetch."""
        worker1 = hpo.create_study(
            study_name="peers", storage=RemoteStorage(server.url),
            pruner=hpo.SuccessiveHalvingPruner(1, 2, 0),
        )
        worker2 = hpo.Study(
            "peers", RemoteStorage(server.url),
            pruner=hpo.SuccessiveHalvingPruner(1, 2, 0),
        )
        peers = [worker2.ask() for _ in range(4)]
        for p in peers:
            p.report(0.0, 1)
        mine = worker1.ask()
        mine.report(9.0, 1)          # worst of 5 at the rung -> pruned
        assert mine.should_prune()
        best = worker1.ask()
        best.report(-1.0, 1)         # best of 6 -> promoted
        assert not best.should_prune()

    def test_fused_matches_unfused_decision(self, server):
        remote = RemoteStorage(server.url)
        study = hpo.create_study(
            study_name="match", storage=remote,
            pruner=hpo.MedianPruner(n_startup_trials=1),
        )
        for v in (1.0, 2.0, 3.0):
            t = study.ask()
            t.report(v, 1)
            study.tell(t, v)
        t = study.ask()
        t.report(10.0, 1)
        fused = t.should_prune()
        # client-side evaluation on the same history must agree
        frozen = remote.get_trial(t._trial_id)
        assert fused == study.pruner.prune(study, frozen) is True

    def test_nop_pruner_fuses_without_decision_cost(self, server):
        remote = RemoteStorage(server.url)
        counter = self._count_frames(remote)
        study = hpo.create_study(study_name="nop", storage=remote)
        trial = study.ask()
        counter["n"] = 0
        trial.report(1.0, 1)
        assert not trial.should_prune()
        assert counter["n"] == 1
        # the value still landed
        assert remote.get_trial(trial._trial_id).intermediate_values == {1: 1.0}

    def test_multi_objective_report_is_one_round_trip(self, server):
        """A multi-objective vector report through the Pareto-aware pruner
        rides the same fused frame: scalarize client-side, one
        report_and_prune RPC, decision cached for should_prune."""
        remote = RemoteStorage(server.url)
        counter = self._count_frames(remote)
        study = hpo.create_study(
            study_name="mo-fused", storage=remote,
            directions=["minimize", "maximize"],
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=1)),
        )
        for vals in ([1.0, 5.0], [2.0, 4.0]):
            t = study.ask()
            t.suggest_float("x", 0, 1)
            t.report(vals, 1)
            study.tell(t, vals)
        bad = study.ask()
        bad.suggest_float("x", 0, 1)
        counter["n"] = 0
        bad.report([100.0, -100.0], 1)   # fused frame: scalarized write + decision
        assert bad.should_prune()        # answered from the cached decision
        assert counter["n"] == 1
        good = study.ask()
        good.suggest_float("x", 0, 1)
        counter["n"] = 0
        good.report([0.0, 100.0], 1)
        assert not good.should_prune()
        assert counter["n"] == 1


class TestPrunerSpecCache:
    """The fused report's pruner spec is interned per (connection, study):
    full spec once (__spec_def__), then a short __spec_ref__ — shaving the
    spec bytes off every subsequent report frame."""

    def _record_frames(self, remote):
        frames = []
        orig = remote._roundtrip

        def recording(request, payloads):
            result = orig(request, payloads)
            # the encoded wire payload is cached per protocol by the call
            frames.append(max(payloads.values(), key=len) if payloads else b"")
            return result

        remote._roundtrip = recording
        return frames

    def _fused_study(self, server, name):
        remote = RemoteStorage(server.url)
        study = hpo.create_study(
            study_name=name, storage=remote,
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.MedianPruner(n_startup_trials=1),
        )
        for v in (1.0, 2.0):
            t = study.ask()
            t.report(v, 1)
            study.tell(t, v)
        return remote, study

    def test_second_report_frame_is_smaller(self, server):
        remote, study = self._fused_study(server, "bytes")
        trial = study.ask()
        remote.close()  # fresh connection: the seeding reports interned already
        frames = self._record_frames(remote)
        trial.report(5.0, 1)
        trial.report(5.0, 2)
        assert len(frames) == 2
        first, second = (len(f) for f in frames)
        # the ref frame drops the whole spec payload: it must be strictly
        # smaller, by at least the size of the serialized MedianPruner spec
        assert second < first - 20, (first, second)
        assert b"__spec_def__" in frames[0] and b"__spec_ref__" not in frames[0]
        assert b"__spec_ref__" in frames[1] and b"median" not in frames[1]

    def test_spec_sent_once_per_connection_and_study(self, server):
        remote, study = self._fused_study(server, "once")
        trials = [study.ask() for _ in range(3)]
        remote.close()  # fresh connection so the def frame is observable
        frames = self._record_frames(remote)
        for step in (1, 2, 3):
            for t in trials:
                t.report(float(step), step)
        defs = [f for f in frames if b"__spec_def__" in f]
        refs = [f for f in frames if b"__spec_ref__" in f]
        assert len(defs) == 1 and len(refs) == len(frames) - 1

    def test_decisions_identical_through_spec_cache(self, server):
        remote, study = self._fused_study(server, "same")
        bad = study.ask()
        bad.report(100.0, 1)   # def frame
        assert bad.should_prune()
        worse = study.ask()
        worse.report(200.0, 1)  # ref frame: same pruner, same peers
        assert worse.should_prune()
        good = study.ask()
        good.report(-1.0, 1)    # ref frame, best value -> promoted
        assert not good.should_prune()

    def test_reconnect_resends_spec_def(self, server):
        remote, study = self._fused_study(server, "reconnect")
        trial = study.ask()
        trial.report(1.5, 1)  # populate the per-connection cache
        remote.close()        # drop socket: both caches die with it
        frames = self._record_frames(remote)
        trial.report(1.5, 2)
        assert any(b"__spec_def__" in f for f in frames)
        assert remote.get_trial(trial._trial_id).intermediate_values[2] == 1.5

    def test_stale_ref_is_resent_as_def(self, server):
        """A ref whose server-side cache entry is gone (torn between encode
        and send) is retried once with the full spec."""
        remote, study = self._fused_study(server, "stale")
        trial = study.ask()
        trial.report(1.0, 1)
        # poison: pretend the spec is cached although this is a new socket
        remote.close()
        remote._local.spec_ids = {
            (study._study_id, '{"n_min_trials": 1, "n_startup_trials": 1, '
             '"n_warmup_steps": 0, "name": "median"}'): 7
        }
        trial.report(2.0, 2)  # ref -> server miss -> auto def resend
        assert remote.get_trial(trial._trial_id).intermediate_values[2] == 2.0
