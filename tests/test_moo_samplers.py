"""Multi-objective samplers + Pareto-aware pruning: NSGA-II block contract,
MOTPE split semantics, scalarized fused pruning, and the PR-4 follow-up
satellites (popsize-aware CMA waves, first-trial-number RNG keying)."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core import moo
from repro.core.frozen import StudyDirection, TrialState
from repro.core.samplers.tpe import _motpe_split
from repro.core.search_space import observed_groups


def zdt1(trial, d=8):
    x = [trial.suggest_float(f"x{i}", 0, 1) for i in range(d)]
    f1 = x[0]
    g = 1.0 + 9.0 * sum(x[1:]) / (d - 1)
    f2 = g * (1.0 - np.sqrt(f1 / g))
    return [f1, f2]


def final_hypervolume(study, ref=(1.1, 11.0)):
    V, _ = study.pareto_front()
    return moo.hypervolume(np.asarray(V), np.asarray(ref))


def run_sampler(sampler, n_trials=80, seed_obj=zdt1, ask_batch=1):
    study = hpo.create_study(directions=["minimize", "minimize"], sampler=sampler)
    study.optimize(seed_obj, n_trials=n_trials, ask_batch=ask_batch)
    return study


class TestNSGAII:
    def test_parameter_validation(self):
        with pytest.raises(ValueError):
            hpo.NSGAIISampler(population_size=1)
        with pytest.raises(ValueError):
            hpo.NSGAIISampler(crossover_prob=1.5)
        with pytest.raises(ValueError):
            hpo.NSGAIISampler(mutation_prob=-0.1)
        with pytest.raises(ValueError):
            hpo.NSGAIISampler(swapping_prob=2.0)

    def test_block_shape_and_bounds(self):
        sampler = hpo.NSGAIISampler(population_size=6, seed=0)
        study = run_sampler(sampler, n_trials=12, seed_obj=lambda t: zdt1(t, d=3))
        (group,) = observed_groups(study.observations())
        block = sampler.sample_joint(study, group, 9)
        assert block is not None and block.shape == (9, 3)
        assert np.isfinite(block).all()
        assert ((block >= 0.0) & (block <= 1.0)).all()

    def test_declines_before_population_seeded(self):
        sampler = hpo.NSGAIISampler(population_size=10, seed=0)
        study = run_sampler(sampler, n_trials=4, seed_obj=lambda t: zdt1(t, d=3))
        (group,) = observed_groups(study.observations())
        assert sampler.sample_joint(study, group, 3) is None

    def test_one_generation_per_wave(self):
        calls = []

        class Recording(hpo.NSGAIISampler):
            def sample_joint(self, study, group, n, trial_ids=None, first_number=None):
                calls.append(n)
                return super().sample_joint(
                    study, group, n, trial_ids=trial_ids, first_number=first_number
                )

        sampler = Recording(population_size=6, seed=0)
        study = run_sampler(sampler, n_trials=12, seed_obj=lambda t: zdt1(t, d=3))
        calls.clear()
        wave = study.ask(6)
        assert calls == [6]  # one block covers the whole generation
        study.tell_batch([(t, zdt1(t, d=3)) for t in wave])

    def test_wave_size_capped_at_population(self):
        sampler = hpo.NSGAIISampler(population_size=5, seed=0)
        study = hpo.create_study(directions=["minimize", "minimize"], sampler=sampler)
        assert sampler.joint_wave_size(study, 32) == 5
        assert sampler.joint_wave_size(study, 3) == 3

    def test_categorical_and_int_offspring_stay_in_domain(self):
        def obj(t):
            a = t.suggest_categorical("a", ["p", "q", "r"])
            b = t.suggest_int("b", 1, 5)
            x = t.suggest_float("x", 0, 1)
            return [x + b, (3 - b) ** 2 + (0 if a == "p" else 1) + (1 - x)]

        sampler = hpo.NSGAIISampler(population_size=6, seed=3)
        study = hpo.create_study(directions=["minimize", "minimize"], sampler=sampler)
        study.optimize(obj, n_trials=30)
        for t in study.get_trials(deepcopy=False, states=(TrialState.COMPLETE,)):
            assert t.params["a"] in ("p", "q", "r")
            assert 1 <= t.params["b"] <= 5
            assert 0.0 <= t.params["x"] <= 1.0

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_dominates_random_on_zdt1(self, seed):
        n = 80
        nsga = run_sampler(hpo.NSGAIISampler(population_size=10, seed=seed), n)
        rand = run_sampler(hpo.RandomSampler(seed=seed), n)
        assert final_hypervolume(nsga) > final_hypervolume(rand)


class TestMOTPE:
    def test_split_prefers_lower_ranks(self):
        # 3 clear fronts of 3 points each; n_below=3 must take front 0 whole
        L = np.asarray(
            [[0.0, 2.0], [1.0, 1.0], [2.0, 0.0],
             [2.0, 4.0], [3.0, 3.0], [4.0, 2.0],
             [4.0, 6.0], [5.0, 5.0], [6.0, 4.0]]
        )
        below, above, w = _motpe_split(L, 3)
        assert sorted(below.tolist()) == [0, 1, 2]
        assert len(above) == 6 and len(w) == 3
        assert (w > 0).all() and (w <= 1.0).all()

    def test_split_breaks_boundary_rank_by_hypervolume(self):
        # front 0 has 4 points but n_below=2: HSSP picks a max-volume subset
        L = np.asarray([[0.0, 3.0], [1.0, 1.0], [1.1, 0.9], [3.0, 0.0]])
        below, above, _ = _motpe_split(L, 2)
        assert len(below) == 2 and len(above) == 2
        assert set(below.tolist()) < {0, 1, 2, 3}

    def test_split_is_chronologically_sorted(self):
        rng = np.random.RandomState(0)
        L = rng.uniform(size=(20, 2))
        below, above, _ = _motpe_split(L, 5)
        assert np.array_equal(below, np.sort(below))
        assert np.array_equal(above, np.sort(above))
        assert len(np.intersect1d(below, above)) == 0
        assert len(below) + len(above) == 20

    def test_scalar_path_runs_and_improves_front(self):
        sampler = hpo.TPESampler(seed=1, n_startup_trials=10, multi_objective=True)
        study = run_sampler(sampler, n_trials=40, seed_obj=lambda t: zdt1(t, d=4))
        assert len(study.best_trials) >= 1

    def test_joint_waves_run(self):
        sampler = hpo.TPESampler(
            seed=1, n_startup_trials=10, multi_objective=True, multivariate=True
        )
        study = run_sampler(
            sampler, n_trials=40, seed_obj=lambda t: zdt1(t, d=4), ask_batch=8
        )
        assert len(study.best_trials) >= 1

    def test_without_flag_multi_objective_stays_uniform(self):
        # the historical fallback: no MOTPE machinery engaged, no crash
        sampler = hpo.TPESampler(seed=1)
        study = run_sampler(sampler, n_trials=15, seed_obj=lambda t: zdt1(t, d=3))
        assert len(study.trials) == 15
        assert sampler._mo_fit is None

    def test_consider_pruned_admits_full_vector_pruned_rows(self):
        sampler = hpo.TPESampler(
            seed=0, n_startup_trials=4, multi_objective=True,
            consider_pruned_trials=True,
        )
        study = hpo.create_study(
            directions=["minimize", "minimize"], sampler=sampler
        )
        for i in range(4):
            t = study.ask()
            t.suggest_float("x", 0, 1)
            study.tell(t, [float(i), float(4 - i)])
        # full-vector pruned rows count as evidence with the flag on;
        # a pruned trial without a full vector stays excluded
        t = study.ask()
        t.suggest_float("x", 0, 1)
        study.tell(t, [0.5, 0.5], state=TrialState.PRUNED)
        t = study.ask()
        t.suggest_float("x", 0, 1)
        study.tell(t, state=TrialState.PRUNED)
        fit = sampler._mo_trial_fit(study)
        assert len(fit.below_rows) + len(fit.above_rows) == 5
        sampler_off = hpo.TPESampler(
            seed=0, n_startup_trials=4, multi_objective=True
        )
        fit_off = sampler_off._mo_trial_fit(study)
        assert len(fit_off.below_rows) + len(fit_off.above_rows) == 4

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_dominates_random_on_zdt1(self, seed):
        n = 80
        motpe = run_sampler(
            hpo.TPESampler(seed=seed, n_startup_trials=16, multi_objective=True), n
        )
        rand = run_sampler(hpo.RandomSampler(seed=seed), n)
        assert final_hypervolume(motpe) > final_hypervolume(rand)


class TestParetoPruner:
    def _directions(self):
        return [StudyDirection.MINIMIZE, StudyDirection.MAXIMIZE]

    def test_scalarization_preserves_dominance(self):
        pruner = hpo.ParetoPruner(hpo.MedianPruner())
        dirs = self._directions()
        rng = np.random.RandomState(0)
        for _ in range(200):
            a = rng.uniform(-2, 2, size=2)
            b = a + rng.uniform(0, 1, size=2) * [1, -1]  # b worse in both
            if np.allclose(a, b):
                continue
            assert pruner.scalarize(a.tolist(), dirs) < pruner.scalarize(b.tolist(), dirs)

    def test_arity_mismatch_raises(self):
        pruner = hpo.ParetoPruner(hpo.MedianPruner())
        with pytest.raises(ValueError):
            pruner.scalarize([1.0], self._directions())

    def test_spec_round_trip(self):
        from repro.core.pruners import pruner_from_spec

        pruner = hpo.ParetoPruner(
            hpo.MedianPruner(n_startup_trials=2), reference_point=[0.0, 1.0], rho=0.1
        )
        spec = pruner.spec()
        rebuilt = pruner_from_spec(spec)
        assert isinstance(rebuilt, hpo.ParetoPruner)
        vals, dirs = [0.3, 0.7], self._directions()
        assert rebuilt.scalarize(vals, dirs) == pruner.scalarize(vals, dirs)

    def test_vector_report_without_scalarizer_raises(self):
        # a scalar pruner can't order vectors: must be rejected.  (NopPruner
        # studies accept vectors since the analytics-service PR — there is no
        # pruning stream to corrupt, and the IV store records per-objective
        # curves from them.)
        study = hpo.create_study(
            directions=["minimize", "minimize"],
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.MedianPruner(),
        )
        t = study.ask()
        with pytest.raises(ValueError):
            t.report([1.0, 2.0], 1)

    def test_scalar_report_with_scalarizer_raises_on_mo_study(self):
        # a raw scalar would enter the scalarized stream unoriented and
        # corrupt every peer's prune decision — must be rejected
        study = hpo.create_study(
            directions=["maximize", "maximize"],
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=1)),
        )
        t = study.ask()
        with pytest.raises(ValueError):
            t.report(0.9, 1)

    def test_fused_decision_on_scalarized_stream(self):
        study = hpo.create_study(
            directions=["minimize", "maximize"],
            sampler=hpo.RandomSampler(seed=0),
            pruner=hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=1)),
        )
        for vals in ([1.0, 5.0], [2.0, 4.0], [1.5, 4.5]):
            t = study.ask()
            t.suggest_float("x", 0, 1)
            t.report(vals, 1)
            study.tell(t, vals)
        bad = study.ask()
        bad.suggest_float("x", 0, 1)
        bad.report([50.0, -50.0], 1)  # dominated by everything
        assert bad.should_prune()
        good = study.ask()
        good.suggest_float("x", 0, 1)
        good.report([0.0, 100.0], 1)  # dominates everything
        assert not good.should_prune()

    def test_stored_stream_is_scalarized_and_consistent(self):
        pruner = hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=1))
        study = hpo.create_study(
            directions=["minimize", "maximize"],
            sampler=hpo.RandomSampler(seed=0),
            pruner=pruner,
        )
        t = study.ask()
        t.suggest_float("x", 0, 1)
        vals = [2.0, 3.0]
        t.report(vals, 1)
        frozen = study._storage.get_trial(t._trial_id)
        expected = pruner.scalarize(vals, study.directions)
        assert frozen.intermediate_values == {1: expected}

    def test_prune_via_optimize_loop(self):
        def obj(trial):
            x = trial.suggest_float("x", 0, 1)
            for step in range(5):
                trial.report([x + step * x, 1.0 - x], step)
                if trial.should_prune():
                    raise hpo.TrialPruned()
            return [x, 1.0 - x]

        study = hpo.create_study(
            directions=["minimize", "maximize"],
            sampler=hpo.RandomSampler(seed=5),
            pruner=hpo.ParetoPruner(hpo.MedianPruner(n_startup_trials=4, n_warmup_steps=1)),
        )
        study.optimize(obj, n_trials=25)
        states = {t.state for t in study.trials}
        assert TrialState.COMPLETE in states and TrialState.PRUNED in states


class TestCmaEsWaveSatellites:
    def _seeded_study(self, sampler, n=12):
        study = hpo.create_study(sampler=sampler)

        def obj(t):
            return (t.suggest_float("x", -2, 2) - 1) ** 2 + t.suggest_float("y", -2, 2) ** 2

        study.optimize(obj, n_trials=n)
        return study

    def test_wave_size_is_popsize_aware(self):
        sampler = hpo.CmaEsSampler(warmup_trials=5, seed=0)
        study = self._seeded_study(sampler)
        d = 2
        popsize = 4 + int(3 * np.log(d))
        assert sampler.joint_wave_size(study, 64) == popsize
        assert sampler.joint_wave_size(study, 3) == 3

    def test_wave_size_passthrough_without_cma_space(self):
        sampler = hpo.CmaEsSampler(warmup_trials=5, seed=0)
        study = hpo.create_study(sampler=sampler)  # no history -> no space
        assert sampler.joint_wave_size(study, 64) == 64

    def test_first_number_keys_the_wave_rng(self):
        sampler = hpo.CmaEsSampler(warmup_trials=5, seed=7)
        study = self._seeded_study(sampler)
        (group,) = observed_groups(study.observations())
        a = sampler.sample_joint(study, group, 4, first_number=12)
        b = sampler.sample_joint(study, group, 4, first_number=13)
        c = sampler.sample_joint(study, group, 4, first_number=12)
        assert not np.allclose(a, b)  # disjoint claims -> disjoint draws
        assert np.allclose(a, c)      # same claim -> deterministic replay

    def test_ask_wave_passes_first_pending_number(self):
        seen = []

        class Recording(hpo.CmaEsSampler):
            def sample_joint(self, study, group, n, trial_ids=None, first_number=None):
                seen.append(first_number)
                return super().sample_joint(
                    study, group, n, trial_ids=trial_ids, first_number=first_number
                )

        sampler = Recording(warmup_trials=5, seed=7)
        study = self._seeded_study(sampler, n=12)
        wave = study.ask(3)
        assert seen and seen[-1] == wave[0].number
        study._release_unrun(wave)

    def test_legacy_sample_joint_signature_still_served(self):
        """Custom samplers without the first_number kwarg keep working
        through Study.ask(n) (the signature is probed, not assumed)."""
        seen = []

        class Legacy(hpo.RandomSampler):
            def sample_joint(self, study, group, n, trial_ids=None):
                seen.append(n)
                return super().sample_joint(study, group, n, trial_ids=trial_ids)

        study = hpo.create_study(sampler=Legacy(seed=0))

        def obj(t):
            return t.suggest_float("x", 0, 1) ** 2

        study.optimize(obj, n_trials=2)
        wave = study.ask(3)
        assert seen == [3]
        study._release_unrun(wave)
