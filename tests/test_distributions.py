"""Distribution + suggest-API property tests (hypothesis)."""

import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't abort collection
from hypothesis import given, settings, strategies as st

import repro.core as hpo
from repro.core.distributions import (
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
    check_distribution_compatibility,
    distribution_to_json,
    json_to_distribution,
)


@given(
    low=st.floats(-1e6, 1e6, allow_nan=False),
    width=st.floats(0.0, 1e6, allow_nan=False),
)
def test_float_bounds_roundtrip(low, width):
    d = FloatDistribution(low, low + width)
    assert d._contains(d.to_internal_repr(low))
    d2 = json_to_distribution(distribution_to_json(d))
    assert d == d2


@given(st.floats(1e-8, 1e3), st.floats(1.0, 1e3))
def test_float_log_serialization(low, mult):
    d = FloatDistribution(low, low * mult, log=True)
    assert json_to_distribution(distribution_to_json(d)) == d


@given(st.integers(-1000, 1000), st.integers(0, 1000), st.integers(1, 7))
def test_int_step_roundtrip(low, width, step):
    d = IntDistribution(low, low + width, step=step)
    assert json_to_distribution(distribution_to_json(d)) == d
    assert d.to_external_repr(float(low)) == low


@given(st.lists(st.one_of(st.integers(), st.text(max_size=6), st.booleans(), st.none()),
                min_size=1, max_size=8, unique_by=lambda x: (type(x).__name__, x)))
def test_categorical_roundtrip(choices):
    d = CategoricalDistribution(choices)
    d2 = json_to_distribution(distribution_to_json(d))
    assert d2 == d
    for i, c in enumerate(choices):
        assert d.to_external_repr(float(i)) == c
        assert d.to_internal_repr(c) == float(i)


def test_invalid_distributions():
    with pytest.raises(ValueError):
        FloatDistribution(2.0, 1.0)
    with pytest.raises(ValueError):
        FloatDistribution(-1.0, 1.0, log=True)
    with pytest.raises(ValueError):
        FloatDistribution(0, 1, log=True, step=0.1)
    with pytest.raises(ValueError):
        IntDistribution(1, 10, step=0)
    with pytest.raises(ValueError):
        CategoricalDistribution([])
    with pytest.raises(ValueError):
        CategoricalDistribution([object()])


def test_compatibility_checks():
    check_distribution_compatibility(
        FloatDistribution(0, 1), FloatDistribution(-1, 2)
    )  # numeric bounds may move
    with pytest.raises(ValueError):
        check_distribution_compatibility(FloatDistribution(0, 1), IntDistribution(0, 1))
    with pytest.raises(ValueError):
        check_distribution_compatibility(
            CategoricalDistribution([1, 2]), CategoricalDistribution([1, 3])
        )


@settings(deadline=None, max_examples=25)
@given(
    low=st.floats(-100, 100),
    width=st.floats(0.1, 100),
    seed=st.integers(0, 2**16),
)
def test_suggest_float_within_bounds(low, width, seed):
    study = hpo.create_study(sampler=hpo.RandomSampler(seed=seed))

    def obj(trial):
        x = trial.suggest_float("x", low, low + width)
        assert low <= x <= low + width
        return x

    study.optimize(obj, n_trials=5)
    assert len(study.trials) == 5


@settings(deadline=None, max_examples=25)
@given(low=st.integers(1, 50), width=st.integers(0, 50), seed=st.integers(0, 2**16))
def test_suggest_int_log_within_bounds(low, width, seed):
    study = hpo.create_study(sampler=hpo.TPESampler(seed=seed, n_startup_trials=3))

    def obj(trial):
        x = trial.suggest_int("x", low, low + width, log=True)
        assert low <= x <= low + width
        assert isinstance(x, int)
        return float(x)

    study.optimize(obj, n_trials=8)


def test_resuggest_same_value_within_trial():
    study = hpo.create_study(sampler=hpo.RandomSampler(seed=0))

    def obj(trial):
        a = trial.suggest_float("x", 0, 1)
        b = trial.suggest_float("x", 0, 1)  # idempotent re-suggest
        assert a == b
        return a

    study.optimize(obj, n_trials=3)


def test_step_quantization():
    study = hpo.create_study(sampler=hpo.RandomSampler(seed=1))

    def obj(trial):
        x = trial.suggest_float("x", 0.0, 1.0, step=0.25)
        assert x in (0.0, 0.25, 0.5, 0.75, 1.0)
        i = trial.suggest_int("i", 0, 10, step=5)
        assert i in (0, 5, 10)
        return x + i

    study.optimize(obj, n_trials=20)
