"""ShardedStorage: URL parsing, consistent-hash routing, id virtualization,
full-contract parity against a single server, and per-shard batching."""

import pytest

from repro.core.distributions import FloatDistribution
from repro.core.frozen import StudyDirection, TrialState
from repro.core.storage import (
    InMemoryStorage,
    RemoteStorage,
    ShardedStorage,
    StorageServer,
    get_storage,
)
from repro.core.storage.cluster import HashRing, parse_sharded_url


@pytest.fixture
def pool():
    servers = [StorageServer(InMemoryStorage()).start() for _ in range(3)]
    yield servers
    for s in servers:
        s.stop()


def _sharded(pool, **kw):
    return ShardedStorage([s.url for s in pool], **kw)


class TestParsing:
    def test_split_keeps_scheme_and_token(self):
        assert parse_sharded_url("remote://tok@a:1,b:2,c:3") == [
            "remote://tok@a:1",
            "remote://tok@b:2",
            "remote://tok@c:3",
        ]

    def test_split_keeps_failover_candidates(self):
        assert parse_sharded_url("remote://a:1+a2:2,b:3") == [
            "remote://a:1+a2:2",
            "remote://b:3",
        ]

    def test_tls_scheme(self):
        assert parse_sharded_url("remote+tls://a:1,b:2") == [
            "remote+tls://a:1",
            "remote+tls://b:2",
        ]

    def test_not_remote_raises(self):
        with pytest.raises(ValueError):
            parse_sharded_url("sqlite:///x.db")

    def test_get_storage_routes_comma_urls(self, pool):
        url = "remote://" + ",".join(s.url.split("://")[1] for s in pool)
        st = get_storage(url)
        assert isinstance(st, ShardedStorage)
        st.close()

    def test_get_storage_single_stays_remote(self, pool):
        st = get_storage(pool[0].url)
        assert isinstance(st, RemoteStorage)
        st.close()


class TestHashRing:
    def test_deterministic_across_instances(self):
        a, b = HashRing(4), HashRing(4)
        keys = [f"study-{i}" for i in range(200)]
        assert [a.lookup(k) for k in keys] == [b.lookup(k) for k in keys]

    def test_spreads_keys(self):
        ring = HashRing(3)
        owners = {ring.lookup(f"study-{i}") for i in range(100)}
        assert owners == {0, 1, 2}

    def test_consistency_under_growth(self):
        # growing the pool must not reshuffle the world: most keys keep
        # their owner (the consistent-hashing property)
        small, big = HashRing(3), HashRing(4)
        keys = [f"study-{i}" for i in range(1000)]
        moved = sum(small.lookup(k) != big.lookup(k) for k in keys)
        assert moved < 500  # naive mod-N hashing would move ~75%


class TestIdVirtualization:
    def test_round_trip(self, pool):
        st = _sharded(pool)
        for gid in [0, 1, 2, 3, 7, 100, 12345]:
            shard, local = st._split(gid)
            assert st._gid(local, shard) == gid
            assert 0 <= shard < 3

    def test_study_and_trial_ids_are_global(self, pool):
        st = _sharded(pool)
        sids = [st.create_new_study([StudyDirection.MINIMIZE], f"s{i}") for i in range(9)]
        assert len(set(sids)) == 9
        assert len({sid % 3 for sid in sids}) > 1  # actually spread
        for i, sid in enumerate(sids):
            assert st.get_study_id_from_name(f"s{i}") == sid
            assert st.get_study_name_from_id(sid) == f"s{i}"
        tids = [st.create_new_trial(sid) for sid in sids for _ in range(2)]
        assert len(set(tids)) == len(tids)
        st.close()

    def test_trials_route_back_to_their_shard(self, pool):
        st = _sharded(pool)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "routed")
        tids = st.create_new_trials(sid, 5)
        for i, tid in enumerate(tids):
            st.set_trial_param(tid, "x", 0.25, FloatDistribution(0, 1))
            assert st.set_trial_state_values(tid, TrialState.COMPLETE, [float(i)])
        trials = st.get_all_trials(sid)
        assert [t.trial_id for t in trials] == tids
        assert [t.number for t in trials] == list(range(5))
        assert st.get_trial(tids[3]).values == [3.0]
        assert st.get_trial_id_from_study_and_number(sid, 3) == tids[3]
        st.close()


class TestContractParity:
    def test_attrs_and_summaries(self, pool):
        st = _sharded(pool)
        sids = [st.create_new_study([StudyDirection.MAXIMIZE], f"p{i}") for i in range(4)]
        for sid in sids:
            st.set_study_user_attr(sid, "team", "a")
            st.set_study_system_attr(sid, "v", 1)
            assert st.get_study_user_attrs(sid) == {"team": "a"}
            assert st.get_study_system_attrs(sid) == {"v": 1}
            assert st.get_study_directions(sid) == [StudyDirection.MAXIMIZE]
        summaries = st.get_all_studies()
        assert sorted(s.study_id for s in summaries) == sorted(sids)
        st.delete_study(sids[0])
        assert len(st.get_all_studies()) == 3
        st.close()

    def test_iv_block_trial_ids_are_globalized(self, pool):
        st = _sharded(pool)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "ivs")
        tids = st.create_new_trials(sid, 3)
        for step in range(2):
            for tid in tids:
                st.set_trial_intermediate_value(tid, step, float(step))
        block = st.get_iv_block(sid)
        assert sorted(int(t) for t in block["trial_ids"]) == sorted(tids)
        # observation blocks and trial events are keyed by per-study numbers
        for tid, v in zip(tids, (1.0, 2.0, 3.0)):
            st.set_trial_state_values(tid, TrialState.COMPLETE, [v])
        obs = st.get_observation_block(sid)
        assert sorted(int(n) for n in obs["numbers"]) == [0, 1, 2]
        ev = st.get_trial_events(sid)
        assert len(ev["kind"]) > 0
        st.close()

    def test_heartbeats_and_reclaim(self, pool):
        st = _sharded(pool)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "hb")
        tid = st.create_new_trial(sid)
        st.record_heartbeat(tid)
        assert st.get_stale_trial_ids(sid, grace_seconds=3600) == []
        assert st.get_stale_trial_ids(sid, grace_seconds=-1.0) == [tid]
        assert st.reclaim_stale_trials(sid, grace_seconds=-1.0, requeue=True) == [tid]
        assert st.get_trial(tid).state == TrialState.WAITING
        st.close()

    def test_revision_and_counts(self, pool):
        st = _sharded(pool)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "rev")
        r0 = st.get_trials_revision(sid)
        tid = st.create_new_trial(sid)
        assert st.get_trials_revision(sid) > r0
        assert st.get_n_trials(sid) == 1
        assert st.get_n_trials(sid, states=(TrialState.COMPLETE,)) == 0
        st.set_trial_user_attr(tid, "k", [1, 2])
        st.set_trial_system_attr(tid, "s", "x")
        t = st.get_trial(tid)
        assert t.user_attrs == {"k": [1, 2]} and t.system_attrs["s"] == "x"
        st.close()

    def test_server_metrics_fan_out(self, pool):
        st = _sharded(pool)
        st.create_new_study([StudyDirection.MINIMIZE], "m")
        metrics = st.get_server_metrics()
        assert len(metrics["shards"]) == 3
        assert all("frames_in" in m for m in metrics["shards"])
        st.close()

    def test_supports_block_fetch(self, pool):
        st = _sharded(pool)
        assert st.supports_block_fetch is True
        st.close()


class TestCallBatch:
    def test_batch_routes_and_reassembles_in_order(self, pool):
        st = _sharded(pool)
        sids = [st.create_new_study([StudyDirection.MINIMIZE], f"b{i}") for i in range(6)]
        tids = [st.create_new_trial(sid) for sid in sids]
        calls = []
        for tid in tids:
            calls.append(("get_trial", (tid,)))
        for sid in sids:
            calls.append(("get_n_trials", (sid, None)))
        out = st.call_batch(calls)
        assert [t.trial_id for t in out[: len(tids)]] == tids
        assert out[len(tids):] == [1] * len(sids)
        st.close()

    def test_batch_writes_and_fused_prune(self, pool):
        st = _sharded(pool)
        sid = st.create_new_study([StudyDirection.MINIMIZE], "fused")
        tid = st.create_new_trial(sid)
        spec = {"name": "median", "n_startup_trials": 100}
        out = st.call_batch(
            [
                ("set_trial_intermediate_value", (tid, 0, 1.5)),
                ("report_and_prune", (sid, tid, 1, 0.5, spec, StudyDirection.MINIMIZE)),
            ]
        )
        assert out[-1] in (True, False)
        assert st.get_trial(tid).intermediate_values == {0: 1.5, 1: 0.5}
        st.close()

    def test_unroutable_method_raises(self, pool):
        st = _sharded(pool)
        with pytest.raises(ValueError):
            st.call_batch([("get_all_studies", ())])
        st.close()


class TestEndToEnd:
    def test_optimize_through_router_with_cache(self, pool):
        from repro.core.samplers import TPESampler
        from repro.core.study import create_study

        url = "remote://" + ",".join(s.url.split("://")[1] for s in pool)
        storage = get_storage(url, cache=True)
        study = create_study(
            storage=storage, study_name="e2e", sampler=TPESampler(seed=7)
        )
        study.optimize(lambda t: t.suggest_float("x", -5, 5) ** 2, n_trials=15)
        assert len(study.trials) == 15
        assert study.best_value is not None
