"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(+ hypothesis property sweeps)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; skip, don't abort collection
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import crossentropy_op, flash_attention_op, ssd_op


RNG = np.random.RandomState(0)


def randn(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.randn(*shape) * scale).astype(dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,S,D,bq,bk",
        [
            (1, 2, 2, 64, 32, 16, 16),    # MHA
            (2, 4, 2, 128, 32, 32, 32),   # GQA 2x
            (1, 8, 1, 96, 16, 32, 32),    # MQA, non-multiple seq (pad path)
            (1, 2, 2, 128, 128, 128, 64), # MXU-width head_dim
        ],
    )
    def test_matches_ref(self, dtype, B, Hq, Hkv, S, D, bq, bk):
        q = randn(B, Hq, S, D).astype(dtype)
        k = randn(B, Hkv, S, D).astype(dtype)
        v = randn(B, Hkv, S, D).astype(dtype)
        out = flash_attention_op(q, k, v, causal=True, block_q=bq, block_k=bk)
        expect = ref.attention_ref(q, k, v, causal=True)
        tol = 1e-4 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol
        )

    @pytest.mark.parametrize("window", [8, 32, 100])
    def test_sliding_window(self, window):
        q, k, v = (randn(1, 2, 64, 16) for _ in range(3))
        out = flash_attention_op(q, k, v, causal=True, window=window, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    @pytest.mark.parametrize("softcap", [10.0, 50.0])
    def test_softcap(self, softcap):
        q, k, v = (randn(1, 2, 64, 16, scale=3.0) for _ in range(3))
        out = flash_attention_op(q, k, v, causal=True, softcap=softcap, block_q=32, block_k=32)
        expect = ref.attention_ref(q, k, v, causal=True, softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    def test_non_causal(self):
        q, k, v = (randn(1, 2, 48, 16) for _ in range(3))
        out = flash_attention_op(q, k, v, causal=False, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    @settings(deadline=None, max_examples=10)
    @given(
        S=st.integers(16, 80),
        D=st.sampled_from([8, 16, 32]),
        Hkv=st.sampled_from([1, 2]),
        g=st.sampled_from([1, 2, 4]),
    )
    def test_property_sweep(self, S, D, Hkv, g):
        rng = np.random.RandomState(S * 7 + D)
        q = jnp.asarray(rng.randn(1, Hkv * g, S, D).astype(np.float32))
        k = jnp.asarray(rng.randn(1, Hkv, S, D).astype(np.float32))
        v = jnp.asarray(rng.randn(1, Hkv, S, D).astype(np.float32))
        out = flash_attention_op(q, k, v, causal=True, block_q=16, block_k=16)
        expect = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)


class TestSSD:
    @pytest.mark.parametrize("S,P,N,chunk", [(64, 16, 8, 16), (128, 32, 16, 32), (32, 8, 8, 32)])
    def test_matches_sequential_ref(self, S, P, N, chunk):
        BH = 3
        x = randn(BH, S, P)
        dt = jnp.abs(randn(BH, S)) * 0.5
        A = -jnp.abs(randn(BH))
        Bm = randn(BH, S, N)
        Cm = randn(BH, S, N)
        y, fin = ssd_op(x, dt, A, Bm, Cm, chunk=chunk)
        for i in range(BH):
            yr, fr = ref.ssd_ref(
                x[i : i + 1, :, None], dt[i : i + 1, :, None], A[i : i + 1],
                Bm[i : i + 1, :, None], Cm[i : i + 1, :, None],
            )
            np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yr[0, :, 0]), atol=2e-3)
            np.testing.assert_allclose(np.asarray(fin[i]), np.asarray(fr[0, 0]), atol=2e-3)

    def test_chunked_jnp_matches_kernel_path(self):
        """models.mamba2.ssd_chunked (the lowered path) == Pallas kernel."""
        from repro.models.mamba2 import ssd_chunked

        B, S, H, P, N = 2, 64, 4, 16, 8
        x = randn(B, S, H, P)
        dt = jnp.abs(randn(B, S, H)) * 0.5
        A = -jnp.abs(randn(H))
        Bm = randn(B, S, 1, N)
        Cm = randn(B, S, 1, N)
        y_jnp, fin_jnp = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
        # fold to kernel layout [B*H, S, ...]
        xk = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
        dtk = dt.transpose(0, 2, 1).reshape(B * H, S)
        Ak = jnp.tile(A, B)
        Bk = jnp.repeat(Bm.transpose(0, 2, 1, 3), H, axis=1).reshape(B * H, S, N)
        Ck = jnp.repeat(Cm.transpose(0, 2, 1, 3), H, axis=1).reshape(B * H, S, N)
        y_k, fin_k = ssd_op(xk, dtk, Ak, Bk, Ck, chunk=16)
        np.testing.assert_allclose(
            np.asarray(y_k.reshape(B, H, S, P).transpose(0, 2, 1, 3)),
            np.asarray(y_jnp), atol=2e-3,
        )
        np.testing.assert_allclose(
            np.asarray(fin_k.reshape(B, H, P, N)), np.asarray(fin_jnp), atol=2e-3
        )


class TestCrossEntropy:
    @pytest.mark.parametrize("T,D,V,bt,bv", [(64, 32, 500, 32, 128), (100, 48, 1000, 32, 256), (16, 16, 50, 16, 64)])
    def test_matches_ref(self, T, D, V, bt, bv):
        x = randn(T, D)
        w = randn(D, V, scale=0.05)
        labels = jnp.asarray(RNG.randint(0, V, (T,)).astype(np.int32))
        nll = crossentropy_op(x, w, labels, block_t=bt, block_v=bv)
        expect = ref.crossentropy_ref(x, w, labels)
        np.testing.assert_allclose(np.asarray(nll), np.asarray(expect), atol=1e-4, rtol=1e-4)

    def test_softcap_and_bf16(self):
        x = randn(32, 16).astype(jnp.bfloat16)
        w = randn(16, 100, scale=0.2).astype(jnp.bfloat16)
        labels = jnp.asarray(RNG.randint(0, 100, (32,)).astype(np.int32))
        nll = crossentropy_op(x, w, labels, softcap=30.0, block_t=16, block_v=64)
        expect = ref.crossentropy_ref(x, w, labels, softcap=30.0)
        np.testing.assert_allclose(np.asarray(nll), np.asarray(expect), atol=5e-2, rtol=5e-2)

    def test_matches_model_chunked_ce(self):
        """kernels CE == models.layers.cross_entropy_chunked (train path)."""
        from repro.models.layers import cross_entropy_chunked

        B, S, D, V = 2, 32, 16, 128
        x = randn(B, S, D)
        w = randn(D, V, scale=0.1)
        labels = jnp.asarray(RNG.randint(0, V, (B, S)).astype(np.int32))
        mean_chunked = cross_entropy_chunked(x, w, labels, chunk=8)
        nll = crossentropy_op(x.reshape(B * S, D), w, labels.reshape(-1), block_t=16, block_v=64)
        np.testing.assert_allclose(float(mean_chunked), float(nll.mean()), atol=1e-4)


class TestSLSTMKernel:
    @pytest.mark.parametrize("B,S,H,D,bt", [(4, 24, 2, 8, 2), (2, 16, 4, 16, 2), (8, 8, 2, 8, 8)])
    def test_matches_model_scan(self, B, S, H, D, bt):
        from repro.kernels.slstm import slstm_scan
        from repro.models.ssm_xlstm import _slstm_scan, empty_slstm_state

        rng = np.random.RandomState(B * 31 + S)
        d = H * D

        class Cfg:
            n_heads = H
            d_model = d
            norm_eps = 1e-6

        u = rng.randn(B, S, 4 * d).astype(np.float32) * 0.5
        R = rng.randn(4, H, D, D).astype(np.float32) * 0.2
        p = {"r_zifo": jnp.asarray(R)}
        hs_ref, fin_ref = _slstm_scan(p, jnp.asarray(u), Cfg, empty_slstm_state(Cfg, B))
        uk = jnp.asarray(u).reshape(B, S, 4, H, D).transpose(1, 0, 2, 3, 4)
        h_seq, (c, n, h, m) = slstm_scan(uk, jnp.asarray(R), batch_tile=bt, interpret=True)
        hs_k = h_seq.transpose(1, 0, 2, 3).reshape(B, S, d)
        np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(c), np.asarray(fin_ref["c"]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(m), np.asarray(fin_ref["m"]), atol=1e-4)


class TestMLSTMParallelVsRecurrent:
    def test_chunked_parallel_matches_recurrence(self):
        from repro.models.ssm_xlstm import mlstm_parallel

        B, S, H, D = 1, 32, 2, 8
        q = randn(B, S, H, D)
        k = randn(B, S, H, D) / np.sqrt(D)
        v = randn(B, S, H, D)
        logi = randn(B, S, H, scale=0.5)
        logf = jnp.asarray(np.log(RNG.uniform(0.8, 0.999, (B, S, H))).astype(np.float32))
        h_par = mlstm_parallel(q, k, v, logi, logf, q_chunk=8)
        h_rec = ref.mlstm_ref(q, k, v, logi, logf)
        np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_rec), atol=2e-3)


class TestParzenScoreKernel:
    def _mixture(self, rng, k):
        mus = rng.uniform(-3, 3, k).astype(np.float32)
        sigmas = rng.uniform(0.05, 1.0, k).astype(np.float32)
        ln = (np.log(np.full(k, 1.0 / k)) - np.log(sigmas)).astype(np.float32)
        return jnp.asarray(mus), jnp.asarray(sigmas), jnp.asarray(ln)

    @pytest.mark.parametrize(
        "C,Kl,Kg,bc,bk",
        [
            (64, 8, 8, 32, 8),      # single component block
            (100, 16, 64, 32, 16),  # unequal sides + non-multiple candidates
            (256, 32, 32, 64, 8),   # multi-block reduction axis
            (512, 128, 256, 256, 64),
        ],
    )
    def test_matches_ref(self, C, Kl, Kg, bc, bk):
        from repro.kernels.parzen import parzen_score

        rng = np.random.RandomState(C + Kl + Kg)
        cands = jnp.asarray(rng.uniform(-4, 4, C).astype(np.float32))
        l = self._mixture(rng, Kl)
        g = self._mixture(rng, Kg)
        out = parzen_score(cands, *l, *g, block_c=bc, block_k=bk, interpret=True)
        expect = ref.parzen_score_ref(cands, *l, *g)
        assert out.shape == (C,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)

    def test_neg_inf_padding_components_are_inert(self):
        """pow2 padding carries log_norm = -inf: scores must equal the
        unpadded mixture's exactly (the kernel clamps, never NaNs)."""
        from repro.kernels.parzen import parzen_score

        rng = np.random.RandomState(0)
        cands = jnp.asarray(rng.uniform(-4, 4, 64).astype(np.float32))
        mus, sigmas, ln = self._mixture(rng, 5)
        pad = lambda v, fill: jnp.pad(v, (0, 3), constant_values=fill)
        padded = (pad(mus, 0.0), pad(sigmas, 1.0), pad(ln, -np.inf))
        out = parzen_score(cands, *padded, *padded, block_k=8, interpret=True)
        expect = ref.parzen_score_ref(cands, mus, sigmas, ln, mus, sigmas, ln)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        C=st.integers(min_value=1, max_value=200),
        Kl=st.integers(min_value=1, max_value=40),
        Kg=st.integers(min_value=1, max_value=40),
    )
    def test_property_sweep(self, C, Kl, Kg):
        from repro.kernels.parzen import parzen_score

        rng = np.random.RandomState(C * 1000 + Kl * 40 + Kg)
        cands = jnp.asarray(rng.uniform(-4, 4, C).astype(np.float32))
        l = self._mixture(rng, Kl)
        g = self._mixture(rng, Kg)
        out = parzen_score(cands, *l, *g, block_c=32, block_k=16, interpret=True)
        expect = ref.parzen_score_ref(cands, *l, *g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4, rtol=2e-4)


class TestMCHypervolumeKernel:
    @pytest.mark.parametrize(
        "n,m,s,bs",
        [
            (8, 3, 256, 256),    # single sample block
            (20, 4, 1000, 256),  # pow2 point padding + non-multiple samples
            (64, 6, 2048, 512),  # many-objective (the estimator's regime)
            (3, 2, 100, 1024),   # block_s > s (clamp path)
        ],
    )
    def test_matches_ref(self, n, m, s, bs):
        from repro.kernels.hypervolume import mc_hv_counts

        rng = np.random.RandomState(n * m + s)
        pts = jnp.asarray(rng.uniform(0, 1, (n, m)).astype(np.float32))
        smp = jnp.asarray(rng.uniform(0, 1.1, (s, m)).astype(np.float32))
        excl, tot = mc_hv_counts(pts, smp, block_s=bs, interpret=True)
        excl_r, tot_r = ref.mc_hv_counts_ref(pts, smp)
        assert excl.shape == (n,)
        np.testing.assert_array_equal(np.asarray(excl), np.asarray(excl_r))
        assert float(tot) == float(tot_r)

    def test_counts_are_consistent(self):
        """Exclusive counts can never exceed the total dominated count, and a
        sample below every point is counted exactly once in total."""
        from repro.kernels.hypervolume import mc_hv_counts

        rng = np.random.RandomState(1)
        pts = jnp.asarray(rng.uniform(0.4, 0.6, (16, 5)).astype(np.float32))
        smp = jnp.asarray(rng.uniform(0, 1, (512, 5)).astype(np.float32))
        excl, tot = mc_hv_counts(pts, smp, block_s=128, interpret=True)
        assert float(jnp.sum(excl)) <= float(tot) <= smp.shape[0]

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=40),
        m=st.integers(min_value=2, max_value=7),
        s=st.integers(min_value=1, max_value=600),
    )
    def test_property_sweep(self, n, m, s):
        from repro.kernels.hypervolume import mc_hv_counts

        rng = np.random.RandomState(n * 7 + m * 601 + s)
        pts = jnp.asarray(rng.uniform(0, 1, (n, m)).astype(np.float32))
        smp = jnp.asarray(rng.uniform(0, 1.1, (s, m)).astype(np.float32))
        excl, tot = mc_hv_counts(pts, smp, block_s=128, interpret=True)
        excl_r, tot_r = ref.mc_hv_counts_ref(pts, smp)
        np.testing.assert_array_equal(np.asarray(excl), np.asarray(excl_r))
        assert float(tot) == float(tot_r)
