"""Wire protocol v2: binary codec roundtrips, hello negotiation and
back-compat, frame fuzzing / reactor robustness, auth scopes, TLS, and the
mini worker-storm smoke on both protocol versions."""

import datetime
import json
import multiprocessing
import socket
import struct
import subprocess
import threading

import numpy as np
import pytest

import repro.core as hpo
from repro.core.distributions import (
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from repro.core.frozen import FrozenTrial, StudyDirection, TrialState
from repro.core.records import IntermediateValueStore, ObservationStore
from repro.core.storage import (
    InMemoryStorage,
    RemoteStorage,
    ShardedStorage,
    StorageServer,
    get_storage,
)
from repro.core.storage.serde import (
    BINARY_MAGIC,
    bdumps,
    bjoin,
    bloads,
    pack,
    unpack,
)
from repro.core.storage.server import MAX_FRAME_BYTES, recv_frame, send_frame


# -- binary codec -------------------------------------------------------------


class TestBinaryCodec:
    def test_scalar_roundtrip(self):
        for v in (None, True, False, 0, -1, 2**40, -(2**40), 1.5, float("inf"),
                  "", "héllo", b"raw\x00bytes", 2**100, -(2**100)):
            got = bloads(bdumps(v))
            assert got == v and type(got) is type(v), v

    def test_nan_roundtrip(self):
        got = bloads(bdumps(float("nan")))
        assert isinstance(got, float) and got != got

    def test_containers_match_json_codec(self):
        # dict int keys are stringified exactly like the JSON path
        obj = {"a": [1, 2.5, None, {"n": [True]}], 3: "three", "t": (1, 2)}
        binary = bloads(bdumps(obj))
        jsonic = unpack(json.loads(json.dumps(pack(obj))))
        assert binary == jsonic
        assert binary["3"] == "three" and binary["t"] == [1, 2]

    def test_ndarray_roundtrip(self):
        for arr in (
            np.arange(6, dtype=np.int64),
            np.arange(6, dtype=np.float64).reshape(2, 3),
            np.array([], dtype=np.float32),
            np.array([[True, False]]),
            np.arange(8, dtype=np.int8)[::2],  # non-contiguous input
        ):
            got = bloads(bdumps(arr))
            assert isinstance(got, np.ndarray)
            assert got.dtype == arr.dtype and got.shape == arr.shape
            assert np.array_equal(got, arr)

    def test_object_array_rejected(self):
        with pytest.raises(TypeError):
            bdumps(np.array([object()]))

    def test_frozen_trial_roundtrip_matches_json_codec(self):
        t = FrozenTrial(
            number=3,
            state=TrialState.PRUNED,
            values=[1.5, -2.0],
            params={"x": 0.25, "c": None},
            distributions={
                "x": FloatDistribution(0, 1, log=False),
                "c": CategoricalDistribution([None, "b", 4]),
            },
            intermediate_values={0: 1.0, 7: float("nan")},
            user_attrs={"k": [1, {"deep": "v"}]},
            system_attrs={"fixed_params": {"x": 0.25}},
            trial_id=17,
            datetime_start=datetime.datetime(2026, 8, 8, 12, 0, 1, 5),
            datetime_complete=datetime.datetime(2026, 8, 8, 12, 0, 2),
        )
        for got in (bloads(bdumps(t)), unpack(json.loads(json.dumps(pack(t))))):
            assert got.number == 3 and got.state is TrialState.PRUNED
            assert got.values == [1.5, -2.0]
            assert got.params == t.params
            assert isinstance(got.distributions["x"], FloatDistribution)
            assert sorted(got.intermediate_values) == [0, 7]
            assert got.intermediate_values[7] != got.intermediate_values[7]
            assert got.user_attrs == t.user_attrs
            assert got.trial_id == 17
            assert got.datetime_start == t.datetime_start
            assert got.datetime_complete == t.datetime_complete

    def test_enum_types_preserved(self):
        got = bloads(bdumps([TrialState.COMPLETE, StudyDirection.MAXIMIZE]))
        assert got[0] is TrialState.COMPLETE
        assert got[1] is StudyDirection.MAXIMIZE

    def test_bjoin_decodes_as_list(self):
        blobs = [bdumps(i) for i in range(5)]
        assert bloads(bjoin(blobs)) == [0, 1, 2, 3, 4]

    @pytest.mark.parametrize(
        "payload",
        [
            b"",                              # empty
            bytes([0xEE]),                    # unknown tag
            bytes([0x05]) + b"\x00\x00\x01",  # truncated str length
            bytes([0x05]) + struct.pack(">I", 100) + b"short",  # str overruns
            bytes([0x07]) + struct.pack(">I", 3) + bdumps(1),   # list underruns
            bytes([0x09]) + b"\x03<f8",       # truncated ndarray header
            bdumps(1) + b"tail",              # trailing bytes
        ],
    )
    def test_malformed_input_raises_cleanly(self, payload):
        with pytest.raises((ValueError, struct.error)):
            bloads(payload)


# -- hello negotiation / back-compat -----------------------------------------


class TestNegotiation:
    def test_v2_negotiated_by_default(self):
        with StorageServer(InMemoryStorage()) as srv:
            r = RemoteStorage(srv.url)
            assert r.protocol == 2
            assert r.supports_block_fetch

    def test_v2_client_falls_back_to_json_only_server(self):
        with StorageServer(InMemoryStorage(), max_protocol=1) as srv:
            r = RemoteStorage(srv.url)  # hello answered as unknown method
            assert r.protocol == 1
            assert not r.supports_block_fetch
            sid = r.create_new_study([StudyDirection.MINIMIZE], "s")
            assert r.get_study_name_from_id(sid) == "s"

    def test_client_pinned_to_v1(self):
        with StorageServer(InMemoryStorage()) as srv:
            r = RemoteStorage(srv.url, protocol=1)
            assert r.protocol == 1
            sid = r.create_new_study([StudyDirection.MINIMIZE], "s")
            assert r.get_study_name_from_id(sid) == "s"

    def test_block_rpcs_require_v2(self):
        with StorageServer(InMemoryStorage()) as srv:
            r = RemoteStorage(srv.url)
            sid = r.create_new_study([StudyDirection.MINIMIZE], "s")
            assert r.get_observation_block(sid)["n"] == 0
            r1 = RemoteStorage(srv.url, protocol=1)
            with pytest.raises(NotImplementedError):
                r1.get_observation_block(sid)
            with pytest.raises(NotImplementedError):
                r1.get_iv_block(sid)

    def test_store_falls_back_permanently_on_not_implemented(self):
        class Flaky(InMemoryStorage):
            supports_block_fetch = True

            def get_observation_block(self, study_id, since=0):
                raise NotImplementedError

            def get_iv_block(self, study_id, since=0):
                raise NotImplementedError

        storage = Flaky()
        sid = storage.create_new_study([StudyDirection.MINIMIZE], "s")
        tid = storage.create_new_trial(sid)
        storage.set_trial_state_values(tid, TrialState.COMPLETE, [1.0])
        obs = ObservationStore(storage, sid)
        obs.refresh()
        assert not obs._block_supported  # downgraded, data still ingested
        assert obs.n_observations == 1
        iv = IntermediateValueStore(storage, sid)
        iv.refresh()
        assert not iv._block_supported
        assert iv.n_rows == 1


def _phase_worker(url, protocol, seed, n_trials, out_q):
    try:
        storage = RemoteStorage(url, protocol=protocol)
        study = hpo.load_study(
            study_name="compat", storage=storage, sampler=hpo.TPESampler(seed=seed),
            pruner=hpo.MedianPruner(n_startup_trials=2),
        )
        study.optimize(_compat_objective, n_trials=n_trials)
        out_q.put("ok")
    except BaseException as e:  # pragma: no cover - surfaced by the test
        out_q.put(f"worker failed: {e!r}")


def _compat_objective(trial):
    x = trial.suggest_float("x", -5, 5)
    k = trial.suggest_int("k", 1, 4)
    for step in range(3):
        trial.report(x * x + step, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return x * x + k * 0.1


def _trial_fingerprint(storage, study_id):
    return [
        (t.number, t.state, tuple(t.values) if t.values else None,
         sorted(t.params.items()), sorted(t.intermediate_values.items()))
        for t in storage.get_all_trials(study_id)
    ]


class TestBackCompatSeededStudy:
    """A seeded 2-process study completes bit-identically to inmemory under
    every protocol pairing: legacy JSON client against the v2 server, and a
    v2 client against a JSON-only server."""

    PHASES = ((7, 10), (23, 10))  # (sampler seed, n_trials) per process

    def _reference(self):
        storage = InMemoryStorage()
        sid = hpo.create_study(study_name="compat", storage=storage)._study_id
        for seed, n in self.PHASES:
            study = hpo.load_study(
                study_name="compat", storage=storage,
                sampler=hpo.TPESampler(seed=seed),
                pruner=hpo.MedianPruner(n_startup_trials=2),
            )
            study.optimize(_compat_objective, n_trials=n)
        return _trial_fingerprint(storage, sid)

    @pytest.mark.parametrize(
        "client_proto,server_max",
        [(1, 2), (2, 1), (2, 2)],
        ids=["json-client-v2-server", "v2-client-json-server", "v2-both"],
    )
    def test_two_process_study_bit_identical(self, client_proto, server_max):
        reference = self._reference()
        with StorageServer(InMemoryStorage(), max_protocol=server_max) as srv:
            admin = RemoteStorage(srv.url, protocol=client_proto)
            sid = hpo.create_study(study_name="compat", storage=admin)._study_id
            # two worker processes run sequentially (deterministic handoff:
            # phase 2 sees exactly phase 1's history, like the reference)
            for seed, n in self.PHASES:
                q = multiprocessing.Queue()
                p = multiprocessing.Process(
                    target=_phase_worker, args=(srv.url, client_proto, seed, n, q)
                )
                p.start()
                result = q.get(timeout=120)
                p.join(timeout=30)
                assert result == "ok", result
            assert _trial_fingerprint(admin, sid) == reference


# -- frame fuzzing / reactor robustness ---------------------------------------


@pytest.fixture(params=[1, 2], ids=["v1", "v2"])
def fuzz_server(request):
    srv = StorageServer(InMemoryStorage(), max_protocol=request.param).start()
    yield srv
    srv.stop()


def _raw_conn(srv):
    sock = socket.create_connection((srv.host, srv.port), timeout=5.0)
    sock.settimeout(5.0)
    return sock


def _server_alive(srv):
    """A fresh client round-trips fine — the loop is still serving."""
    r = RemoteStorage(srv.url)
    sid = r.create_new_study([StudyDirection.MINIMIZE], f"alive-{r._req_id()}")
    assert r.get_study_name_from_id(sid).startswith("alive-")
    r.close()


class TestFrameFuzzing:
    def test_oversized_length_header_drops_connection(self, fuzz_server):
        good = RemoteStorage(fuzz_server.url)  # victim that must survive
        sock = _raw_conn(fuzz_server)
        sock.sendall(struct.pack(">I", MAX_FRAME_BYTES + 1))
        assert sock.recv(1) == b""  # dropped without a byte in response
        assert good.get_all_studies() == []  # other connection unharmed
        _server_alive(fuzz_server)

    def test_garbage_payload_drops_connection(self, fuzz_server):
        sock = _raw_conn(fuzz_server)
        send_frame(sock, b"\x00\xffnot a request under either protocol")
        assert sock.recv(1) == b""
        _server_alive(fuzz_server)

    def test_mid_frame_disconnect_is_isolated(self, fuzz_server):
        for _ in range(3):
            sock = _raw_conn(fuzz_server)
            sock.sendall(struct.pack(">I", 512) + b"x" * 100)  # torn frame
            sock.close()
        _server_alive(fuzz_server)

    def test_partial_frame_completes_after_delay(self, fuzz_server):
        sock = _raw_conn(fuzz_server)
        payload = json.dumps({"id": 1, "method": "ping", "params": []}).encode()
        frame = struct.pack(">I", len(payload)) + payload
        sock.sendall(frame[:3])
        _server_alive(fuzz_server)  # other clients progress meanwhile
        sock.sendall(frame[3:])
        body = recv_frame(sock)
        assert json.loads(body)["result"] == "pong"

    def test_garbage_binary_after_hello_drops_connection(self):
        with StorageServer(InMemoryStorage()) as srv:
            sock = _raw_conn(srv)
            hello = json.dumps(
                {"id": 1, "method": "hello", "params": [{"protocol": 2}]}
            ).encode()
            send_frame(sock, hello)
            assert json.loads(recv_frame(sock))["result"]["protocol"] == 2
            # now binary framing is required: garbage must kill only this conn
            send_frame(sock, bytes([BINARY_MAGIC]) + b"\xee\xee\xee")
            assert sock.recv(1) == b""
            _server_alive(srv)

    def test_unknown_method_is_typed_error_not_drop(self, fuzz_server):
        sock = _raw_conn(fuzz_server)
        send_frame(sock, json.dumps({"id": 5, "method": "no_such", "params": []}).encode())
        resp = json.loads(recv_frame(sock))
        assert resp["ok"] is False and "unknown storage method" in resp["error"]["message"]
        # the connection survives a typed error
        send_frame(sock, json.dumps({"id": 6, "method": "ping", "params": []}).encode())
        assert json.loads(recv_frame(sock))["result"] == "pong"

    def test_protocol_errors_counted(self, fuzz_server):
        before = fuzz_server.get_server_metrics()["protocol_errors"]
        sock = _raw_conn(fuzz_server)
        send_frame(sock, b"{truncated json")
        assert sock.recv(1) == b""
        metrics = fuzz_server.get_server_metrics()
        assert metrics["protocol_errors"] >= before + 1


# -- auth scopes ---------------------------------------------------------------


class TestAuthScopes:
    @pytest.fixture
    def scoped(self):
        backend = InMemoryStorage()
        sid_a = backend.create_new_study([StudyDirection.MINIMIZE], "a")
        sid_b = backend.create_new_study([StudyDirection.MINIMIZE], "b")
        srv = StorageServer(
            backend,
            auth_token="admin",
            auth_tokens=[
                {"token": "viewer", "readonly": True},
                {"token": "team-a", "studies": [sid_a]},
            ],
        ).start()
        yield srv, sid_a, sid_b
        srv.stop()

    def test_readonly_token_blocks_writes(self, scoped):
        srv, sid_a, _ = scoped
        viewer = RemoteStorage(srv.url, auth_token="viewer")
        assert viewer.get_study_name_from_id(sid_a) == "a"  # reads fine
        with pytest.raises(PermissionError):
            viewer.create_new_study([StudyDirection.MINIMIZE], "nope")
        with pytest.raises(PermissionError):
            viewer.create_new_trial(sid_a)
        by_cause = srv.get_server_metrics()["auth_failures_by_cause"]
        assert by_cause["readonly"] == 2  # terminal: one count per violation

    def test_study_scoped_token_allowlist(self, scoped):
        srv, sid_a, sid_b = scoped
        team = RemoteStorage(srv.url, auth_token="team-a")
        tid = team.create_new_trial(sid_a)  # in scope: full access
        team.set_trial_user_attr(tid, "k", 1)
        assert team.get_trial(tid).user_attrs == {"k": 1}
        with pytest.raises(PermissionError):
            team.get_all_trials(sid_b)
        with pytest.raises(PermissionError):
            team.create_new_trial(sid_b)
        with pytest.raises(PermissionError):
            team.get_all_studies()  # not study-addressable
        with pytest.raises(PermissionError):
            team.create_new_study([StudyDirection.MINIMIZE], "c")
        assert srv.get_server_metrics()["auth_failures_by_cause"]["study_scope"] == 4

    def test_trial_addressed_calls_resolve_to_study(self, scoped):
        srv, sid_a, sid_b = scoped
        admin = RemoteStorage(srv.url, auth_token="admin")
        tid_a = admin.create_new_trial(sid_a)
        tid_b = admin.create_new_trial(sid_b)
        team = RemoteStorage(srv.url, auth_token="team-a")
        # a trial the scoped connection never created still resolves (lazy
        # scan of the allowed studies)
        team.set_trial_user_attr(tid_a, "mine", True)
        assert admin.get_trial(tid_a).user_attrs == {"mine": True}
        with pytest.raises(PermissionError):
            team.set_trial_user_attr(tid_b, "theirs", True)
        with pytest.raises(PermissionError):
            team.get_trial(tid_b)

    def test_name_resolution_is_scope_checked(self, scoped):
        srv, sid_a, sid_b = scoped
        team = RemoteStorage(srv.url, auth_token="team-a")
        assert team.get_study_id_from_name("a") == sid_a
        with pytest.raises(PermissionError):
            team.get_study_id_from_name("b")

    def test_bad_token_counted_separately(self, scoped):
        srv, _, _ = scoped
        with pytest.raises(PermissionError):
            RemoteStorage(srv.url, auth_token="wrong")
        metrics = srv.get_server_metrics()
        assert metrics["auth_failures_by_cause"]["bad_token"] >= 1
        assert metrics["auth_failures"] >= 1  # aggregate keeps counting too

    def test_scoped_study_runs_end_to_end(self, scoped):
        srv, sid_a, _ = scoped
        team = RemoteStorage(srv.url, auth_token="team-a")
        study = hpo.load_study(
            study_name="a", storage=team, sampler=hpo.RandomSampler(seed=1)
        )
        study.optimize(lambda t: t.suggest_float("x", 0, 1) ** 2, n_trials=5)
        assert len(study.get_trials(states=(TrialState.COMPLETE,))) == 5


# -- TLS -----------------------------------------------------------------------


@pytest.fixture(scope="module")
def tls_cert(tmp_path_factory):
    d = tmp_path_factory.mktemp("tls")
    cert, key = str(d / "cert.pem"), str(d / "key.pem")
    proc = subprocess.run(
        [
            "openssl", "req", "-x509", "-newkey", "rsa:2048", "-nodes",
            "-keyout", key, "-out", cert, "-days", "1",
            "-subj", "/CN=127.0.0.1",
            "-addext", "subjectAltName=IP:127.0.0.1",
        ],
        capture_output=True,
    )
    if proc.returncode != 0:
        pytest.skip(f"openssl unavailable: {proc.stderr.decode()[:200]}")
    return cert, key


class TestTLS:
    def test_tls_study_end_to_end(self, tls_cert):
        cert, key = tls_cert
        with StorageServer(InMemoryStorage(), tls_cert=cert, tls_key=key) as srv:
            assert srv.url.startswith("remote+tls://")
            r = RemoteStorage(srv.url, tls_ca=cert)
            assert r.protocol == 2  # negotiation runs inside the TLS channel
            study = hpo.create_study(study_name="tls", storage=r)
            study.optimize(lambda t: t.suggest_float("x", -1, 1) ** 2, n_trials=8)
            assert len(study.get_trials(states=(TrialState.COMPLETE,))) == 8

    def test_tls_with_auth_token(self, tls_cert, monkeypatch):
        cert, key = tls_cert
        monkeypatch.setenv("REPRO_STORAGE_TLS_CA", cert)
        with StorageServer(
            InMemoryStorage(), tls_cert=cert, tls_key=key, auth_token="s3c"
        ) as srv:
            url = f"remote+tls://s3c@{srv.host}:{srv.port}"
            client = get_storage(url)  # CA picked up from the env fallback
            sid = client.create_new_study([StudyDirection.MINIMIZE], "t")
            assert client.get_study_name_from_id(sid) == "t"
            with pytest.raises(PermissionError):
                RemoteStorage(srv.url, tls_ca=cert, auth_token="bad")

    def test_plaintext_client_cannot_reach_tls_server(self, tls_cert):
        cert, key = tls_cert
        with StorageServer(InMemoryStorage(), tls_cert=cert, tls_key=key) as srv:
            with pytest.raises(Exception):
                RemoteStorage(f"remote://{srv.host}:{srv.port}", retries=1, timeout=3.0)

    def test_cert_without_key_rejected(self, tls_cert):
        cert, _ = tls_cert
        with pytest.raises(ValueError):
            StorageServer(InMemoryStorage(), tls_cert=cert)


# -- mini worker storm (tier-1 smoke; the full storm lives in benchmarks) ------


def _storm_worker(storage, sid, results, idx):
    try:
        for _ in range(2):
            tid = storage.create_new_trial(sid)
            storage.set_trial_param(tid, "x", 0.5, FloatDistribution(0, 1))
            storage.set_trial_intermediate_value(tid, 0, float(idx))
            assert storage.set_trial_state_values(tid, TrialState.COMPLETE, [float(idx)])
        results[idx] = None
    except Exception as e:  # pragma: no cover - surfaced by the assert below
        results[idx] = e


class TestMiniWorkerStorm:
    @pytest.mark.parametrize("topology", ["single", "sharded"])
    @pytest.mark.parametrize("max_protocol", [1, 2], ids=["v1", "v2"])
    def test_200_worker_storm_smoke(self, max_protocol, topology):
        import contextlib

        n_workers = 200
        n_servers = 1 if topology == "single" else 3
        with contextlib.ExitStack() as stack:
            servers = [
                stack.enter_context(
                    StorageServer(InMemoryStorage(), max_protocol=max_protocol)
                )
                for _ in range(n_servers)
            ]
            if topology == "single":
                storage = RemoteStorage(servers[0].url, timeout=60.0)
                storm_server = servers[0]
            else:
                storage = ShardedStorage([s.url for s in servers], timeout=60.0)
                storm_server = servers[storage.shard_of_study("storm")]
            sid = storage.create_new_study([StudyDirection.MINIMIZE], "storm")
            results = [RuntimeError("never ran")] * n_workers
            threads = [
                threading.Thread(target=_storm_worker, args=(storage, sid, results, i))
                for i in range(n_workers)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=120)
            errors = [e for e in results if e is not None]
            assert not errors, errors[:3]
            trials = storage.get_all_trials(sid)
            assert len(trials) == n_workers * 2
            assert sorted(t.number for t in trials) == list(range(n_workers * 2))
            assert all(t.state == TrialState.COMPLETE for t in trials)
            metrics = storm_server.get_server_metrics()
            assert metrics["frames_in"] > 0 and metrics["bytes_out"] > 0
            # serialize-once accounting: per-method bytes_out measures the
            # actual wire payloads
            assert metrics["methods"]["create_new_trial"]["bytes_out"] > 0
