"""Parity tests for the columnar plot reductions (core/analytics.py): every
reduction vs a brute-force per-trial reference loop on randomized inputs with
NaN/pruned rows, both directions; plus remote-vs-inmemory equivalence of the
delta endpoint payloads."""

import math

import numpy as np
import pytest

import repro.core as hpo
from repro.core import moo
from repro.core.analytics import (
    RevisionPoller,
    StudyAnalytics,
    contour_reduction,
    jsonable,
    running_best,
    slice_reduction,
)
from repro.core.frozen import TrialState

_COMPLETE = int(TrialState.COMPLETE)
_PRUNED = int(TrialState.PRUNED)


def _random_columns(rng, n):
    """Randomized (numbers, values, states, x, y) with NaN and pruned rows."""
    numbers = np.arange(n)
    values = rng.normal(size=n)
    values[rng.random(n) < 0.15] = np.nan
    states = np.where(rng.random(n) < 0.25, _PRUNED, _COMPLETE)
    x = rng.uniform(-2, 5, size=n)
    y = rng.uniform(0, 1, size=n)
    x[rng.random(n) < 0.1] = np.nan
    y[rng.random(n) < 0.1] = np.nan
    return numbers, values, states, x, y


class TestRunningBest:
    @pytest.mark.parametrize("minimize", [True, False])
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_parity_vs_loop(self, minimize, seed):
        rng = np.random.default_rng(seed)
        numbers, values, states, _, _ = _random_columns(rng, 120)
        nums, vals, best = running_best(numbers, values, states, minimize)

        # brute-force reference: walk trials in number order
        ref_nums, ref_vals, ref_best = [], [], []
        cur = None
        for i in range(len(numbers)):
            v = values[i]
            if states[i] != _COMPLETE or not math.isfinite(v):
                continue
            cur = v if cur is None else (min(cur, v) if minimize else max(cur, v))
            ref_nums.append(numbers[i])
            ref_vals.append(v)
            ref_best.append(cur)
        assert nums.tolist() == ref_nums
        np.testing.assert_array_equal(vals, ref_vals)
        np.testing.assert_array_equal(best, ref_best)

    def test_empty(self):
        nums, vals, best = running_best(
            np.empty(0, dtype=int), np.empty(0), np.empty(0, dtype=int), True
        )
        assert nums.size == 0 and vals.size == 0 and best.size == 0


class TestContourReduction:
    @pytest.mark.parametrize("minimize", [True, False])
    @pytest.mark.parametrize("seed", [0, 7])
    def test_parity_vs_loop(self, minimize, seed):
        rng = np.random.default_rng(seed)
        _, values, states, x, y = _random_columns(rng, 200)
        mask = states == _COMPLETE
        nx = ny = 6
        xe, ye, grid, counts = contour_reduction(x, y, values, mask, nx, ny, minimize)

        # reference: per-point loop into the same cells
        ref = np.full((ny, nx), np.nan)
        ref_counts = np.zeros((ny, nx), dtype=int)
        xlo, xhi = xe[0], xe[-1]
        ylo, yhi = ye[0], ye[-1]
        for i in range(len(values)):
            if not mask[i]:
                continue
            if not (math.isfinite(x[i]) and math.isfinite(y[i]) and math.isfinite(values[i])):
                continue
            cx = min(int((x[i] - xlo) / (xhi - xlo) * nx), nx - 1)
            cy = min(int((y[i] - ylo) / (yhi - ylo) * ny), ny - 1)
            ref_counts[cy, cx] += 1
            z = ref[cy, cx]
            if math.isnan(z):
                ref[cy, cx] = values[i]
            else:
                ref[cy, cx] = min(z, values[i]) if minimize else max(z, values[i])
        np.testing.assert_array_equal(counts, ref_counts)
        np.testing.assert_array_equal(grid, ref)

    def test_empty_and_degenerate(self):
        xe, ye, grid, counts = contour_reduction(
            np.empty(0), np.empty(0), np.empty(0), np.empty(0, dtype=bool), 4, 4
        )
        assert np.isnan(grid).all() and counts.sum() == 0
        # all points identical -> single cell, no div-by-zero
        n = 10
        xe, ye, grid, counts = contour_reduction(
            np.full(n, 2.0), np.full(n, 3.0), np.arange(n, dtype=float),
            np.ones(n, dtype=bool), 4, 4,
        )
        assert counts.sum() == n
        assert np.nanmin(grid) == 0.0


class TestSliceReduction:
    @pytest.mark.parametrize("seed", [0, 5])
    def test_band_quantiles_vs_loop(self, seed):
        rng = np.random.default_rng(seed)
        _, values, states, x, _ = _random_columns(rng, 150)
        mask = states == _COMPLETE
        out = slice_reduction(x, values, mask, n_bins=5)
        xs, zs = out["x"], out["z"]
        assert np.isfinite(xs).all() and np.isfinite(zs).all()

        bins = out["bins"]
        blo, bhi = xs.min(), xs.max()
        for c, med, lo, hi, cnt in zip(
            bins["centers"], bins["med"], bins["lo"], bins["hi"], bins["counts"]
        ):
            b = min(int((c - blo) / (bhi - blo) * 5), 4)
            sel = [z for xx, z in zip(xs, zs)
                   if min(int((xx - blo) / (bhi - blo) * 5), 4) == b]
            assert cnt == len(sel)
            assert med == pytest.approx(np.median(sel))
            assert lo == pytest.approx(np.percentile(sel, 25))
            assert hi == pytest.approx(np.percentile(sel, 75))

    def test_empty(self):
        out = slice_reduction(np.empty(0), np.empty(0), np.empty(0, dtype=bool))
        assert out["x"].size == 0 and out["bins"]["centers"].size == 0


class TestParetoViewParity:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_front_mask_vs_pairwise_loop(self, seed):
        rng = np.random.default_rng(seed)
        n = 60
        V = rng.normal(size=(n, 2))
        mask = rng.random(n) < 0.8
        directions = [0, 1]  # minimize, maximize
        L = moo.loss_matrix(V, directions)
        front = moo.pareto_front_mask(L, mask=mask)

        def dominates(a, b):
            return bool(np.all(L[a] <= L[b]) and np.any(L[a] < L[b]))

        for i in range(n):
            if not mask[i]:
                assert not front[i]
                continue
            dominated = any(
                dominates(j, i) for j in range(n) if j != i and mask[j]
            )
            assert front[i] == (not dominated)


class TestJsonable:
    def test_nan_and_numpy(self):
        out = jsonable(
            {
                "a": np.float64(1.5),
                "b": float("nan"),
                "c": np.array([1.0, np.nan, np.inf]),
                "d": np.int64(3),
                "e": [np.float32(2.0), {"f": -np.inf}],
            }
        )
        assert out == {"a": 1.5, "b": None, "c": [1.0, None, None],
                       "d": 3, "e": [2.0, {"f": None}]}
        import json
        json.dumps(out, allow_nan=False)  # strict-JSON safe


class TestStudyAnalytics:
    def _study(self, storage=None, n=40, name="an"):
        s = hpo.create_study(
            study_name=name, storage=storage, sampler=hpo.RandomSampler(seed=4)
        )
        s.optimize(
            lambda t: (t.suggest_float("x", -3, 3)) ** 2 + t.suggest_float("y", 0, 1),
            n_trials=n,
        )
        return s

    def test_views_cached_until_new_trial(self):
        s = self._study()
        sa = StudyAnalytics(s)
        v1 = sa.views()
        assert sa.views() is v1  # same object: version-cache hit
        s.optimize(lambda t: t.suggest_float("x", -3, 3) ** 2
                   + t.suggest_float("y", 0, 1), n_trials=1)
        v2 = sa.views()
        assert v2 is not v1
        assert v2["n_finished"] == v1["n_finished"] + 1

    def test_delta_rows_incremental(self):
        s = self._study(n=10)
        sa = StudyAnalytics(s)
        d = sa.delta_rows(-1)
        assert len(d["rows"]) == 10 and d["last_number"] == 9
        assert [r["number"] for r in d["rows"]] == list(range(10))
        s.optimize(lambda t: t.suggest_float("x", -3, 3) ** 2
                   + t.suggest_float("y", 0, 1), n_trials=3)
        d2 = sa.delta_rows(d["last_number"])
        assert [r["number"] for r in d2["rows"]] == [10, 11, 12]
        for r in d2["rows"]:
            assert set(r["params"]) == {"x", "y"}
            assert r["state"] == "COMPLETE"
            assert len(r["values"]) == 1

    def test_remote_vs_inmemory_delta_equivalence(self):
        """Seeded study through a real server == same study inmemory, row for
        row (the wire adds nothing and loses nothing)."""
        local = self._study(hpo.InMemoryStorage(), n=25, name="eq")
        with hpo.StorageServer(hpo.InMemoryStorage()) as server:
            remote = self._study(hpo.RemoteStorage(server.url), n=25, name="eq")
            d_local = StudyAnalytics(local).delta_rows(-1)
            d_remote = StudyAnalytics(remote).delta_rows(-1)
        assert d_local == d_remote

    def test_poller_revision_gating(self):
        storage = hpo.InMemoryStorage()
        s = self._study(storage, n=3)
        p = RevisionPoller(storage, s._study_id)
        assert p.poll() is True  # first poll always reports change
        assert p.poll() is False
        assert p.poll() is False
        s.optimize(lambda t: t.suggest_float("x", -3, 3) ** 2
                   + t.suggest_float("y", 0, 1), n_trials=1)
        assert p.poll() is True
        assert p.poll() is False
        assert p.ticks == 5 and p.changes == 2

    def test_mo_views(self):
        s = hpo.create_study(
            directions=["minimize", "maximize"], sampler=hpo.RandomSampler(seed=2)
        )
        s.optimize(
            lambda t: (t.suggest_float("x", 0, 1), t.suggest_float("y", 0, 1)),
            n_trials=20,
        )
        v = StudyAnalytics(s).views()
        assert len(v["history"]) == 2
        # history[1] is maximize: best is nondecreasing
        best = v["history"][1]["best"]
        assert all(b2 >= b1 for b1, b2 in zip(best, best[1:]))
        assert v["pareto"] is not None
        assert set(v["pareto"]["front_numbers"]) <= set(v["pareto"]["numbers"])
        assert sorted(v["importance"]["fanova"]) == ["0", "1"]
