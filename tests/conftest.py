"""Shared fixtures.  NOTE: XLA_FLAGS / device-count overrides are NOT set
here — smoke tests must see the real single CPU device; multi-device tests
spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running chaos/storm tests")


@pytest.fixture
def tmp_sqlite(tmp_path):
    return f"sqlite:///{tmp_path}/study.db"


@pytest.fixture
def tmp_journal(tmp_path):
    return f"journal://{tmp_path}/study.journal"
