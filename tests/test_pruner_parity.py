"""Decision parity: the vectorized pruner stack must produce bit-identical
prune decisions to the frozen scalar implementations in ``pruners/_legacy.py``
across randomized studies — dense and sparse step grids, NaN reports, both
directions, every finished/live state mix — and the fused
``report_and_prune`` storage path must agree with both."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core.frozen import TrialState
from repro.core.pruners import pruner_from_spec
from repro.core.pruners._legacy import (
    LegacyHyperbandPruner,
    LegacyMedianPruner,
    LegacyPatientPruner,
    LegacyPercentilePruner,
    LegacySuccessiveHalvingPruner,
    LegacyThresholdPruner,
)


def _build_random_study(seed, direction, sparse, with_nan, n_trials=30, n_steps=12):
    """A study whose trials reported random (possibly NaN) values over dense
    or sparse step grids and ended in a random state."""
    study = hpo.create_study(direction=direction)
    storage, sid = study._storage, study._study_id
    rng = np.random.RandomState(seed)
    for _ in range(n_trials):
        tid = storage.create_new_trial(sid)
        if sparse:
            size = rng.randint(1, n_steps + 1)
            steps = sorted(rng.choice(np.arange(1, 3 * n_steps), size=size, replace=False))
        else:
            steps = range(1, rng.randint(2, n_steps + 2))
        last = None
        for s in steps:
            v = float(rng.randn())
            if with_nan and rng.rand() < 0.15:
                v = float("nan")
            storage.set_trial_intermediate_value(tid, int(s), v)
            last = v
        state = TrialState(int(rng.choice(
            [int(TrialState.COMPLETE), int(TrialState.PRUNED),
             int(TrialState.RUNNING), int(TrialState.FAIL)],
            p=[0.45, 0.25, 0.2, 0.1],
        )))
        if state == TrialState.COMPLETE:
            storage.set_trial_state_values(
                tid, state, [last if last == last else 0.0]
            )
        elif state != TrialState.RUNNING:
            storage.set_trial_state_values(tid, state)
    return study


def _truncated(frozen, step):
    """The frozen trial as it looked when ``step`` was its latest report."""
    t = frozen.copy()
    t.intermediate_values = {s: v for s, v in frozen.intermediate_values.items() if s <= step}
    return t


PRUNER_PAIRS = [
    (
        "median",
        lambda: hpo.MedianPruner(n_startup_trials=2),
        lambda: LegacyMedianPruner(n_startup_trials=2),
    ),
    (
        "percentile",
        lambda: hpo.PercentilePruner(25.0, n_startup_trials=1, n_warmup_steps=2, interval_steps=2),
        lambda: LegacyPercentilePruner(25.0, n_startup_trials=1, n_warmup_steps=2, interval_steps=2),
    ),
    (
        "asha",
        lambda: hpo.SuccessiveHalvingPruner(1, 2, 0),
        lambda: LegacySuccessiveHalvingPruner(1, 2, 0),
    ),
    (
        "asha-s1",
        lambda: hpo.SuccessiveHalvingPruner(2, 4, 1),
        lambda: LegacySuccessiveHalvingPruner(2, 4, 1),
    ),
    (
        "hyperband",
        lambda: hpo.HyperbandPruner(1, 16, 2),
        lambda: LegacyHyperbandPruner(1, 16, 2),
    ),
    (
        "threshold",
        lambda: hpo.ThresholdPruner(lower=-1.5, upper=1.5, n_warmup_steps=1),
        lambda: LegacyThresholdPruner(lower=-1.5, upper=1.5, n_warmup_steps=1),
    ),
    (
        "patient-median",
        lambda: hpo.PatientPruner(hpo.MedianPruner(n_startup_trials=2), patience=2),
        lambda: LegacyPatientPruner(LegacyMedianPruner(n_startup_trials=2), patience=2),
    ),
]


@pytest.mark.parametrize("direction", ["minimize", "maximize"])
@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparse"])
@pytest.mark.parametrize("name,make_new,make_legacy", PRUNER_PAIRS,
                         ids=[p[0] for p in PRUNER_PAIRS])
def test_decisions_bit_identical(direction, sparse, name, make_new, make_legacy):
    for seed in (0, 1, 2):
        study = _build_random_study(seed, direction, sparse, with_nan=True)
        new, legacy = make_new(), make_legacy()
        checked = 0
        for frozen in study.get_trials(deepcopy=False):
            if frozen.state != TrialState.RUNNING:
                continue
            for step in sorted(frozen.intermediate_values):
                t = _truncated(frozen, step)
                got, want = new.prune(study, t), legacy.prune(study, t)
                assert got == want, (
                    f"{name} seed={seed} trial={frozen.number} step={step}: "
                    f"vectorized={got} legacy={want}"
                )
                checked += 1
        assert checked > 0  # the random mix always leaves RUNNING trials


@pytest.mark.parametrize("direction", ["minimize", "maximize"])
@pytest.mark.parametrize("name,make_new,make_legacy", PRUNER_PAIRS,
                         ids=[p[0] for p in PRUNER_PAIRS])
def test_fused_report_path_matches_legacy(direction, name, make_new, make_legacy):
    """`trial.report()` + `should_prune()` over the fused storage op must
    agree with the frozen scalar pruner evaluated on the same history."""
    study = _build_random_study(7, direction, sparse=False, with_nan=False)
    study.pruner = make_new()
    legacy = make_legacy()
    rng = np.random.RandomState(11)
    trial = study.ask()
    for step in range(1, 9):
        v = float(rng.randn())
        trial.report(v, step)
        fused = trial.should_prune()
        frozen = study._storage.get_trial(trial._trial_id)
        assert fused == legacy.prune(study, frozen), f"{name} step={step}"


def test_spec_round_trip_rebuilds_equivalent_pruners():
    for _, make_new, _ in PRUNER_PAIRS:
        pruner = make_new()
        spec = pruner.spec()
        assert spec is not None
        rebuilt = pruner_from_spec(spec)
        # Median rebuilds as its Percentile base class — same decisions
        assert isinstance(rebuilt, type(pruner)) or isinstance(pruner, type(rebuilt))
        assert rebuilt.spec() == spec
    assert pruner_from_spec({"name": "nop"}).spec() == {"name": "nop"}


def test_builtin_subclass_override_is_not_bypassed_by_fusion():
    """A subclass of a built-in pruner must not ship the parent's spec: the
    fused path would rebuild the plain built-in server-side and silently skip
    the override."""

    class Always(hpo.MedianPruner):
        def prune(self, study, trial):
            return True

    pruner = Always(n_startup_trials=0)
    assert pruner.spec() is None  # subclass -> no fusion
    study = hpo.create_study(pruner=pruner)
    t = study.ask()
    t.report(0.0, 1)  # a MedianPruner would never prune the only trial
    assert t.should_prune()  # the override decides, client-side


def test_custom_pruner_without_spec_falls_back_unfused():
    class Custom(hpo.BasePruner):
        def __init__(self):
            self.calls = 0

        def prune(self, study, trial):
            self.calls += 1
            return trial.last_step is not None and trial.last_step >= 3

    study = hpo.create_study(pruner=Custom())
    t = study.ask()
    t.report(1.0, 1)
    assert not t.should_prune()
    t.report(1.0, 3)
    assert t.should_prune()
    assert study.pruner.calls == 2  # evaluated client-side, not fused
