"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, asserting output shapes + finite values (the assignment's smoke gate)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (
    count_active_params,
    count_params,
    forward,
    init_cache,
    init_model_params,
    logits_from_hidden,
    loss_fn,
)
from repro.serve import make_decode_step, make_prefill_step
from repro.train import TrainConfig
from repro.train.train_loop import make_optimizer_for, make_train_step

ARCHS = list(configs.ARCH_IDS)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.modality == "audio":
        toks = rng.randint(0, cfg.vocab, (B, cfg.num_codebooks, S))
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.modality == "vlm":
        S_txt = S - cfg.img_tokens
        toks = rng.randint(0, cfg.vocab, (B, S_txt))
        img = (rng.randn(B, cfg.img_tokens, cfg.d_model) * 0.02).astype(np.float32)
        return {
            "tokens": jnp.asarray(toks),
            "image_embeds": jnp.asarray(img),
            "labels": jnp.asarray(rng.randint(0, cfg.vocab, (B, S))),
        }
    toks = rng.randint(0, cfg.vocab, (B, S))
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    # forward: final hidden + logits shapes
    x, _, aux = forward(params, cfg, batch, mode="train")
    B, S = batch["labels"].shape[0], batch["labels"].shape[-1]
    assert x.shape[0] == B and x.shape[1] == S and x.shape[2] == cfg.d_model
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    if cfg.modality == "audio":
        assert logits.shape == (B, cfg.num_codebooks, 1, cfg.vocab)
    else:
        assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    # one full train step (loss + grads + optimizer update)
    opt = make_optimizer_for(cfg, TrainConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    new_params, _, metrics = step(params, opt_state, jnp.int32(0), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually changed
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))), params, new_params),
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = configs.get_smoke_config(arch)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, moe_capacity=8.0)  # no capacity drops
    params = init_model_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S, seed=3)

    x, _, _ = forward(params, cfg, batch, mode="train")
    full_logits = np.asarray(logits_from_hidden(params, cfg, x))

    if cfg.modality == "audio":
        pre = {"tokens": batch["tokens"][:, :, : S - 1]}
        last = batch["tokens"][:, :, S - 1 : S]
    elif cfg.modality == "vlm":
        pre = {
            "tokens": batch["tokens"][:, : batch["tokens"].shape[1] - 1],
            "image_embeds": batch["image_embeds"],
        }
        last = batch["tokens"][:, -1:]
    else:
        pre = {"tokens": batch["tokens"][:, : S - 1]}
        last = batch["tokens"][:, S - 1 :]

    cache = init_cache(cfg, B, 32)
    logits_p, cache = jax.jit(make_prefill_step(cfg))(params, pre, cache)
    logits_d, _ = jax.jit(make_decode_step(cfg))(params, last, cache, S - 1)

    if cfg.modality == "audio":
        errp = np.abs(np.asarray(logits_p)[:, :, 0] - full_logits[:, :, S - 2]).max()
        errd = np.abs(np.asarray(logits_d)[:, :, 0] - full_logits[:, :, S - 1]).max()
    else:
        errp = np.abs(np.asarray(logits_p)[:, 0] - full_logits[:, S - 2]).max()
        errd = np.abs(np.asarray(logits_d)[:, 0] - full_logits[:, S - 1]).max()
    # bf16 accumulation differences between the chunked-parallel and recurrent
    # paths bound the tolerance (xlstm/deepseek are the widest)
    assert errp < 8e-2, f"{arch} prefill mismatch {errp}"
    assert errd < 8e-2, f"{arch} decode mismatch {errd}"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts(arch):
    """The FULL configs match their nameplate sizes (exercised abstractly —
    no allocation)."""
    cfg = configs.get_config(arch)
    n = count_params(cfg)
    expected = {
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "gemma2-9b": (8.5e9, 10.5e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "smollm-135m": (0.1e9, 0.17e9),
        "xlstm-1.3b": (1.0e9, 2.0e9),
        "zamba2-1.2b": (0.9e9, 1.5e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "llava-next-34b": (30e9, 38e9),
        "musicgen-medium": (1.1e9, 1.8e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B params"
    na = count_active_params(cfg)
    if arch == "qwen3-moe-235b-a22b":
        assert 18e9 <= na <= 26e9  # "a22b"
    if arch == "deepseek-v2-lite-16b":
        assert 2e9 <= na <= 4e9  # ~2.7B active


def test_loss_decreases_on_tiny_model():
    cfg = configs.get_smoke_config("smollm-135m")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer_for(cfg, TrainConfig(lr=5e-3, warmup_steps=2, total_steps=30))
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    batch = make_batch(cfg, B=4, S=64)
    losses = []
    for i in range(30):
        params, opt_state, m = step(params, opt_state, jnp.int32(i), batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_moe_sort_vs_einsum_dispatch():
    """The two MoE dispatch modes agree when no tokens are dropped."""
    import dataclasses

    base = configs.get_smoke_config("qwen3-moe-235b-a22b")
    cfg_e = dataclasses.replace(base, moe_dispatch="einsum", moe_capacity=8.0)
    cfg_s = dataclasses.replace(base, moe_dispatch="sort", moe_capacity=8.0)
    params = init_model_params(cfg_e, jax.random.PRNGKey(0))
    batch = make_batch(cfg_e, B=2, S=16)
    le, _ = loss_fn(params, cfg_e, batch)
    ls, _ = loss_fn(params, cfg_s, batch)
    assert abs(float(le) - float(ls)) < 2e-2, (float(le), float(ls))


def test_gradient_flows_through_every_param():
    cfg = configs.get_smoke_config("zamba2-1.2b")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2, S=32)
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    zero_leaves = []
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        if not np.any(np.asarray(g)):
            zero_leaves.append(jax.tree_util.keystr(path))
    # conv bias / gates can be legitimately tiny but not ALL zero; allow a few
    assert len(zero_leaves) <= 2, zero_leaves
