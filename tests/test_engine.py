"""Device-resident engine: policy unit tests + numpy/jit/Pallas parity.

Covers the shared engine policy in ``kernels/ops.py`` (pow2 padding, trace
registry, ``resolve_engine``), randomized agreement between the numpy, jitted
and Pallas(interpret) paths for Parzen scoring, dominance and hypervolume
contributions, pinned trace counts proving pow2 bucketing bounds retracing,
and the loud-fallback contract (``sampler.engine_fallbacks`` counter +
once-per-reason log) when a requested device engine cannot run.
"""

import logging

import numpy as np
import pytest

import repro.core as hpo
from repro.core import moo, telemetry
from repro.core.frozen import TrialState
from repro.core.samplers.tpe import _ParzenEstimator, _pad_est, _score_numpy
from repro.core.storage import InMemoryStorage
from repro.kernels import ops as kops

jax = pytest.importorskip("jax")


# -- shared policy helpers (kernels/ops.py) -----------------------------------------


class TestOpsPolicy:
    def test_pad_pow2_len(self):
        assert kops.pad_pow2_len(0) == 8
        assert kops.pad_pow2_len(1) == 8
        assert kops.pad_pow2_len(8) == 8
        assert kops.pad_pow2_len(9) == 16
        assert kops.pad_pow2_len(1000) == 1024
        assert kops.pad_pow2_len(3, min_pad=2) == 4

    def test_pad_pow2_vec(self):
        v = np.arange(5, dtype=float)
        out = kops.pad_pow2_vec(v, -np.inf)
        assert out.shape == (8,)
        assert np.array_equal(out[:5], v)
        assert np.all(np.isneginf(out[5:]))
        # already a pow2 bucket: returned untouched (same object)
        v8 = np.arange(8, dtype=float)
        assert kops.pad_pow2_vec(v8, 0.0) is v8

    def test_pad_pow2_rows(self):
        A = np.arange(6, dtype=float).reshape(3, 2)
        out = kops.pad_pow2_rows(A, np.inf)
        assert out.shape == (8, 2)
        assert np.array_equal(out[:3], A)
        assert np.all(np.isinf(out[3:]))

    def test_validate_engine(self):
        for eng in ("auto", "numpy", "jax", "pallas"):
            assert kops.validate_engine(eng) == eng
        with pytest.raises(ValueError):
            kops.validate_engine("cuda")

    def test_resolve_engine(self):
        # explicit engines pass through regardless of work
        assert kops.resolve_engine("numpy", 10**9, 1) == "numpy"
        assert kops.resolve_engine("jax", 0, 10**9) == "jax"
        assert kops.resolve_engine("pallas", 0, 10**9) == "pallas"
        # auto: numpy below the threshold, device above it
        assert kops.resolve_engine("auto", 100, 1000) == "numpy"
        above = kops.resolve_engine("auto", 2000, 1000)
        assert above in ("jax", "pallas")
        # ceiling caps auto off-TPU (memory-bound reductions)
        if jax.default_backend() != "tpu":
            assert kops.resolve_engine("auto", 2000, 1000, ceiling=1500) == "numpy"

    def test_trace_registry(self):
        kops.reset_traces("test.key")
        assert kops.trace_count("test.key") == 0
        kops.bump_trace("test.key")
        kops.bump_trace("test.key")
        assert kops.trace_count("test.key") == 2
        kops.reset_traces("test.key")
        assert kops.trace_count("test.key") == 0


# -- Parzen scoring parity ----------------------------------------------------------


def _mk_est(rng, n_obs, low=-3.0, high=3.0):
    obs = rng.uniform(low, high, n_obs)
    w = rng.uniform(0.5, 1.0, n_obs)
    return _ParzenEstimator(obs, low, high, w, True, 1.0, True)


def _sampler(engine):
    return hpo.TPESampler(seed=0, engine=engine)


class TestParzenParity:
    @pytest.mark.parametrize("n_below,n_above", [(3, 20), (25, 200), (7, 8)])
    def test_numpy_jax_pallas_agree(self, n_below, n_above):
        rng = np.random.RandomState(n_below * 100 + n_above)
        l_est, g_est = _mk_est(rng, n_below), _mk_est(rng, n_above)
        cands = rng.uniform(-3, 3, 64)
        ref = _sampler("numpy")._score_inner(l_est, g_est, cands)
        for engine in ("jax", "pallas"):
            out = _sampler(engine)._score_inner(l_est, g_est, cands)
            np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-4)

    def test_pow2_padding_is_invisible(self):
        """-inf log_norm fills contribute exp(-inf)=0: padded == unpadded."""
        rng = np.random.RandomState(7)
        l_est, g_est = _mk_est(rng, 5), _mk_est(rng, 13)
        cands = rng.uniform(-3, 3, 32)
        padded = _pad_est(l_est)
        n = len(l_est.mus)  # 5 observations + the wide prior component
        assert len(padded[0]) == 8 and np.isneginf(padded[2][n:]).all()
        direct = _score_numpy(
            cands,
            l_est.mus, l_est.sigmas, l_est._log_norm,
            g_est.mus, g_est.sigmas, g_est._log_norm,
        )
        via_pad = _score_numpy(cands, *padded, *_pad_est(g_est))
        np.testing.assert_allclose(via_pad, direct, atol=1e-12)

    def test_score_table_matches_direct_scoring(self):
        """The device score table is the acquisition on a dense grid; interp
        at arbitrary candidates stays within the magic_clip smoothness
        bound (~1e-4 in log space)."""
        rng = np.random.RandomState(3)
        low, high = -3.0, 3.0
        l_est, g_est = _mk_est(rng, 30), _mk_est(rng, 400)
        s = _sampler("jax")
        cache = {}
        for _ in range(2):  # table builds on the second score at one version
            s._maybe_build_table(cache, "x", l_est, g_est, low, high)
        xs, ys = cache[("x", "table")]
        assert len(xs) == kops.SCORE_TABLE_SIZE
        np.testing.assert_allclose(
            ys, s._score_inner(l_est, g_est, xs), atol=2e-4, rtol=1e-4
        )
        cands = rng.uniform(low, high, 256)
        direct = _sampler("numpy")._score_inner(l_est, g_est, cands)
        np.testing.assert_allclose(np.interp(cands, xs, ys), direct, atol=5e-3)

    def test_engines_pick_same_candidates_end_to_end(self):
        results = {}
        for engine in ("numpy", "jax", "pallas"):
            s = hpo.create_study(sampler=hpo.TPESampler(seed=11, engine=engine))
            s.optimize(lambda t: t.suggest_float("x", -4, 4) ** 2, n_trials=14)
            results[engine] = [t.params["x"] for t in s.trials]
        np.testing.assert_allclose(results["jax"], results["numpy"], rtol=1e-5)
        np.testing.assert_allclose(results["pallas"], results["numpy"], rtol=1e-5)


# -- dominance parity ---------------------------------------------------------------


class TestDominanceParity:
    @pytest.mark.parametrize("n,m", [(17, 2), (33, 3), (64, 5)])
    def test_numpy_jax_agree(self, n, m):
        rng = np.random.RandomState(n * m)
        V = rng.randn(n, m)
        # duplicated + dominated rows exercise ties
        V[3] = V[0]
        V[5] = V[1] + 1.0
        ref = moo.dominance_matrix(V)
        assert np.array_equal(moo.dominance_matrix(V, engine="jax"), ref)
        ranks_np = moo.nondomination_ranks(V)
        ranks_jax = moo.nondomination_ranks(V, engine="jax")
        assert np.array_equal(ranks_np, ranks_jax)

    def test_nan_rows_agree(self):
        rng = np.random.RandomState(5)
        V = rng.randn(21, 3)
        V[2, 1] = np.nan
        V[9] = np.nan
        assert np.array_equal(
            moo.dominance_matrix(V, engine="jax"), moo.dominance_matrix(V)
        )

    def test_both_orientations_agree(self):
        """Maximize columns are handled upstream by loss_matrix: parity must
        hold on the sign-flipped matrix too."""
        from repro.core.frozen import StudyDirection

        rng = np.random.RandomState(8)
        V = rng.randn(25, 2)
        for dirs in (
            [StudyDirection.MINIMIZE, StudyDirection.MAXIMIZE],
            [StudyDirection.MAXIMIZE, StudyDirection.MAXIMIZE],
        ):
            L = moo.loss_matrix(V, dirs)
            assert np.array_equal(
                moo.pareto_front_mask(L, engine="jax"), moo.pareto_front_mask(L)
            )


# -- hypervolume parity -------------------------------------------------------------


class TestHypervolumeParity:
    def test_mc_engines_agree(self):
        rng = np.random.RandomState(0)
        pts = rng.rand(24, 6)
        ref = np.full(6, 1.1)
        outs = {}
        for engine in ("numpy", "jax", "pallas"):
            est = moo.HypervolumeEstimator(method="mc", n_samples=4096, engine=engine)
            outs[engine] = (est.hypervolume(pts, ref), est.contributions(pts, ref))
        for engine in ("jax", "pallas"):
            assert abs(outs[engine][0] - outs["numpy"][0]) < 1e-4
            np.testing.assert_allclose(outs[engine][1], outs["numpy"][1], atol=1e-5)

    def test_mc_tracks_exact(self):
        rng = np.random.RandomState(1)
        pts = rng.rand(30, 3)
        ref = np.full(3, 1.1)
        est = moo.HypervolumeEstimator(method="mc", n_samples=100_000)
        hv_exact = moo.hypervolume(pts, ref)
        assert abs(est.hypervolume(pts, ref) - hv_exact) / hv_exact < 0.05
        front = pts[moo.pareto_front_mask(pts)]
        c_exact = moo.hypervolume_contributions(front, ref)
        c_mc = est.contributions(front, ref)
        np.testing.assert_allclose(c_mc, c_exact, atol=5e-3)

    def test_auto_method_switch(self):
        est = moo.HypervolumeEstimator()
        assert est._use_exact(4) and not est._use_exact(5)
        # m <= 4 via the estimator is bit-identical to the exact function
        rng = np.random.RandomState(2)
        pts = rng.rand(12, 3)
        ref = np.full(3, 1.1)
        assert est.hypervolume(pts, ref) == moo.hypervolume(pts, ref)

    def test_dominated_and_outside_points_contribute_zero(self):
        est = moo.HypervolumeEstimator(method="mc", n_samples=8192)
        pts = np.asarray([
            [0.2, 0.2, 0.2, 0.2, 0.2],
            [0.5, 0.5, 0.5, 0.5, 0.5],  # dominated by row 0
            [2.0, 2.0, 2.0, 2.0, 2.0],  # outside the reference box
        ])
        ref = np.ones(5)
        contrib = est.contributions(pts, ref)
        assert contrib[0] > 0.0
        assert contrib[1] == 0.0  # exclusive region of a dominated point is empty
        assert contrib[2] == 0.0


# -- pinned trace counts ------------------------------------------------------------


class TestTraceBounds:
    def test_parzen_kernel_traces_bounded(self):
        from repro.kernels.parzen import parzen_score

        rng = np.random.RandomState(0)
        cands = rng.uniform(-3, 3, 512).astype(np.float32)
        before = kops.trace_count("pallas.parzen")
        for n in range(20, 30):  # one pow2 bucket: at most one fresh trace
            est = _mk_est(np.random.RandomState(n), n)
            parzen_score(cands, *_pad_est(est), *_pad_est(est), interpret=True)
        assert kops.trace_count("pallas.parzen") - before <= 1

    def test_mc_hv_kernel_traces_bounded(self):
        from repro.kernels.hypervolume import mc_hv_counts

        rng = np.random.RandomState(0)
        samples = rng.rand(2048, 4).astype(np.float32)
        before = kops.trace_count("pallas.mc_hv")
        for n in range(17, 27):  # all pad to 32 points
            mc_hv_counts(rng.rand(n, 4).astype(np.float32), samples, interpret=True)
        assert kops.trace_count("pallas.mc_hv") - before <= 1

    def test_gemm_scorer_traces_bounded(self):
        import repro.core.samplers.tpe as tpe_mod

        tpe_mod._jax_gemm_score = None  # fresh jit cache for a clean count
        kops.reset_traces("tpe.joint")
        sampler = hpo.TPESampler(seed=2, multivariate=True, engine="jax",
                                 n_startup_trials=8)
        study = hpo.create_study(sampler=sampler)

        def obj(t):
            x = t.suggest_float("x", -3, 3)
            c = t.suggest_categorical("c", ["a", "b"])
            return x * x + (0.0 if c == "a" else 0.5)

        study.optimize(obj, n_trials=12)
        for _ in range(6):  # observation count sweeps within pow2 buckets
            wave = study.ask(4)
            study.tell_batch([(t, obj(t)) for t in wave])
        assert 0 < kops.trace_count("tpe.joint") <= 6


# -- loud fallback ------------------------------------------------------------------


class TestEngineFallback:
    def test_fallback_counts_and_logs_once(self, monkeypatch, caplog):
        from repro.core.log import reset_once

        monkeypatch.setattr(kops, "_jax_probe", False)  # jax "not importable"
        telemetry.enable()
        try:
            telemetry.reset()
            reset_once()
            sampler = hpo.TPESampler(seed=0, engine="jax", n_startup_trials=3)
            study = hpo.create_study(sampler=sampler)
            with caplog.at_level(logging.WARNING, logger="repro.core.samplers.tpe"):
                study.optimize(lambda t: t.suggest_float("x", -3, 3) ** 2, n_trials=8)
            assert telemetry.counter("sampler.engine_fallbacks").value >= 1
            warns = [r for r in caplog.records if "downgraded to numpy" in r.message]
            assert len(warns) == 1  # once per (sampler, reason), not per ask
            # the study still optimizes on the numpy path
            assert np.isfinite(study.best_value)
        finally:
            telemetry.disable()

    def test_mixed_categorical_groups_keep_device_path(self):
        """Regression: categorical dims used to silently disable the joint
        device scorer; the gemm one-hot encoding keeps it on with zero
        fallbacks."""
        import repro.core.samplers.tpe as tpe_mod

        telemetry.enable()
        try:
            telemetry.reset()
            tpe_mod._jax_gemm_score = None
            kops.reset_traces("tpe.joint")
            sampler = hpo.TPESampler(seed=1, multivariate=True, engine="jax",
                                     n_startup_trials=5)
            study = hpo.create_study(sampler=sampler)

            def obj(t):
                x = t.suggest_float("x", -3, 3)
                c = t.suggest_categorical("c", ["a", "b", "cc"])
                return x * x + {"a": 0.0, "b": 1.0, "cc": 2.0}[c]

            study.optimize(obj, n_trials=8)
            wave = study.ask(6)
            study.tell_batch([(t, obj(t)) for t in wave])
            assert kops.trace_count("tpe.joint") >= 1  # device path ran
            assert telemetry.counter("sampler.engine_fallbacks").value == 0
        finally:
            telemetry.disable()


# -- engine plumbing ----------------------------------------------------------------


class TestEnginePlumbing:
    def test_study_engine_kwarg_reaches_default_sampler(self):
        s = hpo.create_study(engine="numpy")
        assert s.sampler._engine == "numpy"
        with pytest.raises(ValueError):
            hpo.create_study(study_name="bad-engine", engine="cuda")

    def test_explicit_sampler_keeps_its_engine(self):
        s = hpo.create_study(sampler=hpo.TPESampler(engine="numpy"), engine="jax")
        assert s.sampler._engine == "numpy"

    def test_jit_scoring_alias(self):
        assert hpo.TPESampler(jit_scoring=True)._engine == "jax"
        assert hpo.TPESampler()._engine == "auto"
        assert hpo.NSGAIISampler(engine="numpy")._engine == "numpy"


# -- WAITING index (Study.ask fast path) --------------------------------------------


class TestWaitingIndex:
    def test_index_matches_scan(self):
        storage = InMemoryStorage()
        study = hpo.create_study(storage=storage)
        for i in range(5):
            study.enqueue_trial({"x": float(i)})
        trial = study.ask()  # claims the oldest enqueued trial
        trial.suggest_float("x", 0, 10)

        waiting = storage.get_all_trials(
            study._study_id, deepcopy=False, states=(TrialState.WAITING,)
        )
        scan = [
            t for t in storage.get_all_trials(study._study_id, deepcopy=False)
            if t.state == TrialState.WAITING
        ]
        assert [t.number for t in waiting] == [t.number for t in scan]
        assert len(waiting) == 4
        # the mixed-state query still takes the scan path and stays consistent
        both = storage.get_all_trials(
            study._study_id, deepcopy=False,
            states=(TrialState.WAITING, TrialState.RUNNING),
        )
        assert len(both) == 5

    def test_enqueued_order_preserved(self):
        """optimize() claims enqueued trials oldest-first through the
        WAITING index and replays their fixed params."""
        study = hpo.create_study()
        for i in range(3):
            study.enqueue_trial({"x": float(i)})
        study.optimize(lambda t: t.suggest_float("x", 0, 10), n_trials=3)
        assert [t.values[0] for t in study.trials] == [0.0, 1.0, 2.0]
