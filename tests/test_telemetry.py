"""Telemetry backbone: registry semantics, spans, trial event trace, and the
server metrics surface (ISSUE 6 acceptance)."""

import threading
import time

import pytest

import repro.core as hpo
from repro.core import telemetry
from repro.core.telemetry import (
    EV_COMPLETED,
    EV_CREATED,
    EV_PRUNED,
    EV_REPORTED,
    EVENT_KINDS,
    Counter,
    Histogram,
    MetricsRegistry,
    TrialEventLog,
    _iter_event_tuples,
)


@pytest.fixture(autouse=True)
def _clean_registry():
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


# -- instruments ---------------------------------------------------------------


class TestInstruments:
    def test_counter_threadsafe(self):
        c = Counter("c")
        threads = [
            threading.Thread(target=lambda: [c.inc() for _ in range(1000)])
            for _ in range(8)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 8000

    def test_histogram_percentiles(self):
        h = Histogram("h")
        for ms in range(1, 101):  # 1ms .. 100ms uniform
            h.observe(ms / 1e3)
        s = h.summary()
        assert s["count"] == 100
        assert s["min"] == pytest.approx(1e-3)
        assert s["max"] == pytest.approx(0.1)
        # uniform 1..100ms: p50 ~ 50ms, p95 ~ 95ms, p99 ~ 99ms within one
        # geometric bucket (10/decade -> ~26% wide) of the true value
        assert 0.03 < s["p50"] < 0.07
        assert 0.07 < s["p95"] < 0.1
        assert 0.08 < s["p99"] <= 0.1
        assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]

    def test_histogram_empty_and_overflow(self):
        h = Histogram("h")
        assert h.summary()["p99"] == 0.0
        h.observe(1e9)  # beyond the top bound -> overflow bucket
        assert h.summary()["p99"] == pytest.approx(1e9)
        assert h.summary()["max"] == pytest.approx(1e9)

    def test_gauge(self):
        r = MetricsRegistry()
        g = r.gauge("g")
        g.set(3.0)
        g.add(-1.0)
        assert g.value == 2.0


# -- registry / module-level helpers ------------------------------------------


class TestRegistry:
    def test_disabled_is_noop(self):
        assert not telemetry.enabled()
        telemetry.inc("x")
        telemetry.observe("y", 0.5)
        with telemetry.span("z"):
            pass
        snap = telemetry.snapshot()
        assert snap == {"counters": {}, "gauges": {}, "histograms": {}}

    def test_disabled_span_is_shared_noop(self):
        s1 = telemetry.span("a")
        s2 = telemetry.span("b")
        assert s1 is s2  # one shared _NOOP object, no allocation per call

    def test_enabled_records(self):
        telemetry.enable()
        telemetry.inc("ops", 3)
        telemetry.inc("ops")
        with telemetry.span("lat"):
            time.sleep(0.01)
        snap = telemetry.snapshot()
        assert snap["counters"]["ops"] == 4
        h = snap["histograms"]["lat"]
        assert h["count"] == 1
        assert 0.005 < h["mean"] < 1.0  # the sleep is timed, roughly

    def test_reset(self):
        telemetry.enable()
        telemetry.inc("x")
        telemetry.reset()
        assert telemetry.snapshot()["counters"] == {}

    def test_snapshot_json_safe(self):
        import json

        telemetry.enable()
        telemetry.inc("a")
        telemetry.set_gauge("b", 1.5)
        telemetry.observe("c", 0.01)
        json.dumps(telemetry.snapshot())  # must not raise

    def test_worker_context(self):
        default = telemetry.worker_id()
        assert ":" in default
        telemetry.set_worker_context("1.2.3.4:555")
        try:
            assert telemetry.worker_id() == "1.2.3.4:555"
        finally:
            telemetry.set_worker_context(None)
        assert telemetry.worker_id() == default


# -- trial event log -----------------------------------------------------------


class TestEventLog:
    def test_append_and_rows(self):
        log = TrialEventLog()
        log.append(EV_CREATED, 0, worker="w0")
        log.append(EV_REPORTED, 0, step=3, worker="w0")
        log.append(EV_COMPLETED, 0, worker="w1")
        rows = log.rows()
        assert [r["event"] for r in rows] == ["created", "reported", "completed"]
        assert rows[1]["step"] == 3
        assert rows[0]["worker"] == "w0" and rows[2]["worker"] == "w1"
        # monotonic timestamps
        assert rows[0]["t_ns"] <= rows[1]["t_ns"] <= rows[2]["t_ns"]

    def test_growth_past_initial_capacity(self):
        log = TrialEventLog()
        for i in range(300):
            log.append(EV_CREATED, i, worker="w")
        assert len(log) == 300
        assert [r["number"] for r in log.rows()] == list(range(300))

    def test_incremental_snapshot(self):
        log = TrialEventLog()
        for i in range(5):
            log.append(EV_CREATED, i, worker="w")
        snap = log.snapshot(since=3)
        assert snap["since"] == 3 and snap["next"] == 5
        assert snap["number"] == [3, 4]
        # a since past the end is clamped, not an error
        assert log.snapshot(since=99)["kind"] == []

    def test_storage_hosts_event_log(self):
        st = hpo.InMemoryStorage()
        s = hpo.create_study(storage=st, pruner=hpo.NopPruner())

        def obj(t):
            t.suggest_float("x", 0, 1)
            t.report(1.0, 0)
            return 1.0

        s.optimize(obj, n_trials=3)
        snap = st.get_trial_events(s._study_id)
        kinds = [EVENT_KINDS[k] for k in snap["kind"]]
        assert kinds.count("created") == 3
        assert kinds.count("reported") == 3
        assert kinds.count("completed") == 3
        # delete_study drops the trace
        st.delete_study(s._study_id)
        assert st.get_trial_events(s._study_id)["kind"] == []


# -- remote round trip (acceptance) -------------------------------------------


def _run_seeded_study(storage):
    s = hpo.create_study(
        study_name="trace",
        storage=storage,
        sampler=hpo.RandomSampler(seed=7),
        pruner=hpo.MedianPruner(n_startup_trials=2, n_warmup_steps=0),
    )

    def obj(t):
        x = t.suggest_float("x", 0, 1)
        for step in range(3):
            t.report(x + step * 0.1, step)
            if t.should_prune():
                raise hpo.TrialPruned()
        return x

    s.optimize(obj, n_trials=12)
    return s._study_id


class TestRemoteRoundTrip:
    def test_event_trace_survives_remote_protocol(self):
        """The remote run must reconstruct the exact (event, number, step)
        sequence an inmemory run of the same seeded study produces."""
        mem = hpo.InMemoryStorage()
        local_sid = _run_seeded_study(mem)
        local = list(_iter_event_tuples(mem.get_trial_events(local_sid)))

        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            remote = hpo.RemoteStorage(server.url)
            remote_sid = _run_seeded_study(remote)
            wire = remote.get_trial_events(remote_sid)
        assert list(_iter_event_tuples(wire)) == local
        # worker ids on the server-recorded trace are the client peers
        assert all(w.count(":") == 1 for w in wire["workers"])

    def test_get_server_metrics_rpc(self):
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            remote = hpo.RemoteStorage(server.url)
            _run_seeded_study(remote)
            m = remote.get_server_metrics()
            m2 = server.get_server_metrics()
        assert m["frames_in"] > 0 and m["bytes_in"] > 0
        assert m["frames_out"] > 0 and m["bytes_out"] > 0
        methods = m["methods"]
        assert "create_new_trial" in methods
        row = methods["create_new_trial"]
        assert row["calls"] == 12 and row["errors"] == 0
        assert row["bytes_out"] > 0
        assert 0 <= row["p50"] <= row["p95"] <= row["p99"] <= row["max"]
        # the in-process accessor serves the same surface
        assert m2["methods"]["create_new_trial"]["calls"] == 12

    def test_client_rpc_spans_when_enabled(self):
        telemetry.enable()
        backend = hpo.InMemoryStorage()
        with hpo.StorageServer(backend) as server:
            remote = hpo.RemoteStorage(server.url)
            sid = remote.create_new_study(
                [hpo.StudyDirection.MINIMIZE], "spans"
            )
            for _ in range(3):
                remote.create_new_trial(sid)
        snap = telemetry.snapshot()
        assert snap["counters"]["client.frames_out"] >= 4
        assert snap["counters"]["client.bytes_out"] > 0
        assert snap["histograms"]["client.rpc.create_new_trial"]["count"] == 3

    def test_cached_storage_counters(self):
        telemetry.enable()
        st = hpo.CachedStorage(hpo.InMemoryStorage())
        sid = st.create_new_study([hpo.StudyDirection.MINIMIZE], "cc")
        tid = st.create_new_trial(sid)
        st.get_trial(tid)  # own RUNNING trial -> cache hit
        snap = telemetry.snapshot()
        assert snap["counters"].get("cached.get_trial.hit_own", 0) >= 1


# -- overhead guard ------------------------------------------------------------


def test_disabled_span_overhead_tiny():
    """The disabled span must be within an order of magnitude of a bare
    function call — the <2% production budget pinned by the benchmark."""
    n = 50_000
    t0 = time.perf_counter_ns()
    for _ in range(n):
        with telemetry.span("x"):
            pass
    per_call = (time.perf_counter_ns() - t0) / n
    assert per_call < 5_000  # ns; generous CI bound, typically ~250ns
