"""Checkpoint save/restore incl. resharding restore and trainer auto-resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import init_model_params
from repro.train import (
    CheckpointManager,
    SyntheticLM,
    TrainConfig,
    Trainer,
    restore_pytree,
    save_pytree,
)


def test_roundtrip_pytree(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
    }
    path = str(tmp_path / "ck.ckpt")
    save_pytree(path, tree, step=42)
    step, restored = restore_pytree(path, jax.eval_shape(lambda: tree))
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_manager_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, {"x": jnp.full((4,), float(step))}, blocking=True)
    assert mgr.all_steps() == [20, 30]
    step, tree = mgr.restore_latest({"x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert step == 30
    assert float(tree["x"][0]) == 30.0


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros((128, 128))})
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.ckpt")
    save_pytree(path, {"x": jnp.zeros((4,))}, 0)
    with pytest.raises(ValueError):
        restore_pytree(path, {"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_trainer_resume_continues_step_count(tmp_path):
    cfg = configs.get_smoke_config("smollm-135m")
    data = SyntheticLM(cfg, batch=2, seq=32, seed=0)
    t1 = Trainer(cfg, TrainConfig(total_steps=6, checkpoint_every=3, eval_every=2), data, workdir=str(tmp_path))
    t1.run()
    mgr = CheckpointManager(str(tmp_path))
    assert 6 in mgr.all_steps()
    # second trainer resumes from 6 and continues to 10
    t2 = Trainer(
        cfg, TrainConfig(total_steps=10, checkpoint_every=3, eval_every=2),
        SyntheticLM(cfg, batch=2, seq=32, seed=0), workdir=str(tmp_path),
    )
    res = t2.run()
    assert res["step"] == 10


def test_restore_under_different_sharding_subprocess(tmp_path):
    """Write a checkpoint with 1 device, restore sharded onto a 4-device mesh
    (elastic restart onto a different topology)."""
    import subprocess
    import sys

    cfg = configs.get_smoke_config("tinyllama-1.1b")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "p.ckpt")
    save_pytree(path, params, step=5)

    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {os.path.abspath('src')!r})
import jax, numpy as np
from repro import configs
from repro.models import abstract_params, params_logical
from repro.models.sharding import TRAIN_RULES, tree_shardings
from repro.train import restore_pytree

cfg = configs.get_smoke_config("tinyllama-1.1b")
mesh = jax.make_mesh((2, 2), ("data", "model"))
aps = abstract_params(cfg)
sh = tree_shardings(aps, params_logical(cfg), mesh, TRAIN_RULES)
step, params = restore_pytree({path!r}, aps, sh)
assert step == 5
leaf = jax.tree.leaves(params)[0]
assert len(leaf.sharding.device_set) >= 1
total = sum(float(np.sum(np.asarray(x, np.float64) != 0)) for x in jax.tree.leaves(params))
assert total > 0
print("RESHARD_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=240
    )
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
