"""Seeded sample-parity suite: the vectorized sampler stack (columnar
observation store + array codecs) must produce **bit-identical** samples to
the frozen pre-refactor scalar path (`repro.core.samplers._legacy`) under a
fixed seed.  Any divergence means the refactor changed sampling semantics,
not just its implementation."""

import numpy as np
import pytest

import repro.core as hpo
from repro.core.samplers import _legacy as legacy


def mixed_objective(trial):
    x = trial.suggest_float("x", -3, 3)
    lr = trial.suggest_float("lr", 1e-5, 1.0, log=True)
    n = trial.suggest_int("n", 1, 16, log=True)
    q = trial.suggest_float("q", 0.0, 1.0, step=0.25)
    k = trial.suggest_categorical("k", ["a", "b", "c"])
    extra = 0.0
    if trial.number % 3 == 0:  # conditional branch -> partial presence
        extra = trial.suggest_float("cond", 0, 1)
    return (
        x * x + abs(np.log10(lr) + 3) + 0.1 * n + q + (0.0 if k == "a" else 1.0) + extra
    )


def numeric_objective(trial):
    x = trial.suggest_float("x", -2, 2)
    y = trial.suggest_float("y", -2, 2)
    z = trial.suggest_int("z", 1, 32, log=True)
    return (1 - x) ** 2 + 100 * (y - x * x) ** 2 + 0.01 * z


def trace(sampler, objective, n_trials):
    study = hpo.create_study(sampler=sampler)
    study.optimize(objective, n_trials=n_trials)
    return [(t.params, t.values, t.state) for t in study.trials]


@pytest.mark.parametrize("seed", [0, 7, 42])
def test_tpe_parity(seed):
    new = trace(hpo.TPESampler(seed=seed, n_startup_trials=8), mixed_objective, 50)
    old = trace(legacy.LegacyTPESampler(seed=seed, n_startup_trials=8), mixed_objective, 50)
    assert new == old


def test_tpe_parity_consider_pruned():
    def objective(trial):
        x = trial.suggest_float("x", 0, 1)
        for i in range(3):
            trial.report(x + 0.1 * i, i)
            if x > 0.7 and i == 1:
                raise hpo.TrialPruned()
        return x

    new = trace(
        hpo.TPESampler(seed=9, n_startup_trials=5, consider_pruned_trials=True),
        objective, 40,
    )
    old = trace(
        legacy.LegacyTPESampler(seed=9, n_startup_trials=5, consider_pruned_trials=True),
        objective, 40,
    )
    assert new == old


def test_tpe_parity_maximize():
    def objective(trial):
        return -((trial.suggest_float("x", -3, 3) - 1) ** 2)

    def run(sampler):
        s = hpo.create_study(sampler=sampler, direction="maximize")
        s.optimize(objective, n_trials=30)
        return [(t.params, t.values) for t in s.trials]

    assert run(hpo.TPESampler(seed=5, n_startup_trials=6)) == run(
        legacy.LegacyTPESampler(seed=5, n_startup_trials=6)
    )


@pytest.mark.parametrize("seed", [3, 11])
def test_random_parity(seed):
    new = trace(hpo.RandomSampler(seed=seed), mixed_objective, 30)
    old = trace(legacy.LegacyRandomSampler(seed=seed), mixed_objective, 30)
    assert new == old


def test_grid_parity():
    grid = {"a": [1, 2, 3], "b": [10.0, 20.0]}

    def objective(trial):
        a = trial.suggest_int("a", 1, 3)
        b = trial.suggest_float("b", 10.0, 20.0)
        c = trial.suggest_float("c", 0, 1)  # off-grid -> uniform fallback
        return a * b + c

    new = trace(hpo.GridSampler(grid, seed=4), objective, 8)
    old = trace(legacy.LegacyGridSampler(grid, seed=4), objective, 8)
    assert new == old


def test_cmaes_parity():
    new = trace(hpo.CmaEsSampler(warmup_trials=10, seed=5), numeric_objective, 70)
    old = trace(
        legacy.LegacyCmaEsSampler(
            warmup_trials=10, seed=5,
            independent_sampler=legacy.LegacyRandomSampler(seed=5),
        ),
        numeric_objective, 70,
    )
    assert new == old


def test_tpe_cmaes_mixture_parity():
    new = trace(hpo.make_sampler("tpe+cmaes", seed=11), numeric_objective, 60)
    old = trace(
        legacy.LegacyCmaEsSampler(
            warmup_trials=40, seed=11,
            independent_sampler=legacy.LegacyTPESampler(seed=11),
        ),
        numeric_objective, 60,
    )
    assert new == old


def test_gp_parity():
    new = trace(hpo.GPSampler(seed=2, n_startup_trials=8), numeric_objective, 35)
    old = trace(legacy.LegacyGPSampler(seed=2, n_startup_trials=8), numeric_objective, 35)
    assert new == old


def test_tpe_jit_scoring_samples_in_bounds():
    """The optional jax-jitted scorer is not held to bit parity (XLA math),
    but must produce valid samples from the same study."""
    jax = pytest.importorskip("jax")  # noqa: F841
    study = hpo.create_study(
        sampler=hpo.TPESampler(seed=0, n_startup_trials=5, jit_scoring=True)
    )

    def objective(trial):
        x = trial.suggest_float("x", -3, 3)
        lr = trial.suggest_float("lr", 1e-4, 1.0, log=True)
        return x * x + abs(np.log10(lr) + 2)

    study.optimize(objective, n_trials=15)
    for t in study.trials:
        assert -3 <= t.params["x"] <= 3
        assert 1e-4 <= t.params["lr"] <= 1.0


def test_tpe_multivariate_false_matches_legacy():
    """The univariate path is frozen behind multivariate=False: explicit
    flag, bit-identical to the pre-refactor scalar sampler."""
    new = trace(
        hpo.TPESampler(seed=13, n_startup_trials=8, multivariate=False),
        mixed_objective, 40,
    )
    old = trace(legacy.LegacyTPESampler(seed=13, n_startup_trials=8), mixed_objective, 40)
    assert new == old


def test_tpe_multivariate_false_batched_ask_stream_unchanged():
    """With multivariate=False a batched ask(n) only batches trial creation:
    no joint presample runs, so the sampling RNG stream — and therefore every
    suggested value — is identical to the scalar one-ask-at-a-time loop."""

    def run(ask_batch):
        study = hpo.create_study(
            sampler=hpo.TPESampler(seed=3, n_startup_trials=8, multivariate=False)
        )
        study.optimize(mixed_objective, n_trials=30, ask_batch=ask_batch)
        return [(t.params, t.values, t.state) for t in study.trials]

    assert run(1) == run(5)
