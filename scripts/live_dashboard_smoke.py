"""Headless live-dashboard smoke (CI): optimize a 50-trial study against a
real StorageServer, then drive ``repro.core.dashboard --live`` at it and
assert the rendered HTML carries the live metrics panel.

    PYTHONPATH=src python scripts/live_dashboard_smoke.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

import repro.core as hpo
from repro.core import dashboard


def objective(trial: hpo.Trial) -> float:
    x = trial.suggest_float("x", -5, 5)
    y = trial.suggest_float("y", -5, 5)
    for step in range(1, 4):
        trial.report((x - 1) ** 2 + y ** 2 + 1.0 / step, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return (x - 1) ** 2 + y ** 2


def main() -> None:
    out = Path(tempfile.mkdtemp(prefix="live_dash_")) / "dash.html"
    with hpo.StorageServer(hpo.InMemoryStorage()) as server:
        study = hpo.create_study(
            study_name="live-smoke",
            storage=server.url,
            sampler=hpo.TPESampler(seed=0),
            pruner=hpo.MedianPruner(),
        )
        study.optimize(objective, n_trials=50)

        # two revision-gated polls: the first renders, the idle second skips
        dashboard.main([
            server.url, "live-smoke", str(out),
            "--live", "--watch", "0.2", "--ticks", "2",
        ])
        events = hpo.RemoteStorage(server.url).get_trial_events(study._study_id)

    html = out.read_text()
    for needle in ("Live server metrics", "trials/s", "Optimization history",
                   "get_all_trials", "<svg"):
        assert needle in html, f"missing {needle!r} in {out}"
    n_created = sum(1 for k in events["kind"] if k == 0)
    assert n_created == 50, f"expected 50 created events, got {n_created}"
    print(f"live dashboard smoke OK: {len(html)} bytes, "
          f"{len(events['kind'])} trace events -> {out}")


if __name__ == "__main__":
    main()
