"""Headless analytics-service smoke (CI): optimize a 50-trial study against a
real StorageServer, serve it through the live dashboard HTTP service, and pin
the revision-gating contract end to end — an idle delta poll returns zero
rows (and touches no trial data), N new tells return exactly N rows.

    PYTHONPATH=src python scripts/dashboard_service_smoke.py
"""

import json
import sys
import urllib.request

sys.path.insert(0, "src")

import repro.core as hpo
from repro.core import telemetry
from repro.serve.dashboard_service import DashboardService


def objective(trial: hpo.Trial) -> float:
    x = trial.suggest_float("x", -5, 5)
    y = trial.suggest_float("y", -5, 5)
    for step in range(1, 4):
        trial.report((x - 1) ** 2 + y ** 2 + 1.0 / step, step)
        if trial.should_prune():
            raise hpo.TrialPruned()
    return (x - 1) ** 2 + y ** 2


def get(svc, path):
    return json.loads(urllib.request.urlopen(svc.url + path).read())


def main() -> None:
    telemetry.enable()
    with hpo.StorageServer(hpo.InMemoryStorage()) as server:
        study = hpo.create_study(
            study_name="svc-smoke",
            storage=server.url,
            sampler=hpo.TPESampler(seed=0),
            pruner=hpo.MedianPruner(),
        )
        study.optimize(objective, n_trials=50)

        svc = DashboardService(server.url).start()
        try:
            # cold poll: the full study arrives as delta rows
            d = get(svc, "/api/study/svc-smoke/delta?since_rev=-1&since_num=-1")
            assert len(d["rows"]) == 50, f"expected 50 rows, got {len(d['rows'])}"

            # idle polls: revision unchanged -> zero rows, zero refetch
            before = telemetry.snapshot()["counters"]
            for _ in range(3):
                d2 = get(svc, "/api/study/svc-smoke/delta"
                              f"?since_rev={d['rev']}&since_num={d['last_number']}")
                assert d2["idle"] and "rows" not in d2, d2
            after = telemetry.snapshot()["counters"]
            refetches = {
                k: (after[k] - before.get(k, 0))
                for k in after if ".refresh.fetch" in k or k.endswith(".refresh.block")
            }
            assert not any(refetches.values()), f"idle polls refetched: {refetches}"

            # N more tells -> exactly N new rows
            n_new = 7
            study.optimize(objective, n_trials=n_new)
            d3 = get(svc, "/api/study/svc-smoke/delta"
                          f"?since_rev={d['rev']}&since_num={d['last_number']}")
            assert len(d3["rows"]) == n_new, f"expected {n_new}, got {len(d3['rows'])}"
            assert [r["number"] for r in d3["rows"]] == list(range(50, 50 + n_new))

            # the five views + importance render from the columnar reductions
            v = get(svc, "/api/study/svc-smoke/views")
            assert v["n_finished"] == 57
            assert v["history"][0]["best"] == sorted(v["history"][0]["best"], reverse=True)
            assert v["contour"] is not None and v["slices"] and v["curves"]["objectives"]
            assert v["importance"]["fanova"]["0"]

            page = urllib.request.urlopen(svc.url + "/study/svc-smoke").read().decode()
            assert "optimization history" in page and "pareto front" in page
            metrics = urllib.request.urlopen(svc.url + "/metrics").read().decode()
            assert "repro_dashboard_delta_idle_total 3" in metrics
        finally:
            svc.stop()

    print(f"dashboard service smoke OK: 50+{n_new} trials, 3 idle polls, "
          f"views + /metrics verified")


if __name__ == "__main__":
    main()
