from __future__ import annotations

from .checkpoint import CheckpointManager, restore_pytree, save_pytree
from .data import MemmapTokens, SyntheticLM, make_data
from .optimizer import (
    Optimizer,
    adafactor,
    adamw,
    constant_schedule,
    global_norm,
    make_optimizer,
    sgd,
    warmup_cosine,
)
from .train_loop import TrainConfig, Trainer, make_sharded_init, make_train_step

__all__ = [
    "TrainConfig", "Trainer", "make_train_step", "make_sharded_init",
    "Optimizer", "adamw", "adafactor", "sgd", "make_optimizer",
    "warmup_cosine", "constant_schedule", "global_norm",
    "CheckpointManager", "save_pytree", "restore_pytree",
    "SyntheticLM", "MemmapTokens", "make_data",
]
