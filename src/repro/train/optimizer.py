"""Optimizers in pure JAX: AdamW, Adafactor (factored second moments — the
235B-config choice), SGD+momentum; global-norm clipping; warmup+cosine
schedules.

Optimizer state is a pytree parallel to params, so GSPMD shards it exactly
like the parameters (ZeRO-style for free when params are FSDP-sharded).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "sgd",
    "make_optimizer",
    "warmup_cosine",
    "constant_schedule",
    "global_norm",
    "clip_by_global_norm",
]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        # (step+1)/warmup so the very first step trains (lr > 0 at step 0)
        warm = peak_lr * jnp.minimum(1.0, (step + 1.0) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]  # (grads, state, params, step)
    name: str = "opt"


def adamw(
    schedule: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t
        lr = schedule(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m_new / c1
            vhat = v_new / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step_
            return p_new.astype(p.dtype), m_new.astype(state_dtype), v_new.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return p_new, {"m": m_new, "v": v_new}, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update, "adamw")


def adafactor(
    schedule: Callable,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    clip_norm: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), beta1=0.

    For a [r, c] matrix the state is r + c floats instead of r*c — this is
    what lets qwen3-moe-235b train on one 256-chip pod (see DESIGN.md)."""

    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def one(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        beta2t = 1.0 - jnp.power(t, -0.8)  # Adafactor's decay schedule

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if factored(p):
                vr = beta2t * st["vr"] + (1 - beta2t) * g2.mean(axis=-1)
                vc = beta2t * st["vc"] + (1 - beta2t) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g32 / jnp.sqrt(
                    (vr / denom)[..., None] * vc[..., None, :] + eps
                )
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2t * st["v"] + (1 - beta2t) * g2
                u = g32 / jnp.sqrt(v + eps)
                new_st = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            p_new = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), new_st

        # note: state["v"] carries an extra {vr,vc}/{v} dict *below* each param
        # leaf; tree.map flattens the later trees only up to `grads` leaves, so
        # `st` arrives as that dict.
        out = jax.tree.map(upd, grads, state["v"], params)
        is_pair = lambda x: isinstance(x, tuple)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        v_new = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return p_new, {"v": v_new}, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update, "adafactor")


def sgd(schedule: Callable, momentum: float = 0.9, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(step)

        def upd(g, mu, p):
            mu_new = momentum * mu + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * mu_new).astype(p.dtype), mu_new

        out = jax.tree.map(upd, grads, state["mu"], params)
        is_pair = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda o: o[0], out, is_leaf=is_pair),
            {"mu": jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)},
            {"grad_norm": gnorm, "lr": lr},
        )

    return Optimizer(init, update, "sgd")


def make_optimizer(name: str, schedule: Callable, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "adafactor":
        return adafactor(schedule, **kw)
    if name == "sgd":
        return sgd(schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
