"""Deterministic synthetic data pipeline (+ a memmap token-file reader).

Synthetic streams are *stateless*: batch at step ``s`` is a pure function of
(seed, s), so resuming from a checkpoint just means ``skip_to(step)`` — no
iterator state to persist, and every data-parallel worker can slice its shard
of the global batch independently (deterministic data skip on restart).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig

__all__ = ["SyntheticLM", "MemmapTokens", "make_data"]


def _tokens_for_step(seed: int, step: int, shape, vocab: int) -> np.ndarray:
    """Cheap counter-based PRNG (philox-like mix) — identical on every host."""
    n = int(np.prod(shape))
    idx = np.arange(n, dtype=np.uint64) + np.uint64(step) * np.uint64(n)
    x = idx * np.uint64(6364136223846793005) + np.uint64(seed * 2 + 1)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    return (x % np.uint64(vocab)).astype(np.int32).reshape(shape)


@dataclasses.dataclass
class SyntheticLM:
    """Markov-flavored synthetic LM batches: tokens are hash noise, labels are
    next-token shifts, so CE starts at ~ln(V) and a real model can still fit
    local correlations (we inject short-range structure for learnability)."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    structured: bool = True
    _step: int = 0

    def skip_to(self, step: int) -> None:
        self._step = step

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        if cfg.modality == "audio":
            shape = (self.batch, cfg.num_codebooks, self.seq + 1)
        elif cfg.modality == "vlm":
            shape = (self.batch, self.seq - cfg.img_tokens + 1)
        else:
            shape = (self.batch, self.seq + 1)
        toks = _tokens_for_step(self.seed, step, shape, self.cfg.vocab)
        if self.structured:
            # short-range structure: every odd position repeats its neighbor
            # (mod vocab) so models that attend locally beat the entropy floor
            if cfg.modality == "audio":
                toks[:, :, 1::2] = (toks[:, :, 0::2][:, :, : toks[:, :, 1::2].shape[2]] + 1) % cfg.vocab
            else:
                toks[:, 1::2] = (toks[:, 0::2][:, : toks[:, 1::2].shape[1]] + 1) % cfg.vocab
        if cfg.modality == "audio":
            return {
                "tokens": jnp.asarray(toks[:, :, :-1]),
                "labels": jnp.asarray(toks[:, :, 1:]),
            }
        if cfg.modality == "vlm":
            rng = np.random.RandomState((self.seed, step, 7) .__hash__() % (2**31))
            img = rng.randn(self.batch, cfg.img_tokens, cfg.d_model).astype(np.float32) * 0.02
            labels = np.concatenate(
                [np.zeros((self.batch, cfg.img_tokens), np.int32), toks[:, 1:]], axis=1
            )
            return {
                "tokens": jnp.asarray(toks[:, :-1]),
                "image_embeds": jnp.asarray(img),
                "labels": jnp.asarray(labels),
            }
        return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}

    def next_batch(self) -> dict:
        b = self.batch_at(self._step)
        self._step += 1
        return b


@dataclasses.dataclass
class MemmapTokens:
    """Packed int32 token file (the production path: pre-tokenized shards on
    NFS/GCS-fuse).  Sequential chunking with a deterministic per-step offset."""

    path: str
    cfg: ModelConfig
    batch: int
    seq: int
    _step: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._per_step = self.batch * (self.seq + 1)
        if len(self._data) < self._per_step:
            raise ValueError("token file smaller than one batch")

    def skip_to(self, step: int) -> None:
        self._step = step

    def next_batch(self) -> dict:
        n_steps = len(self._data) // self._per_step
        ofs = (self._step % n_steps) * self._per_step
        chunk = np.array(self._data[ofs : ofs + self._per_step]).reshape(
            self.batch, self.seq + 1
        )
        self._step += 1
        return {"tokens": jnp.asarray(chunk[:, :-1]), "labels": jnp.asarray(chunk[:, 1:])}


def make_data(cfg: ModelConfig, batch: int, seq: int, seed: int = 0, path: str | None = None):
    if path:
        return MemmapTokens(path, cfg, batch, seq)
    return SyntheticLM(cfg, batch, seq, seed)
