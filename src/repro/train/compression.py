"""Gradient compression for the data-parallel all-reduce.

At multi-pod scale the DP gradient reduction crosses the (slow) inter-pod
links; compressing it trades FLOPs for bytes on exactly the link the
collective-roofline term says is the bottleneck.

Two codecs, both with *error feedback* (the compression residual is carried
to the next step so the estimator stays unbiased in the long run):

* int8 per-tensor-scale quantization (8x fewer bytes, dense)
* top-k magnitude sparsification (k as a fraction; indices+values)

``compressed_psum`` is the shard_map building block: quantize -> psum ->
dequantize.  ``wrap_grad_fn`` applies it to a whole gradient pytree.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["int8_compress", "int8_decompress", "topk_mask", "compressed_psum", "wrap_grad_fn"]


def int8_compress(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    """Keep the top-frac entries by |value| (dense mask — the collective still
    moves a dense tensor, but zeros compress on the wire with int8)."""
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compressed_psum(x, axis_name: str, codec: str = "int8"):
    """Quantize -> psum -> dequantize (inside shard_map).  All participants
    must share ONE scale (sum_i q_i * s only factors out for a common s), so
    a scalar pmax of the local maxima runs first — negligible traffic.  The
    int8 payload is summed in int32 to avoid overflow across >=256 ranks."""
    if codec == "none":
        return jax.lax.psum(x, axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = gmax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def wrap_grad_fn(grad_fn: Callable, mesh, axis_name: str = "data",
                 codec: str = "int8", ef: bool = True) -> Callable:
    """Turn a per-shard grad fn into a DP-all-reduced one with compression +
    error feedback.  grad_fn(params, batch_shard) -> grads (local)."""

    def reduced(params, batch, residual):
        def body(p, b, r):
            g = grad_fn(p, b)

            def one(gl, rl):
                gl = gl + rl if ef else gl
                red = compressed_psum(gl, axis_name, codec)
                new_r = gl - red / jax.lax.psum(1, axis_name) if ef else jnp.zeros_like(gl)
                return red, new_r

            out = jax.tree.map(one, g, r)
            is_pair = lambda x: isinstance(x, tuple)
            return (
                jax.tree.map(lambda o: o[0], out, is_leaf=is_pair),
                jax.tree.map(lambda o: o[1], out, is_leaf=is_pair),
            )

        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(axis_name), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )(params, batch, residual)

    return reduced
