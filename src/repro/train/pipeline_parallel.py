"""GPipe-style pipeline parallelism with ``shard_map`` + ``lax.ppermute``.

At >512-chip scale (or >400B params) DP×TP alone stops fitting; this module
provides the PP axis: layers are striped across a ``stage`` mesh axis and
microbatches stream through with point-to-point ``ppermute`` transfers — no
all-gathers on the critical path.

Schedule (standard GPipe, M microbatches over P stages):

  for t in 0 .. M+P-2:          # pipeline ticks
      every stage: if it holds a live microbatch, run its layer slice
      ppermute activations stage i -> i+1

Bubble fraction = (P-1)/(M+P-1); EXPERIMENTS.md §Perf quantifies when PP
beats pure DP×TP on the v5e roofline for the assigned models (short answer:
not at ≤512 chips for ≤235B — which is why the production dry-run meshes use
DP×TP(×EP); PP is validated on small host meshes in tests/test_pipeline.py).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipelined_apply", "make_pp_train_step"]


def pipelined_apply(
    stage_fn: Callable,  # (stage_params, x) -> x  — one stage's layer slice
    params,  # pytree with leading dim = n_stages on every leaf
    x,  # [M, mb, ...] microbatched activations
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Run x through all stages in pipeline order.  Inside shard_map each
    device holds params for its stage (leading dim 1) and circulates
    microbatch activations."""
    n_stages = mesh.shape[stage_axis]
    M = x.shape[0]

    def body(stage_params, xs):
        stage_params = jax.tree.map(lambda a: a[0], stage_params)  # [1,...] -> [...]
        idx = jax.lax.axis_index(stage_axis)
        mb, feat = xs.shape[1], xs.shape[2:]
        state = jnp.zeros((mb, *feat), xs.dtype)  # live microbatch on this stage
        outputs = jnp.zeros_like(xs)

        def tick(t, carry):
            state, outputs = carry
            # stage 0 injects microbatch t (when available)
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False
            )
            state = jnp.where((idx == 0) & (t < M), inject, state)
            live = (t - idx >= 0) & (t - idx < M)
            out = stage_fn(stage_params, state)
            state = jnp.where(live, out, state)
            # last stage writes its finished microbatch t - (P-1)
            done_slot = t - (n_stages - 1)
            outputs = jax.lax.cond(
                (idx == n_stages - 1) & (done_slot >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, state, jnp.clip(done_slot, 0, M - 1), axis=0
                ),
                lambda o: o,
                outputs,
            )
            # shift activations to the next stage
            state = jax.lax.ppermute(
                state, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)],
            )
            # ppermute feeds stage i+1 with stage i's output; stage 0's inbox is
            # garbage from the wrap-around — it re-injects anyway.
            return state, outputs

        state, outputs = jax.lax.fori_loop(0, M + n_stages - 1, tick, (state, outputs))
        # only the last stage holds real outputs; broadcast to all stages via psum
        # after masking others to zero so every shard returns the same value.
        outputs = jnp.where(idx == n_stages - 1, outputs, jnp.zeros_like(outputs))
        outputs = jax.lax.psum(outputs, stage_axis)
        return outputs

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )(params, x)


def make_pp_train_step(stage_fn, loss_fn, mesh, stage_axis: str = "stage"):
    """Toy end-to-end PP train step for the tests: forward via pipelined_apply,
    loss on the full output, grads via jax.grad through the shard_map."""

    def step(params, x, y, lr):
        def objective(p):
            out = pipelined_apply(stage_fn, p, x, mesh, stage_axis)
            return loss_fn(out, y)

        loss, grads = jax.value_and_grad(objective)(params)
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_params, loss

    return step
