"""Train-step construction + the host-side training loop.

``make_train_step`` builds the pure step function lowered by both the real
trainer and the dry-run: grad of the chunked-CE loss, optional microbatch
accumulation (scan), optimizer update, donation-friendly signature.

``Trainer`` adds the production concerns: sharded init, checkpoint/restart
(auto-resume from the latest step), deterministic data skip on resume, eval
hooks that feed the HPO pruner, and graceful preemption (SIGTERM -> final
checkpoint).
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import (
    ModelConfig,
    abstract_params,
    init_model_params,
    loss_fn,
    params_logical,
)
from repro.models.sharding import ShardingRules, logical_to_sharding, tree_shardings

from .checkpoint import CheckpointManager
from .optimizer import Optimizer, make_optimizer, warmup_cosine

__all__ = ["TrainConfig", "make_train_step", "Trainer", "make_sharded_init"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    clip_norm: float = 1.0
    microbatch: int = 0  # 0 = no accumulation; else per-step slices
    checkpoint_every: int = 200
    eval_every: int = 20
    seed: int = 0


def make_optimizer_for(cfg: ModelConfig, tcfg: TrainConfig) -> Optimizer:
    sched = warmup_cosine(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)
    if cfg.optimizer == "adamw":
        return make_optimizer(
            "adamw", sched, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm,
        )
    if cfg.optimizer == "adafactor":
        return make_optimizer("adafactor", sched, clip_norm=tcfg.clip_norm)
    return make_optimizer("sgd", sched, clip_norm=tcfg.clip_norm)


def make_train_step(cfg: ModelConfig, opt: Optimizer, microbatch: int = 0) -> Callable:
    """Returns step(params, opt_state, step_no, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def step(params, opt_state, step_no, batch):
        if microbatch and microbatch > 1:
            # grad accumulation: scan over microbatch slices of the batch dim
            def resh(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def body(acc, mbatch):
                loss, metrics, grads = grads_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, (loss, grads))
                return acc, None

            zero = (
                jnp.float32(0.0),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, mb)
            loss = loss_sum / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grad_sum)
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)
        new_params, new_opt, opt_metrics = opt.update(grads, opt_state, params, step_no)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, out_metrics

    return step


def make_sharded_init(cfg: ModelConfig, opt: Optimizer, mesh, rules: ShardingRules):
    """jit-compiled init with output shardings pinned to the rules table —
    parameters are born sharded, never materialized on one host."""
    aps = abstract_params(cfg)
    logical = params_logical(cfg)
    p_sh = tree_shardings(aps, logical, mesh, rules)
    opt_abs = jax.eval_shape(opt.init, aps)
    o_sh = _opt_shardings(opt_abs, p_sh)

    def init(key):
        params = init_model_params(cfg, key)
        return params, opt.init(params)

    return jax.jit(init, out_shardings=(p_sh, o_sh)), p_sh, o_sh


def _opt_shardings(opt_abs, param_shardings):
    """Optimizer state shardings: inherit from the matching parameter where
    shapes coincide (adam m/v); adafactor's factored vr/vc inherit the param
    spec minus the reduced axis (so expert/vocab shards stay sharded)."""
    from jax.sharding import NamedSharding, PartitionSpec

    flat_p = {
        tuple(str(k) for k in path): s
        for path, s in jax.tree_util.tree_leaves_with_path(param_shardings)
    }

    def param_spec_for(keys):
        for start in range(len(keys)):
            if keys[start:] in flat_p:
                return flat_p[keys[start:]]
        return None

    def one(path, leaf):
        keys = tuple(str(k) for k in path)
        hit = param_spec_for(keys)
        if hit is not None:
            return hit
        if keys and keys[-1] in ("vr", "vc"):
            hit = param_spec_for(keys[:-1])
            if hit is not None:
                spec = list(hit.spec)
                spec += [None] * (len(leaf.shape) + 1 - len(spec))
                drop = -1 if keys[-1] == "vr" else -2
                del spec[drop]
                # drop axes that no longer divide
                clean = []
                for dim, ax in zip(leaf.shape, spec):
                    axes = (ax,) if isinstance(ax, str) else (ax or ())
                    size = 1
                    for a in axes:
                        size *= hit.mesh.shape[a]
                    clean.append(ax if size and dim % max(size, 1) == 0 else None)
                return NamedSharding(hit.mesh, PartitionSpec(*clean))
        some = next(iter(flat_p.values()))
        return NamedSharding(some.mesh, PartitionSpec())

    leaves = jax.tree_util.tree_leaves_with_path(opt_abs)
    vals = [one(p, l) for p, l in leaves]
    return jax.tree.unflatten(jax.tree.structure(opt_abs), vals)


class Trainer:
    """Host-side loop with checkpoint/restart and pruner hooks."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        data_iter,
        workdir: str | None = None,
        mesh=None,
        rules: ShardingRules | None = None,
        report_fn: Callable[[int, float], bool] | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data = data_iter
        self.workdir = workdir
        self.mesh = mesh
        self.rules = rules
        self.report_fn = report_fn  # returns True if the trial should stop (pruned)
        self.opt = make_optimizer_for(cfg, tcfg)
        self.ckpt = CheckpointManager(workdir) if workdir else None
        self._preempted = False

    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not the main thread (e.g. HPO worker threads)

    def run(self) -> dict:
        self._install_sigterm()
        cfg, tcfg = self.cfg, self.tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        params = init_model_params(cfg, key)
        opt_state = self.opt.init(params)
        start_step = 0
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest((params, opt_state))
            if restored is not None:
                start_step, (params, opt_state) = restored
        step_fn = jax.jit(
            make_train_step(cfg, self.opt, tcfg.microbatch), donate_argnums=(0, 1)
        )

        self.data.skip_to(start_step)
        losses = []
        last = None
        for step in range(start_step, tcfg.total_steps):
            batch = self.data.next_batch()
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.asarray(step, jnp.int32), batch
            )
            last = metrics
            if (step + 1) % tcfg.eval_every == 0 or step + 1 == tcfg.total_steps:
                loss = float(metrics["loss"])
                losses.append(loss)
                if self.report_fn is not None and self.report_fn(step + 1, loss):
                    # pruned by the HPO layer: stop immediately, do not checkpoint
                    # (the paper's no-repechage design: pruned trials never resume)
                    return {"pruned": True, "last_loss": loss, "step": step + 1}
            if self.ckpt is not None and (
                (step + 1) % tcfg.checkpoint_every == 0 or self._preempted
            ):
                self.ckpt.save(step + 1, (params, opt_state))
                if self._preempted:
                    return {"preempted": True, "step": step + 1,
                            "last_loss": float(last["loss"]) if last else float("nan")}
        if self.ckpt is not None:
            self.ckpt.wait()
        return {
            "pruned": False,
            "last_loss": float(last["loss"]) if last is not None else float("nan"),
            "losses": losses,
            "step": tcfg.total_steps,
            "params": params,
        }
