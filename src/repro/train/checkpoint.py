"""Checkpointing with async save and resharding restore.

Format: one ``.npz`` per checkpoint (flattened path->array) + a JSON manifest
(step, tree paths, shapes, dtypes).  Saves run on a background thread so the
train loop never blocks on disk (async checkpointing); ``restore`` device_puts
each leaf with the *target* sharding, so a checkpoint written on one mesh can
be restored onto a different mesh/topology (elastic restart after losing a
slice — the fault-tolerance path exercised in tests).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]

_SEP = "::"


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        out[jax.tree_util.keystr(path)] = leaf
    return out


def save_pytree(path: str, tree, step: int = 0) -> None:
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "keys": [], "dtypes": []}
    for i, (k, v) in enumerate(sorted(flat.items())):
        arr = np.asarray(v)
        manifest["dtypes"].append(str(arr.dtype))
        if arr.dtype.kind not in "fiub" or arr.dtype.name == "bfloat16":
            # numpy npz cannot persist custom dtypes (bfloat16/f8): widen to f32
            arr = arr.astype(np.float32)
        arrays[f"a{i}"] = arr
        manifest["keys"].append(k)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def restore_pytree(path: str, target, shardings=None):
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional parallel tree of NamedShardings
    for resharded placement."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path)
    by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}

    leaves = jax.tree_util.tree_leaves_with_path(target)
    sh_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    out = []
    for (p, leaf), sh in zip(leaves, sh_leaves):
        k = jax.tree_util.keystr(p)
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = by_key[k]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(leaf.dtype)))
    return manifest["step"], jax.tree.unflatten(jax.tree.structure(target), out)


class CheckpointManager:
    """Directory of ``step_<n>.ckpt`` files; keeps the newest ``keep``."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.ckpt")

    def save(self, step: int, tree, blocking: bool = False) -> None:
        # snapshot to host synchronously (cheap vs serialize), write async
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save_pytree(self._path(step), host, step)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            for suffix in ("", ".json"):
                try:
                    os.remove(self._path(s) + suffix)
                except FileNotFoundError:
                    pass

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = re.match(r"step_(\d+)\.ckpt$", name)
            if m and os.path.exists(os.path.join(self.dir, name + ".json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def restore_latest(self, target, shardings=None):
        self.wait()
        steps = self.all_steps()
        if not steps:
            return None
        return restore_pytree(self._path(steps[-1]), target, shardings)
