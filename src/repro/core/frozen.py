"""Immutable snapshots of trials: ``TrialState`` and ``FrozenTrial``."""

from __future__ import annotations

import copy
import datetime
import enum
from typing import Any

from .distributions import BaseDistribution

__all__ = ["TrialState", "FrozenTrial", "StudyDirection", "IV_VEC_PREFIX", "iv_vec_key"]

#: system-attr key prefix for per-objective intermediate-value vectors
#: (``iv_vec:<step>`` -> ``[v0, v1, ...]``).  Riding on system attrs means
#: every backend, both wire protocols, the op journal and replication carry
#: vector reports with zero schema changes — and scalar studies, which never
#: write the key, are byte-identical on the wire.
IV_VEC_PREFIX = "iv_vec:"


def iv_vec_key(step: int) -> str:
    return f"{IV_VEC_PREFIX}{int(step)}"


class TrialState(enum.IntEnum):
    RUNNING = 0
    COMPLETE = 1
    PRUNED = 2
    FAIL = 3
    WAITING = 4  # enqueued, not yet claimed by a worker

    def is_finished(self) -> bool:
        return self in (TrialState.COMPLETE, TrialState.PRUNED, TrialState.FAIL)


class StudyDirection(enum.IntEnum):
    MINIMIZE = 0
    MAXIMIZE = 1


class FrozenTrial:
    """An immutable record of a trial as persisted in storage.

    ``params`` holds external reprs; ``distributions`` the per-param domains.
    ``intermediate_values`` maps step -> reported value (paper Fig. 5's
    'report API' history that pruners consume).
    """

    def __init__(
        self,
        number: int,
        state: TrialState,
        value: float | None = None,
        values: list[float] | None = None,
        params: dict[str, Any] | None = None,
        distributions: dict[str, BaseDistribution] | None = None,
        intermediate_values: dict[int, float] | None = None,
        user_attrs: dict[str, Any] | None = None,
        system_attrs: dict[str, Any] | None = None,
        trial_id: int = -1,
        datetime_start: datetime.datetime | None = None,
        datetime_complete: datetime.datetime | None = None,
    ):
        if value is not None and values is not None:
            raise ValueError("specify only one of value / values")
        self.number = number
        self.state = state
        self.values = [value] if value is not None else (list(values) if values else None)
        self.params = dict(params or {})
        self.distributions = dict(distributions or {})
        self.intermediate_values = dict(intermediate_values or {})
        self.user_attrs = dict(user_attrs or {})
        self.system_attrs = dict(system_attrs or {})
        self._trial_id = trial_id
        self.datetime_start = datetime_start
        self.datetime_complete = datetime_complete

    # -- convenience ---------------------------------------------------------

    @property
    def value(self) -> float | None:
        if self.values is None:
            return None
        if len(self.values) != 1:
            raise RuntimeError("this trial is multi-objective; use .values")
        return self.values[0]

    @property
    def trial_id(self) -> int:
        return self._trial_id

    @property
    def intermediate_value_vectors(self) -> dict[int, list[float]]:
        """Per-objective intermediate vectors: step -> ``[v0, v1, ...]``,
        decoded from the ``iv_vec:<step>`` system attrs (empty on scalar
        studies).  The scalar ``intermediate_values`` entry at the same step
        holds the pruner-facing scalarization, not objective 0."""
        out: dict[int, list[float]] = {}
        for k, v in self.system_attrs.items():
            if isinstance(k, str) and k.startswith(IV_VEC_PREFIX):
                try:
                    out[int(k[len(IV_VEC_PREFIX):])] = list(v)
                except (TypeError, ValueError):
                    continue
        return out

    @property
    def last_step(self) -> int | None:
        if not self.intermediate_values:
            return None
        return max(self.intermediate_values)

    @property
    def duration(self) -> datetime.timedelta | None:
        if self.datetime_start is None or self.datetime_complete is None:
            return None
        return self.datetime_complete - self.datetime_start

    def copy(self) -> "FrozenTrial":
        """Structured copy on the suggest hot path: containers are fresh
        dicts/lists, leaf values are shared.  Params, objective values, and
        intermediate values are immutable scalars; distributions are never
        mutated after construction.  Only attr *values* (arbitrary JSON) are
        deep-copied, since callers may mutate those in place."""
        t = FrozenTrial.__new__(FrozenTrial)
        t.number = self.number
        t.state = self.state
        t.values = list(self.values) if self.values is not None else None
        t.params = dict(self.params)
        t.distributions = dict(self.distributions)
        t.intermediate_values = dict(self.intermediate_values)
        t.user_attrs = copy.deepcopy(self.user_attrs)
        t.system_attrs = copy.deepcopy(self.system_attrs)
        t._trial_id = self._trial_id
        t.datetime_start = self.datetime_start
        t.datetime_complete = self.datetime_complete
        return t

    def __repr__(self) -> str:
        return (
            f"FrozenTrial(number={self.number}, state={self.state.name}, "
            f"values={self.values}, params={self.params})"
        )

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, FrozenTrial):
            return NotImplemented
        return self.__dict__ == other.__dict__
