"""Columnar observation + intermediate-value stores — the array substrate
shared by the sampler *and* pruner stacks.

Before this module existed, every ``ask`` re-materialized the full trial
history as Python ``FrozenTrial`` lists and looped per-parameter in scalar
numpy — O(trials x params) interpreter work per trial.  The
:class:`ObservationStore` replaces that with an incrementally-maintained
structure-of-arrays view of *finished* trials:

* one ``(n_trials, n_params)`` float64 matrix in **model space**
  (log-transformed numerics / categorical indices; see
  ``BaseDistribution.to_internal``), NaN where a trial did not suggest a
  parameter (define-by-run conditionals),
* aligned ``numbers`` / ``states`` / ``values`` (first objective) /
  ``last_intermediate_values`` vectors.

Maintenance is incremental and storage-agnostic:

* ``refresh()`` first polls the storage's monotonic **revision counter**
  (``get_trials_revision``) — if nothing changed since the last look, the
  refresh is O(1) and touches no trial data,
* otherwise it fetches only the suffix ``number >= watermark`` via
  ``get_all_trials(since=...)`` (the same hook :class:`CachedStorage` uses,
  so the two compose: through a cached remote backend a refresh is at most
  one revision RPC),
* finished trials are immutable (BaseStorage contract), so each is encoded
  into the matrix exactly once, O(n_params) amortized per ``Study.tell``.

Out-of-order finishes (trial #5 completing before #3) are appended as they
arrive; the number-sorted view is re-materialized lazily, only when new rows
landed.  Returned arrays are read-only views shared between callers — never
mutate them.

The :class:`IntermediateValueStore` is the pruner-side sibling: an
``(n_trials, n_steps)`` NaN-padded matrix of reported intermediate values
(rows indexed by trial number — dense by the storage contract — columns by a
sorted side table of distinct steps, so sparse/irregular step grids cost only
the columns they use), plus aligned ``states`` / ``trial_ids`` vectors and
lazily-cached best-so-far prefix matrices (``fmin.accumulate`` /
``fmax.accumulate`` along the step axis).  Unlike the observation store it
must track *live* RUNNING trials — their rows are rewritten on refresh —
so its revision gate is the whole optimization: when ``get_trials_revision``
is unchanged a refresh is O(1), otherwise only the suffix past the dense
finished prefix is refetched and re-encoded.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING

import numpy as np

from . import telemetry
from .frozen import IV_VEC_PREFIX, TrialState
from .storage.base import get_trials_since

if TYPE_CHECKING:
    from .distributions import BaseDistribution
    from .storage.base import BaseStorage

__all__ = ["ObservationStore", "IntermediateValueStore"]

_MIN_CAPACITY = 32

#: system-attr key the grid sampler claims cells under (imported by
#: ``samplers/grid.py``); ingested as a dedicated column so ``_taken`` is a
#: vector op over finished trials instead of a FrozenTrial walk
_GRID_ATTR = "grid_sampler:grid_id"


def _poll_revision(store) -> "int | None":
    """Shared revision-gate probe for both columnar stores.

    Returns the storage's current per-study revision, or None when the
    backend does not support one (the probe downgrades
    ``store._revision_supported`` permanently on the first
    ``NotImplementedError``/missing method, so later refreshes skip the
    call).  Callers MUST read the revision *before* reading trial data:
    writes landing between the two reads then surface as a fresh revision on
    the next refresh instead of being lost."""
    if store._revision_supported:
        get_rev = getattr(store._storage, "get_trials_revision", None)
        if get_rev is None:
            store._revision_supported = False
        else:
            try:
                return get_rev(store._study_id)
            except NotImplementedError:
                store._revision_supported = False
    return None


class ObservationStore:
    def __init__(self, storage: "BaseStorage", study_id: int):
        self._storage = storage
        self._study_id = study_id
        self._lock = threading.RLock()

        self._n = 0
        self._capacity = 0
        self._numbers = np.empty(0, dtype=np.int64)
        self._states = np.empty(0, dtype=np.int64)
        self._values = np.empty(0)
        # multi-objective values: (capacity, n_objectives) NaN-padded matrix
        # plus a per-row arity column (len(trial.values); 0 when absent) so
        # the Pareto engine can exclude wrong-arity rows exactly like the
        # frozen pairwise loop did.  n_objectives comes from the study's
        # directions, fetched once on first refresh.
        self._n_objectives: "int | None" = None
        self._values_mat = np.empty((0, 0))
        self._values_len = np.empty(0, dtype=np.int64)
        self._last_iv = np.empty(0)
        self._grid_ids = np.empty(0, dtype=np.int64)
        self._cols: dict[str, np.ndarray] = {}
        self._dists: dict[str, "BaseDistribution"] = {}
        # distribution-type tracking for the vectorized intersection space:
        # per-param int8 row of type codes (-1 = not suggested), a type->code
        # registry, and the latest distribution per (name, code, state)
        self._type_rows: dict[str, np.ndarray] = {}
        self._type_codes: dict[type, int] = {}
        self._latest_dist: dict[tuple, tuple[int, "BaseDistribution"]] = {}

        self._watermark = 0          # every number < watermark is ingested
        self._finished: set[int] = set()  # ingested numbers >= watermark
        self._revision: int | None = None
        self._revision_supported = True
        # columnar block fetch (wire protocol v2): downgraded permanently on
        # the first NotImplementedError, exactly like the revision probe
        self._block_supported = True

        self._dirty = False
        self._view_numbers = self._numbers
        self._view_states = self._states
        self._view_values = self._values
        self._view_values_mat = self._values_mat
        self._view_values_len = self._values_len
        self._view_last_iv = self._last_iv
        self._view_grid_ids = self._grid_ids
        self._view_cols: dict[str, np.ndarray] = {}
        self._view_type_rows: dict[str, np.ndarray] = {}

        #: bumped whenever new observations land; samplers key caches on it
        self.version = 0

    # -- maintenance -----------------------------------------------------------

    def refresh(self) -> None:
        """Bring the store up to date with storage.  O(1) when the storage
        revision is unchanged; otherwise one incremental suffix fetch."""
        with self._lock:
            rev = _poll_revision(self)
            if rev is not None and rev == self._revision:
                telemetry.inc("records.obs.refresh.noop")
                return
            telemetry.inc("records.obs.refresh.fetch")
            if self._n_objectives is None:
                # directions are immutable after study creation: one fetch
                # sizes the values matrix for the store's whole lifetime
                self._n_objectives = len(
                    self._storage.get_study_directions(self._study_id)
                )
                self._values_mat = np.full((self._capacity, self._n_objectives), np.nan)
                self._view_values_mat = self._values_mat[:0]
            if self._block_supported and getattr(
                self._storage, "supports_block_fetch", False
            ):
                try:
                    block = self._storage.get_observation_block(
                        self._study_id, self._watermark
                    )
                except NotImplementedError:
                    self._block_supported = False
                else:
                    telemetry.inc("records.obs.refresh.block")
                    self._ingest_block(block)
                    while self._watermark in self._finished:
                        self._finished.discard(self._watermark)
                        self._watermark += 1
                    self._revision = rev
                    return
            fresh = get_trials_since(
                self._storage, self._study_id, self._watermark, deepcopy=False
            )
            for t in fresh:
                if not t.state.is_finished() or t.number in self._finished:
                    continue
                self._append(t)
            while self._watermark in self._finished:
                self._finished.discard(self._watermark)
                self._watermark += 1
            self._revision = rev

    def _ingest_block(self, block: dict) -> None:
        """Ingest a ``get_observation_block`` payload — the same per-row
        writes :meth:`_append` performs, but fed from contiguous wire arrays
        (model-space internals computed server-side) instead of FrozenTrial
        objects, so a remote refresh decodes no JSON trial dicts at all."""
        n = int(block["n"])
        if n == 0:
            return
        from .distributions import json_to_distribution

        numbers, states = block["numbers"], block["states"]
        values, values_len = block["values"], block["values_len"]
        values_mat, last_iv = block["values_mat"], block["last_iv"]
        grid_ids = block["grid_ids"]
        m = self._values_mat.shape[1]
        mat_ok = values_mat.ndim == 2 and values_mat.shape[1] == m
        # interned distributions decode once per block, not once per row
        params = [
            (name, ent["internal"], ent["dist_idx"],
             [json_to_distribution(s) for s in ent["dists"]])
            for name, ent in block["params"].items()
        ]
        complete, pruned = int(TrialState.COMPLETE), int(TrialState.PRUNED)
        for i in range(n):
            num = int(numbers[i])
            if num in self._finished:
                continue
            if self._n == self._capacity:
                self._grow(max(_MIN_CAPACITY, 2 * self._capacity))
            row = self._n
            self._numbers[row] = num
            st = int(states[i])
            self._states[row] = st
            self._values[row] = values[i]
            self._values_len[row] = int(values_len[i])
            if mat_ok and int(values_len[i]) == m:
                self._values_mat[row, :] = values_mat[i]
            self._last_iv[row] = last_iv[i]
            self._grid_ids[row] = int(grid_ids[i])
            for name, internal, dist_idx, dists in params:
                di = int(dist_idx[i])
                if di < 0:
                    continue
                dist = dists[di]
                col = self._cols.get(name)
                if col is None:
                    col = np.full(self._capacity, np.nan)
                    self._cols[name] = col
                col[row] = internal[i]
                self._dists[name] = dist
                code = self._type_codes.setdefault(type(dist), len(self._type_codes))
                trow = self._type_rows.get(name)
                if trow is None:
                    trow = np.full(self._capacity, -1, dtype=np.int8)
                    self._type_rows[name] = trow
                trow[row] = code
                if st in (complete, pruned):
                    key = (name, code, st)
                    prev = self._latest_dist.get(key)
                    if prev is None or num > prev[0]:
                        self._latest_dist[key] = (num, dist)
            self._n += 1
            self._finished.add(num)
            self._dirty = True
            self.version += 1

    def _append(self, trial) -> None:
        if self._n == self._capacity:
            self._grow(max(_MIN_CAPACITY, 2 * self._capacity))
        row = self._n
        self._numbers[row] = trial.number
        self._states[row] = int(trial.state)
        self._values[row] = trial.values[0] if trial.values else np.nan
        vals = trial.values or []
        self._values_len[row] = len(vals)
        m = self._values_mat.shape[1]
        if len(vals) == m:
            self._values_mat[row, :] = vals
        # wrong-arity rows stay NaN: the Pareto engine excludes them via the
        # arity column, matching the frozen pairwise loop's length filter
        last = trial.last_step
        self._last_iv[row] = (
            trial.intermediate_values[last] if last is not None else np.nan
        )
        gid = trial.system_attrs.get(_GRID_ATTR)
        self._grid_ids[row] = int(gid) if gid is not None else -1
        for name, dist in trial.distributions.items():
            col = self._cols.get(name)
            if col is None:
                col = np.full(self._capacity, np.nan)
                self._cols[name] = col
            col[row] = float(dist.to_internal([trial.params[name]])[0])
            self._dists[name] = dist
            code = self._type_codes.setdefault(type(dist), len(self._type_codes))
            trow = self._type_rows.get(name)
            if trow is None:
                trow = np.full(self._capacity, -1, dtype=np.int8)
                self._type_rows[name] = trow
            trow[row] = code
            if trial.state in (TrialState.COMPLETE, TrialState.PRUNED):
                key = (name, code, int(trial.state))
                prev = self._latest_dist.get(key)
                if prev is None or trial.number > prev[0]:
                    self._latest_dist[key] = (trial.number, dist)
        self._n += 1
        self._finished.add(trial.number)
        self._dirty = True
        self.version += 1

    def _grow(self, capacity: int) -> None:
        def enlarge(arr: np.ndarray, fill) -> np.ndarray:
            out = np.full(capacity, fill, dtype=arr.dtype)
            out[: self._n] = arr[: self._n]
            return out

        self._numbers = enlarge(self._numbers, 0)
        self._states = enlarge(self._states, 0)
        self._values = enlarge(self._values, np.nan)
        self._values_len = enlarge(self._values_len, 0)
        m = self._values_mat.shape[1]
        vmat = np.full((capacity, m), np.nan)
        vmat[: self._n] = self._values_mat[: self._n]
        self._values_mat = vmat
        self._last_iv = enlarge(self._last_iv, np.nan)
        self._grid_ids = enlarge(self._grid_ids, -1)
        for name in self._cols:
            self._cols[name] = enlarge(self._cols[name], np.nan)
        for name in self._type_rows:
            self._type_rows[name] = enlarge(self._type_rows[name], -1)
        self._capacity = capacity

    def _materialize(self) -> None:
        if not self._dirty:
            return
        n = self._n
        order = np.argsort(self._numbers[:n], kind="stable")

        def view(arr: np.ndarray) -> np.ndarray:
            out = arr[:n][order]
            out.flags.writeable = False
            return out

        self._view_numbers = view(self._numbers)
        self._view_states = view(self._states)
        self._view_values = view(self._values)
        self._view_values_mat = view(self._values_mat)
        self._view_values_len = view(self._values_len)
        self._view_last_iv = view(self._last_iv)
        self._view_grid_ids = view(self._grid_ids)
        self._view_cols = {name: view(col) for name, col in self._cols.items()}
        self._view_type_rows = {
            name: view(row) for name, row in self._type_rows.items()
        }
        self._dirty = False

    # -- columnar accessors (all number-ordered, read-only) ---------------------

    @property
    def n_observations(self) -> int:
        with self._lock:
            return self._n

    @property
    def numbers(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_numbers

    @property
    def states(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_states

    @property
    def values(self) -> np.ndarray:
        """First objective value per finished trial (NaN when absent)."""
        with self._lock:
            self._materialize()
            return self._view_values

    @property
    def n_objectives(self) -> "int | None":
        """Number of study objectives (None until the first refresh)."""
        with self._lock:
            return self._n_objectives

    @property
    def values_matrix(self) -> np.ndarray:
        """``(n_trials, n_objectives)`` matrix of final objective vectors,
        number-ordered.  Rows are NaN where the trial carried no values or a
        wrong-arity vector (see :attr:`values_arity`) — the substrate of the
        multi-objective engine (``core/moo.py``)."""
        with self._lock:
            self._materialize()
            return self._view_values_mat

    @property
    def values_arity(self) -> np.ndarray:
        """``len(trial.values)`` per finished trial (0 when absent).  The
        Pareto engine masks on ``values_arity == n_objectives`` to reproduce
        the frozen pairwise loop's length filter exactly."""
        with self._lock:
            self._materialize()
            return self._view_values_len

    @property
    def last_intermediate_values(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_last_iv

    @property
    def grid_ids(self) -> np.ndarray:
        """Grid-sampler cell ids per finished trial (-1 where unclaimed)."""
        with self._lock:
            self._materialize()
            return self._view_grid_ids

    def intersection_space(
        self, include_pruned: bool = False
    ) -> "dict[str, BaseDistribution]":
        """The intersection search space over finished trials, as one vector
        op per parameter: a parameter survives iff its type-code row has no
        -1 (absent) and a single code across the state mask; the returned
        distribution is the one from the highest-numbered included trial
        (bounds may drift).  Semantics match
        ``search_space.intersection_search_space``."""
        with self._lock:
            self._materialize()
            states = self._view_states
            mask = states == int(TrialState.COMPLETE)
            allowed = [TrialState.COMPLETE]
            if include_pruned:
                mask = mask | (states == int(TrialState.PRUNED))
                allowed.append(TrialState.PRUNED)
            if not bool(mask.any()):
                return {}
            out: dict[str, "BaseDistribution"] = {}
            for name, trow in self._view_type_rows.items():
                codes = trow[mask]
                code = int(codes[0])
                if code < 0 or bool((codes != code).any()):
                    continue
                cands = [
                    ent
                    for st in allowed
                    if (ent := self._latest_dist.get((name, code, int(st))))
                ]
                if cands:
                    out[name] = max(cands, key=lambda e: e[0])[1]
            return dict(sorted(out.items()))

    def co_occurrence(
        self, names: "list[str] | None" = None, include_pruned: bool = True
    ) -> tuple[list[str], np.ndarray]:
        """``(names, mask)`` where ``mask[i, j]`` is True iff parameters
        ``names[i]`` and ``names[j]`` were both suggested by at least one
        observed trial — the relation whose connected components are the
        joint-sampling groups (see ``search_space.observed_groups``).

        Computed as one boolean matmul over the store's dist-type rows
        (presence = type code >= 0), restricted to COMPLETE (and by default
        PRUNED) trials so the grouping matches the observations samplers
        actually model."""
        with self._lock:
            self._materialize()
            names = self.param_names() if names is None else list(names)
            if not names or self._n == 0:
                return names, np.zeros((len(names), len(names)), dtype=bool)
            states = self._view_states
            mask = states == int(TrialState.COMPLETE)
            if include_pruned:
                mask = mask | (states == int(TrialState.PRUNED))
            absent = np.full(self._n, -1, dtype=np.int8)
            present = np.stack(
                [self._view_type_rows.get(n, absent) >= 0 for n in names], axis=1
            )
            present = present & mask[:, None]
            p = present.astype(np.int64)
            return names, (p.T @ p) > 0

    def snapshot(self) -> tuple:
        """``(version, states, values, last_intermediate_values, cols)`` as
        one **consistent** set of number-ordered read-only views, taken under
        a single lock acquisition.  Concurrent refreshes replace the view
        arrays and the column dict wholesale (never mutate them), so a
        caller holding a snapshot keeps seeing one coherent history even
        while other threads tell new trials — mixing individual property
        reads across a refresh does not have that guarantee."""
        with self._lock:
            self._materialize()
            return (
                self.version,
                self._view_states,
                self._view_values,
                self._view_last_iv,
                self._view_cols,
            )

    def snapshot_mo(self) -> tuple:
        """Multi-objective sibling of :meth:`snapshot`: ``(version, states,
        values_matrix, values_arity, numbers, cols)`` as one consistent set
        of number-ordered read-only views under a single lock acquisition —
        mixing individual property reads across a concurrent refresh could
        pair a stale mask with a re-sorted matrix."""
        with self._lock:
            self._materialize()
            return (
                self.version,
                self._view_states,
                self._view_values_mat,
                self._view_values_len,
                self._view_numbers,
                self._view_cols,
            )

    def param_names(self) -> list[str]:
        with self._lock:
            return sorted(self._cols)

    def column(self, name: str) -> "np.ndarray | None":
        """Model-space values of one parameter (NaN where not suggested)."""
        with self._lock:
            self._materialize()
            return self._view_cols.get(name)

    def distribution(self, name: str) -> "BaseDistribution | None":
        with self._lock:
            return self._dists.get(name)

    def matrix(self, names: "list[str] | None" = None) -> np.ndarray:
        """The ``(n_trials, n_params)`` model-space matrix (NaN = missing)."""
        with self._lock:
            self._materialize()
            names = self.param_names() if names is None else names
            if not names:
                return np.empty((self._n, 0))
            cols = [
                self._view_cols.get(n, np.full(self._n, np.nan)) for n in names
            ]
            return np.stack(cols, axis=1) if self._n else np.empty((0, len(names)))

    def design_matrix(self, names: list[str]) -> tuple[np.ndarray, np.ndarray]:
        """``(X, y)`` over COMPLETE trials that carry a value and suggested
        every parameter in ``names`` — the rows relational samplers (CMA-ES,
        GP) train on, straight from the store with no re-encoding."""
        with self._lock:
            self._materialize()
            mask = (self._view_states == int(TrialState.COMPLETE)) & ~np.isnan(
                self._view_values
            )
            cols = []
            for name in names:
                col = self._view_cols.get(name)
                if col is None:
                    return np.empty((0, len(names))), np.empty(0)
                mask = mask & ~np.isnan(col)
                cols.append(col)
            if not names:
                return np.empty((int(mask.sum()), 0)), self._view_values[mask]
            X = np.stack([c[mask] for c in cols], axis=1)
            return X, self._view_values[mask]


class IntermediateValueStore:
    """Revision-gated ``(n_trials, n_steps)`` matrix of reported values.

    * Rows are indexed directly by trial ``number`` (dense per the storage
      contract); columns by a sorted side table of the distinct steps seen so
      far, so sparse or irregular step grids (rungs 1, 2, 4, 8, ...) cost
      only the columns they use.  Cells are NaN where nothing was reported.
    * ``states`` / ``trial_ids`` vectors are aligned with the rows; rows not
      yet observed carry state -1 so every pruner mask excludes them.
    * ``best_so_far(minimize)`` caches the NaN-ignoring prefix-best matrix
      (``np.fmin/fmax.accumulate`` over the step axis) — the array the
      percentile pruners slice one column out of per decision.
    * ``refresh()`` is O(1) when the storage's ``get_trials_revision`` is
      unchanged; otherwise it refetches only ``number >= watermark``, where
      the watermark advances over the dense *finished* prefix (finished
      trials are immutable, so their rows are never rewritten; RUNNING rows
      are re-encoded each refresh because their dicts mutate in place).

    Every backend hosts one instance per study for the fused
    ``report_and_prune`` storage op; ``Study.intermediate_values()`` exposes
    a client-side one for direct ``pruner.prune`` calls.  Readers that slice
    several arrays must do so inside ``with store.lock():`` for a torn-free
    snapshot.
    """

    def __init__(self, storage: "BaseStorage", study_id: int, track_dirty: bool = False):
        self._storage = storage
        self._study_id = study_id
        self._lock = threading.RLock()

        self._n_rows = 0
        self._row_cap = 0
        self._steps = np.empty(0, dtype=np.int64)  # sorted distinct steps
        self._step_index: dict[int, int] = {}
        self._matrix = np.empty((0, 0))
        self._states = np.empty(0, dtype=np.int64)
        self._trial_ids = np.empty(0, dtype=np.int64)
        self._row_len = np.empty(0, dtype=np.int64)  # reported values per row
        # per-objective vector reports (multi-objective learning curves):
        # a lazily-created (row_cap, n_steps, n_objectives) tensor plus a
        # per-row arity column (0 = scalar-only trial), mirroring the
        # observation store's values_arity.  Scalar studies never allocate
        # the tensor, so the widened store costs them nothing.
        self._n_obj = 1
        self._vtensor: "np.ndarray | None" = None
        self._iv_arity = np.empty(0, dtype=np.int64)

        self._watermark = 0  # every number < watermark is finished + encoded
        self._revision: int | None = None
        self._revision_supported = True
        self._block_supported = True  # see ObservationStore._block_supported
        self._bsf: dict[bool, np.ndarray] = {}  # minimize? -> prefix-best

        # per-trial dirty tracking (hosted stores only): backends note every
        # intermediate-value write via ``note_dirty``, so a refresh re-encodes
        # only the changed RUNNING rows instead of every row past the
        # watermark.  Rows whose state or report count changed are re-encoded
        # even without a note (covers writers on *other* storage instances —
        # only a same-length step overwrite from a foreign process can hide,
        # and reports are append-per-step in practice).
        self._track_dirty = track_dirty
        self._dirty: set[int] = set()          # row numbers noted changed
        self._dirty_unknown = False            # a note arrived for an unseen id
        self._id_to_row: dict[int, int] = {}
        #: rows (re-)encoded so far — observability hook, pinned by tests
        self.reencode_count = 0

        #: bumped whenever any cell changes; decisions may key caches on it
        self.version = 0

    def lock(self):
        """Context manager for a consistent multi-array read."""
        return self._lock

    # -- maintenance -----------------------------------------------------------

    def note_dirty(self, trial_id: int) -> None:
        """Mark one trial's row as changed (called by backends on every
        intermediate-value write).  O(1); unknown ids — a trial reported
        before this store ever encoded it — set a conservative flag that
        forces the next refresh to re-encode every fetched row."""
        with self._lock:
            row = self._id_to_row.get(trial_id)
            if row is not None:
                self._dirty.add(row)
            else:
                self._dirty_unknown = True

    def refresh(self) -> None:
        with self._lock:
            rev = _poll_revision(self)
            if (
                rev is not None and rev == self._revision
                and not self._dirty and not self._dirty_unknown
            ):
                # a note may land *after* the write it describes was already
                # fetched under this revision — the dirty check above keeps
                # that row from going stale until the next unrelated mutation
                telemetry.inc("records.iv.refresh.noop")
                return
            telemetry.inc("records.iv.refresh.fetch")
            if self._block_supported and getattr(
                self._storage, "supports_block_fetch", False
            ):
                try:
                    block = self._storage.get_iv_block(self._study_id, self._watermark)
                except NotImplementedError:
                    self._block_supported = False
                else:
                    telemetry.inc("records.iv.refresh.block")
                    self._ingest_block(block)
                    self._revision = rev
                    return
            fresh = get_trials_since(
                self._storage, self._study_id, self._watermark, deepcopy=False
            )
            if fresh:
                self._ingest(fresh)
            else:
                # nothing at/after the watermark: any noted row is finished
                # (immutable), so the dirty state carries no information —
                # clear it or a spurious note would pin refreshes forever
                self._dirty.clear()
                self._dirty_unknown = False
            self._revision = rev

    def _ingest_block(self, block: dict) -> None:
        """Ingest a ``get_iv_block`` CSR payload — the same row writes
        :meth:`_ingest` performs, but cell placement is one vectorized
        ``searchsorted`` scatter per row instead of a Python dict walk."""
        n = int(block["n"])
        if n == 0:
            self._dirty.clear()
            self._dirty_unknown = False
            return
        numbers, states = block["numbers"], block["states"]
        trial_ids, rowptr = block["trial_ids"], block["rowptr"]
        steps, vals = block["steps"], block["vals"]
        top = int(numbers.max())
        if top >= self._row_cap:
            self._grow_rows(max(_MIN_CAPACITY, 2 * self._row_cap, top + 1))
        self._n_rows = max(self._n_rows, top + 1)

        # optional per-objective vector columns (flat CSR keyed by trial
        # number): absent entirely on scalar studies — see build_iv_block
        vec_map: dict[int, list] = {}
        vec_numbers = block.get("vec_numbers")
        if vec_numbers is not None and len(vec_numbers):
            vec_steps, vec_ptr = block["vec_steps"], block["vec_ptr"]
            vec_vals = block["vec_vals"]
            for j in range(len(vec_numbers)):
                lo, hi = int(vec_ptr[j]), int(vec_ptr[j + 1])
                vec_map.setdefault(int(vec_numbers[j]), []).append(
                    (int(vec_steps[j]), vec_vals[lo:hi])
                )

        skip_clean = self._track_dirty and not self._dirty_unknown
        sel = []
        for i in range(n):
            row = int(numbers[i])
            cnt = int(rowptr[i + 1] - rowptr[i])
            if (
                skip_clean
                and row not in self._dirty
                and self._states[row] == int(states[i])
                and self._row_len[row] == cnt
            ):
                continue  # clean RUNNING row: state and report count unchanged
            sel.append(i)

        new_steps = {
            int(s)
            for i in sel
            for s in steps[int(rowptr[i]) : int(rowptr[i + 1])]
            if int(s) not in self._step_index
        }
        for i in sel:
            for s, _ in vec_map.get(int(numbers[i]), ()):
                if s not in self._step_index:
                    new_steps.add(s)
        if new_steps:
            self._grow_cols(new_steps)

        for i in sel:
            row = int(numbers[i])
            tid = int(trial_ids[i])
            self._states[row] = int(states[i])
            self._trial_ids[row] = tid
            self._id_to_row[tid] = row
            self._matrix[row, :] = np.nan
            lo, hi = int(rowptr[i]), int(rowptr[i + 1])
            if hi > lo:
                self._matrix[row, np.searchsorted(self._steps, steps[lo:hi])] = vals[lo:hi]
            self._row_len[row] = hi - lo
            vitems = vec_map.get(row)
            if vitems:
                self._ensure_objectives(max(len(v) for _, v in vitems))
                self._vtensor[row, :, :] = np.nan
                for s, v in vitems:
                    self._vtensor[row, self._step_index[s], : len(v)] = v
                self._iv_arity[row] = max(len(v) for _, v in vitems)
            elif self._vtensor is not None and self._iv_arity[row]:
                self._vtensor[row, :, :] = np.nan
                self._iv_arity[row] = 0
            self.reencode_count += 1
        self._dirty.clear()
        self._dirty_unknown = False
        if sel:
            telemetry.inc("records.iv.rows_reencoded", len(sel))
        while self._watermark < self._n_rows and TrialState(
            self._states[self._watermark]
        ).is_finished():
            self._watermark += 1
        if sel:
            self._bsf.clear()
            self.version += 1

    def _ingest(self, trials) -> None:
        top = max(t.number for t in trials)
        if top >= self._row_cap:
            self._grow_rows(max(_MIN_CAPACITY, 2 * self._row_cap, top + 1))
        self._n_rows = max(self._n_rows, top + 1)

        # deepcopy=False feeds live dict refs on in-process backends: a
        # concurrent report can mutate mid-iteration, so snapshot with retry
        def snapshot(t) -> list:
            for _ in range(3):
                try:
                    return list(t.intermediate_values.items())
                except RuntimeError:  # pragma: no cover - dict-resize race
                    continue
            return list(t.intermediate_values.items())

        # per-objective vectors ride on iv_vec:<step> system attrs -> same
        # live-dict snapshot policy as the scalar reports above
        def vec_snapshot(t) -> list:
            for _ in range(3):
                try:
                    return [
                        (int(k[len(IV_VEC_PREFIX):]), [float(x) for x in v])
                        for k, v in t.system_attrs.items()
                        if isinstance(k, str) and k.startswith(IV_VEC_PREFIX)
                    ]
                except (RuntimeError, TypeError, ValueError):  # pragma: no cover
                    continue
            return []

        rows = []
        skip_clean = self._track_dirty and not self._dirty_unknown
        for t in trials:
            row = t.number
            if (
                skip_clean
                and row not in self._dirty
                and self._states[row] == int(t.state)  # -1 (never encoded) differs
                and self._row_len[row] == len(t.intermediate_values)
            ):
                continue  # clean RUNNING row: state and report count unchanged
            rows.append((row, t, snapshot(t), vec_snapshot(t)))

        new_steps = set()
        for _, _, items, vec_items in rows:
            for s, _ in items:
                if int(s) not in self._step_index:
                    new_steps.add(int(s))
            for s, _ in vec_items:
                if int(s) not in self._step_index:
                    new_steps.add(int(s))
        if new_steps:
            self._grow_cols(new_steps)

        for row, t, items, vec_items in rows:
            self._states[row] = int(t.state)
            self._trial_ids[row] = t.trial_id
            self._id_to_row[t.trial_id] = row
            self._matrix[row, :] = np.nan
            for s, v in items:
                self._matrix[row, self._step_index[int(s)]] = v
            self._row_len[row] = len(items)
            if vec_items:
                self._ensure_objectives(max(len(v) for _, v in vec_items))
                self._vtensor[row, :, :] = np.nan
                for s, v in vec_items:
                    self._vtensor[row, self._step_index[int(s)], : len(v)] = v
                self._iv_arity[row] = max(len(v) for _, v in vec_items)
            elif self._vtensor is not None and self._iv_arity[row]:
                self._vtensor[row, :, :] = np.nan
                self._iv_arity[row] = 0
            self.reencode_count += 1
        self._dirty.clear()
        self._dirty_unknown = False
        if rows:
            telemetry.inc("records.iv.rows_reencoded", len(rows))
        while self._watermark < self._n_rows and TrialState(
            self._states[self._watermark]
        ).is_finished():
            self._watermark += 1
        if rows:
            self._bsf.clear()
            self.version += 1

    def _grow_rows(self, capacity: int) -> None:
        n_cols = self._matrix.shape[1]
        matrix = np.full((capacity, n_cols), np.nan)
        matrix[: self._n_rows] = self._matrix[: self._n_rows]
        self._matrix = matrix
        if self._vtensor is not None:
            vt = np.full((capacity, n_cols, self._n_obj), np.nan)
            vt[: self._n_rows] = self._vtensor[: self._n_rows]
            self._vtensor = vt

        def enlarge(arr: np.ndarray, fill) -> np.ndarray:
            out = np.full(capacity, fill, dtype=arr.dtype)
            out[: self._n_rows] = arr[: self._n_rows]
            return out

        self._states = enlarge(self._states, -1)
        self._trial_ids = enlarge(self._trial_ids, -1)
        self._row_len = enlarge(self._row_len, 0)
        self._iv_arity = enlarge(self._iv_arity, 0)
        self._row_cap = capacity

    def _grow_cols(self, new_steps: set) -> None:
        steps = np.asarray(
            sorted(set(self._steps.tolist()) | new_steps), dtype=np.int64
        )
        matrix = np.full((self._row_cap, len(steps)), np.nan)
        if self._steps.size:
            matrix[:, np.searchsorted(steps, self._steps)] = self._matrix
        if self._vtensor is not None:
            vt = np.full((self._row_cap, len(steps), self._n_obj), np.nan)
            if self._steps.size:
                vt[:, np.searchsorted(steps, self._steps), :] = self._vtensor
            self._vtensor = vt
        self._matrix = matrix
        self._steps = steps
        self._step_index = {int(s): j for j, s in enumerate(steps)}

    def _ensure_objectives(self, arity: int) -> None:
        """Widen (or create) the per-objective tensor to ``arity`` slots."""
        if arity <= self._n_obj and self._vtensor is not None:
            return
        n_obj = max(arity, self._n_obj)
        vt = np.full((self._row_cap, self._matrix.shape[1], n_obj), np.nan)
        if self._vtensor is not None:
            vt[:, :, : self._n_obj] = self._vtensor
        self._vtensor = vt
        self._n_obj = n_obj

    # -- accessors (hold ``lock()`` across multi-array reads) -------------------

    @staticmethod
    def _ro(arr: np.ndarray) -> np.ndarray:
        """Read-only view: these buffers are long-lived and shared across
        every decision on the backend — a caller mutating one would corrupt
        peer data for all subsequent prunes (same policy as the
        ObservationStore views)."""
        out = arr.view()
        out.flags.writeable = False
        return out

    @property
    def n_rows(self) -> int:
        with self._lock:
            return self._n_rows

    @property
    def steps(self) -> np.ndarray:
        with self._lock:
            return self._ro(self._steps)

    @property
    def states(self) -> np.ndarray:
        with self._lock:
            return self._ro(self._states[: self._n_rows])

    @property
    def trial_ids(self) -> np.ndarray:
        with self._lock:
            return self._ro(self._trial_ids[: self._n_rows])

    @property
    def matrix(self) -> np.ndarray:
        with self._lock:
            return self._ro(self._matrix[: self._n_rows])

    @property
    def n_objectives(self) -> int:
        """Widest vector arity seen so far (1 while scalar-only)."""
        with self._lock:
            return self._n_obj if self._vtensor is not None else 1

    @property
    def iv_arity(self) -> np.ndarray:
        """Per-row vector arity (0 = scalar-only reports), aligned with
        :attr:`states` — the IV sibling of ``ObservationStore.values_arity``."""
        with self._lock:
            return self._ro(self._iv_arity[: self._n_rows])

    def objective_matrix(self, objective: int = 0) -> np.ndarray:
        """One objective's ``(n_trials, n_steps)`` learning-curve matrix.

        Rows that reported vectors read from the per-objective tensor; rows
        that reported plain scalars fall back to the scalar matrix for
        ``objective == 0`` (a scalar report *is* objective 0) and stay NaN
        for higher objectives.  Note the scalar matrix itself is not that
        fallback for vector rows — there it holds the pruner-facing
        scalarized loss."""
        objective = int(objective)
        with self._lock:
            n = self._n_rows
            if self._vtensor is None:
                if objective == 0:
                    return self._ro(self._matrix[:n])
                return self._ro(np.full((n, self._matrix.shape[1]), np.nan))
            if objective >= self._n_obj:
                return self._ro(np.full((n, self._matrix.shape[1]), np.nan))
            out = self._vtensor[:n, :, objective].copy()
            if objective == 0:
                scalar_rows = self._iv_arity[:n] == 0
                out[scalar_rows] = self._matrix[:n][scalar_rows]
            out.flags.writeable = False
            return out

    def step_index(self, step: int) -> "int | None":
        """Column of exactly ``step``, or None if never reported."""
        with self._lock:
            return self._step_index.get(int(step))

    def index_upto(self, step: int) -> int:
        """Column of the largest recorded step <= ``step`` (-1 if none)."""
        with self._lock:
            return int(np.searchsorted(self._steps, int(step), side="right")) - 1

    def step_column(self, step: int) -> "np.ndarray | None":
        """All trials' values at exactly ``step`` (NaN where unreported)."""
        with self._lock:
            j = self._step_index.get(int(step))
            return self._ro(self._matrix[: self._n_rows, j]) if j is not None else None

    def best_so_far(self, minimize: bool) -> np.ndarray:
        """Prefix-best matrix: cell (i, j) is trial i's best reported value
        over steps[0..j], ignoring NaN reports (NaN iff none reported)."""
        with self._lock:
            cached = self._bsf.get(minimize)
            if cached is None:
                op = np.fmin if minimize else np.fmax
                cached = op.accumulate(self._matrix[: self._n_rows], axis=1)
                cached.flags.writeable = False
                self._bsf[minimize] = cached
            return cached
