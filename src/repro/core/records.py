"""Columnar observation store — the sampler stack's shared array substrate.

Before this module existed, every ``ask`` re-materialized the full trial
history as Python ``FrozenTrial`` lists and looped per-parameter in scalar
numpy — O(trials x params) interpreter work per trial.  The
:class:`ObservationStore` replaces that with an incrementally-maintained
structure-of-arrays view of *finished* trials:

* one ``(n_trials, n_params)`` float64 matrix in **model space**
  (log-transformed numerics / categorical indices; see
  ``BaseDistribution.to_internal``), NaN where a trial did not suggest a
  parameter (define-by-run conditionals),
* aligned ``numbers`` / ``states`` / ``values`` (first objective) /
  ``last_intermediate_values`` vectors.

Maintenance is incremental and storage-agnostic:

* ``refresh()`` first polls the storage's monotonic **revision counter**
  (``get_trials_revision``) — if nothing changed since the last look, the
  refresh is O(1) and touches no trial data,
* otherwise it fetches only the suffix ``number >= watermark`` via
  ``get_all_trials(since=...)`` (the same hook :class:`CachedStorage` uses,
  so the two compose: through a cached remote backend a refresh is at most
  one revision RPC),
* finished trials are immutable (BaseStorage contract), so each is encoded
  into the matrix exactly once, O(n_params) amortized per ``Study.tell``.

Out-of-order finishes (trial #5 completing before #3) are appended as they
arrive; the number-sorted view is re-materialized lazily, only when new rows
landed.  Returned arrays are read-only views shared between callers — never
mutate them.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING

import numpy as np

from .frozen import TrialState
from .storage.base import get_trials_since

if TYPE_CHECKING:
    from .distributions import BaseDistribution
    from .storage.base import BaseStorage

__all__ = ["ObservationStore"]

_MIN_CAPACITY = 32


class ObservationStore:
    def __init__(self, storage: "BaseStorage", study_id: int):
        self._storage = storage
        self._study_id = study_id
        self._lock = threading.RLock()

        self._n = 0
        self._capacity = 0
        self._numbers = np.empty(0, dtype=np.int64)
        self._states = np.empty(0, dtype=np.int64)
        self._values = np.empty(0)
        self._last_iv = np.empty(0)
        self._cols: dict[str, np.ndarray] = {}
        self._dists: dict[str, "BaseDistribution"] = {}

        self._watermark = 0          # every number < watermark is ingested
        self._finished: set[int] = set()  # ingested numbers >= watermark
        self._revision: int | None = None
        self._revision_supported = True

        self._dirty = False
        self._view_numbers = self._numbers
        self._view_states = self._states
        self._view_values = self._values
        self._view_last_iv = self._last_iv
        self._view_cols: dict[str, np.ndarray] = {}

        #: bumped whenever new observations land; samplers key caches on it
        self.version = 0

    # -- maintenance -----------------------------------------------------------

    def refresh(self) -> None:
        """Bring the store up to date with storage.  O(1) when the storage
        revision is unchanged; otherwise one incremental suffix fetch."""
        with self._lock:
            rev: int | None = None
            if self._revision_supported:
                get_rev = getattr(self._storage, "get_trials_revision", None)
                if get_rev is None:
                    self._revision_supported = False
                else:
                    try:
                        rev = get_rev(self._study_id)
                    except NotImplementedError:
                        self._revision_supported = False
            if rev is not None and rev == self._revision:
                return
            # capture the revision *before* reading trial data: concurrent
            # writes between the two reads surface as a new revision next time
            fresh = get_trials_since(
                self._storage, self._study_id, self._watermark, deepcopy=False
            )
            for t in fresh:
                if not t.state.is_finished() or t.number in self._finished:
                    continue
                self._append(t)
            while self._watermark in self._finished:
                self._finished.discard(self._watermark)
                self._watermark += 1
            self._revision = rev

    def _append(self, trial) -> None:
        if self._n == self._capacity:
            self._grow(max(_MIN_CAPACITY, 2 * self._capacity))
        row = self._n
        self._numbers[row] = trial.number
        self._states[row] = int(trial.state)
        self._values[row] = trial.values[0] if trial.values else np.nan
        last = trial.last_step
        self._last_iv[row] = (
            trial.intermediate_values[last] if last is not None else np.nan
        )
        for name, dist in trial.distributions.items():
            col = self._cols.get(name)
            if col is None:
                col = np.full(self._capacity, np.nan)
                self._cols[name] = col
            col[row] = float(dist.to_internal([trial.params[name]])[0])
            self._dists[name] = dist
        self._n += 1
        self._finished.add(trial.number)
        self._dirty = True
        self.version += 1

    def _grow(self, capacity: int) -> None:
        def enlarge(arr: np.ndarray, fill) -> np.ndarray:
            out = np.full(capacity, fill, dtype=arr.dtype)
            out[: self._n] = arr[: self._n]
            return out

        self._numbers = enlarge(self._numbers, 0)
        self._states = enlarge(self._states, 0)
        self._values = enlarge(self._values, np.nan)
        self._last_iv = enlarge(self._last_iv, np.nan)
        for name in self._cols:
            self._cols[name] = enlarge(self._cols[name], np.nan)
        self._capacity = capacity

    def _materialize(self) -> None:
        if not self._dirty:
            return
        n = self._n
        order = np.argsort(self._numbers[:n], kind="stable")

        def view(arr: np.ndarray) -> np.ndarray:
            out = arr[:n][order]
            out.flags.writeable = False
            return out

        self._view_numbers = view(self._numbers)
        self._view_states = view(self._states)
        self._view_values = view(self._values)
        self._view_last_iv = view(self._last_iv)
        self._view_cols = {name: view(col) for name, col in self._cols.items()}
        self._dirty = False

    # -- columnar accessors (all number-ordered, read-only) ---------------------

    @property
    def n_observations(self) -> int:
        with self._lock:
            return self._n

    @property
    def numbers(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_numbers

    @property
    def states(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_states

    @property
    def values(self) -> np.ndarray:
        """First objective value per finished trial (NaN when absent)."""
        with self._lock:
            self._materialize()
            return self._view_values

    @property
    def last_intermediate_values(self) -> np.ndarray:
        with self._lock:
            self._materialize()
            return self._view_last_iv

    def param_names(self) -> list[str]:
        with self._lock:
            return sorted(self._cols)

    def column(self, name: str) -> "np.ndarray | None":
        """Model-space values of one parameter (NaN where not suggested)."""
        with self._lock:
            self._materialize()
            return self._view_cols.get(name)

    def distribution(self, name: str) -> "BaseDistribution | None":
        with self._lock:
            return self._dists.get(name)

    def matrix(self, names: "list[str] | None" = None) -> np.ndarray:
        """The ``(n_trials, n_params)`` model-space matrix (NaN = missing)."""
        with self._lock:
            self._materialize()
            names = self.param_names() if names is None else names
            if not names:
                return np.empty((self._n, 0))
            cols = [
                self._view_cols.get(n, np.full(self._n, np.nan)) for n in names
            ]
            return np.stack(cols, axis=1) if self._n else np.empty((0, len(names)))

    def design_matrix(self, names: list[str]) -> tuple[np.ndarray, np.ndarray]:
        """``(X, y)`` over COMPLETE trials that carry a value and suggested
        every parameter in ``names`` — the rows relational samplers (CMA-ES,
        GP) train on, straight from the store with no re-encoding."""
        with self._lock:
            self._materialize()
            mask = (self._view_states == int(TrialState.COMPLETE)) & ~np.isnan(
                self._view_values
            )
            cols = []
            for name in names:
                col = self._view_cols.get(name)
                if col is None:
                    return np.empty((0, len(names))), np.empty(0)
                mask = mask & ~np.isnan(col)
                cols.append(col)
            if not names:
                return np.empty((int(mask.sum()), 0)), self._view_values[mask]
            X = np.stack([c[mask] for c in cols], axis=1)
            return X, self._view_values[mask]
