"""Zero-dependency telemetry: metrics registry, timing spans, trial event trace.

Two pillars (ISSUE 6):

* A **metrics registry** — counters, gauges, and fixed-bucket latency
  histograms with interpolated p50/p95/p99 — all thread-safe and near-zero
  cost when disabled.  The module-level helpers (:func:`inc`, :func:`span`,
  :func:`observe`, ...) route through one global registry that is **off by
  default**: a disabled ``span()`` returns a shared no-op context manager and
  a disabled ``inc()`` is a single attribute check, so instrumented hot paths
  (``Study.ask``, the fused ``report_and_prune``, every ``RemoteStorage``
  RPC) pay well under the 2% budget pinned by ``benchmarks/storage_bench.py``.
  ``StorageServer`` owns a *separate* always-on registry so
  ``get_server_metrics`` works without globally enabling client telemetry.

* A **trial-lifecycle event trace** — :class:`TrialEventLog` records
  created/claimed/reported/pruned/completed/failed events columnarly
  (int8 kinds, int64 numbers/steps/monotonic-ns timestamps, interned worker
  ids) so a study's full trace costs a few flat arrays, survives the remote
  protocol as plain JSON columns (``BaseStorage.get_trial_events``), and can
  be diffed event-for-event between an inmemory and a remote run.

Metric names are dotted lowercase ``component.operation[.detail]`` —
e.g. ``study.ask`` (histogram, seconds), ``client.rpc.get_trial`` (histogram),
``cached.get_trial.hit`` (counter), ``server.bytes_in`` (counter).  Latency
histograms are always in **seconds**.
"""

from __future__ import annotations

import bisect
import math
import os
import socket
import threading
import time
from typing import Any, Iterator

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TrialEventLog",
    "EVENT_KINDS",
    "EV_CREATED",
    "EV_CLAIMED",
    "EV_REPORTED",
    "EV_PRUNED",
    "EV_COMPLETED",
    "EV_FAILED",
    "enable",
    "disable",
    "enabled",
    "counter",
    "gauge",
    "histogram",
    "inc",
    "set_gauge",
    "observe",
    "span",
    "snapshot",
    "reset",
    "worker_id",
    "set_worker_context",
]


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic int counter; ``inc`` is lock-guarded (int += is not atomic)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins scalar (active connections, queue depths, ...)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += float(dv)

    @property
    def value(self) -> float:
        return self._value


# Fixed geometric bucket grid shared by every histogram: 10 buckets/decade
# from 100ns to 100s.  Latencies are recorded in seconds; anything above the
# top bound lands in the overflow bucket and percentiles clamp to max_seen.
_BOUNDS: list[float] = [
    float(b) for b in np.geomspace(1e-7, 100.0, num=91)
]


class Histogram:
    """Fixed-bucket latency histogram with interpolated percentiles.

    Shared geometric bucket bounds (1e-7s .. 100s, 10/decade) keep recording
    O(log n_buckets) via bisect and make snapshots mergeable; percentile
    queries interpolate within the winning bucket, clamped to the observed
    min/max so p99 of a tight distribution doesn't smear to bucket edges.
    """

    __slots__ = ("name", "_counts", "_count", "_sum", "_min", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._counts = [0] * (len(_BOUNDS) + 1)  # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = 0.0
        self._lock = threading.Lock()

    def observe(self, seconds: float) -> None:
        if seconds < 0.0:
            seconds = 0.0
        idx = bisect.bisect_left(_BOUNDS, seconds)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += seconds
            if seconds < self._min:
                self._min = seconds
            if seconds > self._max:
                self._max = seconds

    def percentile(self, q: float) -> float:
        """Interpolated quantile, ``q`` in [0, 1]."""
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            rank = q * total
            cum = 0
            for idx, c in enumerate(self._counts):
                cum += c
                if cum >= rank and c > 0:
                    lo = _BOUNDS[idx - 1] if idx > 0 else 0.0
                    hi = _BOUNDS[idx] if idx < len(_BOUNDS) else self._max
                    frac = (rank - (cum - c)) / c
                    est = lo + (hi - lo) * frac
                    return float(min(max(est, self._min), self._max))
            return float(self._max)

    def summary(self) -> dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
        return {
            "count": count,
            "sum": total,
            "mean": total / count if count else 0.0,
            "min": self._min if count else 0.0,
            "max": self._max,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class _NoopSpan:
    """Shared do-nothing context manager: the disabled-span fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


class MetricsRegistry:
    """Thread-safe name -> instrument map with a machine-readable snapshot."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- instrument accessors (create on first use) --
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name))
        return h

    # -- recording helpers honoring the enabled flag --
    def inc(self, name: str, n: int = 1) -> None:
        if self.enabled:
            self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        if self.enabled:
            self.gauge(name).set(v)

    def observe(self, name: str, seconds: float) -> None:
        if self.enabled:
            self.histogram(name).observe(seconds)

    def span(self, name: str) -> Any:
        if not self.enabled:
            return _NOOP
        return _Span(self.histogram(name))

    def snapshot(self) -> dict[str, Any]:
        """JSON-safe dump: counters/gauges as scalars, histograms summarized."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary() for k, h in sorted(hists.items())},
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# global registry (off by default; spans collapse to _NOOP while disabled)
# ---------------------------------------------------------------------------

_registry = MetricsRegistry(enabled=False)


def enable() -> None:
    _registry.enabled = True


def disable() -> None:
    _registry.enabled = False


def enabled() -> bool:
    return _registry.enabled


def counter(name: str) -> Counter:
    return _registry.counter(name)


def gauge(name: str) -> Gauge:
    return _registry.gauge(name)


def histogram(name: str) -> Histogram:
    return _registry.histogram(name)


def inc(name: str, n: int = 1) -> None:
    if _registry.enabled:
        _registry.counter(name).inc(n)


def set_gauge(name: str, v: float) -> None:
    if _registry.enabled:
        _registry.gauge(name).set(v)


def observe(name: str, seconds: float) -> None:
    if _registry.enabled:
        _registry.histogram(name).observe(seconds)


def span(name: str) -> Any:
    if not _registry.enabled:
        return _NOOP
    return _Span(_registry.histogram(name))


def snapshot() -> dict[str, Any]:
    return _registry.snapshot()


def reset() -> None:
    _registry.reset()


# ---------------------------------------------------------------------------
# worker identity
# ---------------------------------------------------------------------------

_HOST = socket.gethostname()
_tls = threading.local()


def set_worker_context(ident: str | None) -> None:
    """Override this thread's worker id (server handlers set the client's
    peer address so server-recorded events carry *client* identity)."""
    _tls.worker = ident


def worker_id() -> str:
    ident = getattr(_tls, "worker", None)
    if ident is not None:
        return ident
    return f"{_HOST}:{os.getpid()}"


# ---------------------------------------------------------------------------
# trial lifecycle event trace
# ---------------------------------------------------------------------------

EV_CREATED = 0
EV_CLAIMED = 1
EV_REPORTED = 2
EV_PRUNED = 3
EV_COMPLETED = 4
EV_FAILED = 5

EVENT_KINDS = ("created", "claimed", "reported", "pruned", "completed", "failed")


class TrialEventLog:
    """Columnar append-only trial lifecycle trace for one study.

    Events live in parallel numpy columns (int8 kind, int64 trial number /
    step / monotonic-ns timestamp, interned worker-id index) that grow by
    doubling; ``snapshot(since)`` slices them into plain JSON lists so the
    trace crosses the remote protocol for free and incremental pollers fetch
    only the tail.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0
        cap = 64
        self._kind = np.empty(cap, dtype=np.int8)
        self._number = np.empty(cap, dtype=np.int64)
        self._step = np.empty(cap, dtype=np.int64)
        self._t_ns = np.empty(cap, dtype=np.int64)
        self._worker_idx = np.empty(cap, dtype=np.int32)
        self._workers: list[str] = []
        self._worker_ids: dict[str, int] = {}

    def __len__(self) -> int:
        return self._n

    def _grow(self) -> None:
        cap = len(self._kind) * 2
        for name in ("_kind", "_number", "_step", "_t_ns", "_worker_idx"):
            col = getattr(self, name)
            fresh = np.empty(cap, dtype=col.dtype)
            fresh[: self._n] = col[: self._n]
            setattr(self, name, fresh)

    def append(
        self, kind: int, number: int, step: int = -1, worker: str | None = None
    ) -> None:
        if worker is None:
            worker = worker_id()
        t = time.monotonic_ns()
        with self._lock:
            widx = self._worker_ids.get(worker)
            if widx is None:
                widx = len(self._workers)
                self._workers.append(worker)
                self._worker_ids[worker] = widx
            if self._n == len(self._kind):
                self._grow()
            i = self._n
            self._kind[i] = kind
            self._number[i] = number
            self._step[i] = step
            self._t_ns[i] = t
            self._worker_idx[i] = widx
            self._n = i + 1

    def snapshot(self, since: int = 0) -> dict[str, Any]:
        """Columns from event ``since`` on, as a JSON-safe wire dict."""
        with self._lock:
            n = self._n
            since = max(0, min(int(since), n))
            return {
                "since": since,
                "next": n,
                "kind": self._kind[since:n].tolist(),
                "number": self._number[since:n].tolist(),
                "step": self._step[since:n].tolist(),
                "t_ns": self._t_ns[since:n].tolist(),
                "worker_idx": self._worker_idx[since:n].tolist(),
                "workers": list(self._workers),
            }

    def rows(self) -> list[dict[str, Any]]:
        """Expanded per-event dicts (diagnostics / tests), oldest first."""
        snap = self.snapshot()
        return expand_events(snap)


def expand_events(snap: dict[str, Any]) -> list[dict[str, Any]]:
    """Turn a :meth:`TrialEventLog.snapshot` wire dict into per-event rows."""
    workers = snap.get("workers", [])
    out = []
    for kind, number, step, t_ns, widx in zip(
        snap["kind"], snap["number"], snap["step"], snap["t_ns"], snap["worker_idx"]
    ):
        out.append(
            {
                "event": EVENT_KINDS[kind],
                "number": int(number),
                "step": int(step),
                "t_ns": int(t_ns),
                "worker": workers[widx] if 0 <= widx < len(workers) else "?",
            }
        )
    return out


def _iter_event_tuples(snap: dict[str, Any]) -> Iterator[tuple[str, int, int]]:
    """(event, number, step) triples — the worker/time-independent trace."""
    for kind, number, step in zip(snap["kind"], snap["number"], snap["step"]):
        yield (EVENT_KINDS[kind], int(number), int(step))
