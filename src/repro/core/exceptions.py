"""Exceptions for the define-by-run HPO engine."""

from __future__ import annotations


class ReproError(Exception):
    """Base class for all repro.core errors."""


class TrialPruned(ReproError):
    """Raised (by user code or ``Trial.report``-driven logic) to signal that the
    current trial was pruned.

    The ``Study.optimize`` loop catches this exception and marks the trial as
    ``TrialState.PRUNED`` instead of ``FAILED``.  This mirrors the paper's
    'should_prune API' contract (paper Fig. 5).
    """


class StorageInternalError(ReproError):
    """A storage backend failed in a way that retrying cannot fix."""


class DuplicatedStudyError(ReproError):
    """A study with the requested name already exists in the storage."""


class StudyNotFoundError(KeyError, ReproError):
    """No study with the requested name/id exists in the storage."""


class TrialNotFoundError(KeyError, ReproError):
    """No trial with the requested id exists in the storage."""


class RetryableStorageError(ReproError):
    """Transient storage failure (lock contention, torn read); safe to retry."""


class StorageUnavailableError(RetryableStorageError):
    """The storage node cannot serve this call *right now* — e.g. a replica
    that has not been promoted refusing writes during a failover window.
    Clients back off, rotate to another candidate, and retry."""
