"""Multi-process distributed optimization (paper §4, Fig. 7).

The paper's model: run the *same* worker script N times with the same storage
URL and study name.  ``run_workers`` is the programmatic equivalent (spawning
local processes); on a cluster you simply launch ``examples/distributed_study.py``
(or your own script) once per node — workers are stateless and elastic, so
joining late or dying early never corrupts the study.
"""

from __future__ import annotations

import multiprocessing as mp
import time
from typing import Callable

from .frozen import TrialState
from .pruners import BasePruner
from .samplers import BaseSampler
from .storage import StorageServer, get_storage
from .study import Study, load_study

__all__ = ["run_workers", "worker_main", "RetryFailedTrialCallback"]


def worker_main(
    storage_url: str,
    study_name: str,
    objective: Callable,
    n_trials: int,
    sampler_factory: Callable[[], BaseSampler] | None = None,
    pruner_factory: Callable[[], BasePruner] | None = None,
    seed_offset: int | None = None,
    heartbeat_interval: float | None = 2.0,
    timeout: float | None = None,
    use_cache: bool = True,
    ask_batch: int = 1,
) -> None:
    """Entry point executed inside each worker process.

    ``seed_offset`` reseeds the sampler deterministically per worker so
    exploration streams are distinct but reproducible (``None`` keeps the
    nondeterministic default).  ``use_cache`` wraps ``remote://`` storage in
    :class:`CachedStorage` so per-``ask`` reads stay incremental.
    ``ask_batch > 1`` claims that many trials per storage round trip
    (``Study.ask(n)``) — the remote-latency amortization knob.
    """
    storage = get_storage(
        storage_url, cache=use_cache and storage_url.startswith("remote://")
    )
    study = load_study(
        study_name,
        storage,
        sampler=sampler_factory() if sampler_factory else None,
        pruner=pruner_factory() if pruner_factory else None,
    )
    # different workers must explore differently
    study.sampler.reseed_rng(seed_offset)
    study.heartbeat_interval = heartbeat_interval
    study.optimize(
        objective, n_trials=n_trials, timeout=timeout, catch=(Exception,),
        ask_batch=ask_batch,
    )
    storage.close()


def run_workers(
    n_workers: int,
    storage_url: str,
    study_name: str,
    objective: Callable,
    n_trials_per_worker: int,
    sampler_factory: Callable[[], BaseSampler] | None = None,
    pruner_factory: Callable[[], BasePruner] | None = None,
    timeout: float | None = None,
    start_method: str = "fork",
    serve_storage: bool = False,
    serve_host: str = "127.0.0.1",
    use_cache: bool = True,
    ask_batch: int = 1,
    auth_token: str | None = None,
    reclaim_grace: float | None = None,
    reclaim_requeue: bool = False,
) -> float:
    """Launch ``n_workers`` processes optimizing the same study; returns the
    wall-clock duration.  Storage must be shareable across processes
    (``sqlite:///``, ``journal://``, or ``remote://``).

    With ``serve_storage=True`` the parent wraps ``storage_url`` in a
    :class:`StorageServer` and hands workers its ``remote://`` URL instead —
    the pattern for fleets without a shared filesystem: serve once (e.g. over
    a SQLite file local to the server host), point every node at the URL.
    ``auth_token`` arms the server's shared-secret handshake and embeds the
    token in the workers' URL; ``ask_batch`` makes each worker claim that
    many trials per round trip.

    ``reclaim_grace`` (with ``serve_storage=True``) arms the server-side
    sweeper: RUNNING trials whose worker stopped heartbeating for that many
    seconds are FAILed — or re-enqueued as WAITING with
    ``reclaim_requeue=True``, so a surviving worker's ``ask()`` re-runs them.
    """
    server = None
    worker_url = storage_url
    if serve_storage:
        server = StorageServer(
            get_storage(storage_url), host=serve_host, auth_token=auth_token,
            reclaim_grace=reclaim_grace, reclaim_requeue=reclaim_requeue,
        ).start()
        worker_url = (
            f"remote://{auth_token}@{server.host}:{server.port}"
            if auth_token
            else server.url
        )
    ctx = mp.get_context(start_method)
    procs = []
    t0 = time.time()
    try:
        for i in range(n_workers):
            p = ctx.Process(
                target=worker_main,
                args=(worker_url, study_name, objective, n_trials_per_worker),
                kwargs=dict(
                    sampler_factory=sampler_factory,
                    pruner_factory=pruner_factory,
                    seed_offset=i,
                    timeout=timeout,
                    use_cache=use_cache,
                    ask_batch=ask_batch,
                ),
            )
            p.start()
            procs.append(p)
        for p in procs:
            p.join()
    finally:
        if server is not None:
            server.stop()
    return time.time() - t0


class RetryFailedTrialCallback:
    """Study callback: when a trial FAILs (e.g. node preempted), re-enqueue its
    parameters up to ``max_retry`` times.  Combined with heartbeat failover
    this gives at-least-once trial execution under node failures."""

    def __init__(self, max_retry: int = 1):
        self._max_retry = max_retry

    def __call__(self, study: Study, trial) -> None:
        if trial.state != TrialState.FAIL:
            return
        n_prev = int(trial.system_attrs.get("retry:count", 0))
        if n_prev >= self._max_retry:
            return
        study.enqueue_trial(dict(trial.params), user_attrs={"retry_of": trial.number})
        # mark the new enqueued trial's retry depth via study attr on the failed one
        study._storage.set_trial_system_attr(trial.trial_id, "retry:count", n_prev + 1)
