"""Thread-safe in-process storage — the 'lightweight' backend.

This is the zero-setup default the paper calls out as essential for
notebook-scale use (§4): no DB, no files, instant.  Still fully thread-safe so
``study.optimize(n_jobs=k)`` works against it.
"""

from __future__ import annotations

import copy
import threading
import time
from typing import Any, Iterable

from .. import telemetry as _telemetry
from ..distributions import BaseDistribution, check_distribution_compatibility
from ..exceptions import DuplicatedStudyError, StudyNotFoundError, TrialNotFoundError
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary

__all__ = ["InMemoryStorage"]


class _StudyRecord:
    def __init__(self, study_id: int, name: str, directions: list[StudyDirection]):
        self.study_id = study_id
        self.name = name
        self.directions = directions
        self.user_attrs: dict[str, Any] = {}
        self.system_attrs: dict[str, Any] = {}
        self.trials: list[FrozenTrial] = []  # index == number
        self.revision = 0  # bumped on every trial mutation (get_trials_revision)
        # numbers of WAITING trials: Study.ask scans for claimable enqueued
        # trials on *every* ask, so the WAITING lookup must not degrade to a
        # full O(n_trials) state scan as the history grows
        self.waiting: set[int] = set()


class InMemoryStorage(BaseStorage):
    def __init__(self):
        self._lock = threading.RLock()
        self._studies: dict[int, _StudyRecord] = {}
        self._study_name_to_id: dict[str, int] = {}
        self._next_study_id = 0
        self._trial_index: dict[int, tuple[int, int]] = {}  # trial_id -> (study_id, number)
        self._next_trial_id = 0
        self._heartbeats: dict[int, float] = {}

    # -- study -----------------------------------------------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        with self._lock:
            if study_name in self._study_name_to_id:
                raise DuplicatedStudyError(study_name)
            sid = self._next_study_id
            self._next_study_id += 1
            self._studies[sid] = _StudyRecord(sid, study_name, list(directions))
            self._study_name_to_id[study_name] = sid
            return sid

    def delete_study(self, study_id: int) -> None:
        with self._lock:
            rec = self._get_study(study_id)
            del self._study_name_to_id[rec.name]
            del self._studies[study_id]
        self._drop_intermediate_store(study_id)
        self._drop_event_log(study_id)

    def get_study_id_from_name(self, study_name: str) -> int:
        with self._lock:
            if study_name not in self._study_name_to_id:
                raise StudyNotFoundError(study_name)
            return self._study_name_to_id[study_name]

    def get_study_name_from_id(self, study_id: int) -> str:
        with self._lock:
            return self._get_study(study_id).name

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        with self._lock:
            return list(self._get_study(study_id).directions)

    def get_all_studies(self) -> list[StudySummary]:
        with self._lock:
            return [
                StudySummary(
                    s.study_id, s.name, list(s.directions), len(s.trials),
                    dict(s.user_attrs), dict(s.system_attrs),
                )
                for s in self._studies.values()
            ]

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        with self._lock:
            self._get_study(study_id).user_attrs[key] = value

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        with self._lock:
            self._get_study(study_id).system_attrs[key] = value

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        with self._lock:
            return dict(self._get_study(study_id).user_attrs)

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        with self._lock:
            return dict(self._get_study(study_id).system_attrs)

    # -- trial -------------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        with self._lock:
            rec = self._get_study(study_id)
            tid = self._next_trial_id
            self._next_trial_id += 1
            number = len(rec.trials)
            if template_trial is None:
                t = FrozenTrial(
                    number=number,
                    state=TrialState.RUNNING,
                    trial_id=tid,
                    datetime_start=self._now(),
                )
            else:
                t = template_trial.copy()
                t.number = number
                t._trial_id = tid
                if t.datetime_start is None:
                    t.datetime_start = self._now()
            rec.trials.append(t)
            if t.state == TrialState.WAITING:
                rec.waiting.add(number)
            self._trial_index[tid] = (study_id, number)
            rec.revision += 1
        # outside the backend lock: the event log takes its own leaf lock
        self._record_event(study_id, _telemetry.EV_CREATED, number)
        return tid

    def _get_study(self, study_id: int) -> _StudyRecord:
        if study_id not in self._studies:
            raise StudyNotFoundError(study_id)
        return self._studies[study_id]

    def _get_trial_ref(self, trial_id: int) -> FrozenTrial:
        if trial_id not in self._trial_index:
            raise TrialNotFoundError(trial_id)
        sid, number = self._trial_index[trial_id]
        return self._studies[sid].trials[number]

    def _bump_revision(self, trial_id: int) -> None:
        sid, _ = self._trial_index[trial_id]
        rec = self._studies.get(sid)
        if rec is not None:
            rec.revision += 1

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution: BaseDistribution,
    ) -> None:
        with self._lock:
            t = self._get_trial_ref(trial_id)
            self._check_not_finished(t)
            if param_name in t.distributions:
                check_distribution_compatibility(t.distributions[param_name], distribution)
            t.params[param_name] = distribution.to_external_repr(param_value_internal)
            t.distributions[param_name] = distribution
            self._bump_revision(trial_id)

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        with self._lock:
            t = self._get_trial_ref(trial_id)
            if state == TrialState.RUNNING and t.state != TrialState.WAITING:
                return False
            t.state = state
            if values is not None:
                t.values = [float(v) for v in values]
            if state == TrialState.RUNNING:
                t.datetime_start = self._now()
            if state.is_finished():
                t.datetime_complete = self._now()
                self._heartbeats.pop(trial_id, None)
            self._bump_revision(trial_id)
            sid, number = self._trial_index[trial_id]
            rec = self._studies.get(sid)
            if rec is not None:
                if state == TrialState.WAITING:
                    rec.waiting.add(number)
                else:
                    rec.waiting.discard(number)
        self._record_state_event(sid, state, number)
        return True

    def set_trial_intermediate_value(self, trial_id: int, step: int, intermediate_value: float) -> None:
        with self._lock:
            t = self._get_trial_ref(trial_id)
            self._check_not_finished(t)
            t.intermediate_values[int(step)] = float(intermediate_value)
            self._bump_revision(trial_id)
            sid, number = self._trial_index[trial_id]
        # outside the backend lock: hosted IV stores lock store-first
        self._note_iv_dirty(trial_id, sid)
        self._record_event(sid, _telemetry.EV_REPORTED, number, step=int(step))

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        with self._lock:
            t = self._get_trial_ref(trial_id)
            self._check_not_finished(t)
            t.user_attrs[key] = value
            self._bump_revision(trial_id)

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        with self._lock:
            t = self._get_trial_ref(trial_id)
            t.system_attrs[key] = value
            self._bump_revision(trial_id)

    def get_trial(self, trial_id: int) -> FrozenTrial:
        with self._lock:
            return self._get_trial_ref(trial_id).copy()

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        with self._lock:
            rec = self._get_study(study_id)
            trials = rec.trials
            if (
                since is None
                and states
                and all(s == TrialState.WAITING for s in states)
            ):
                # WAITING index: Study.ask issues this exact query per ask, so
                # it must stay O(n_waiting), not O(n_trials)
                trials = [trials[i] for i in sorted(rec.waiting)]
            else:
                if since is not None:
                    trials = trials[since:]  # numbers are dense list indices
                if states is not None:
                    trials = [t for t in trials if t.state in states]
            return [copy.deepcopy(t) for t in trials] if deepcopy else list(trials)

    def get_trials_revision(self, study_id: int) -> int:
        with self._lock:
            return self._get_study(study_id).revision

    @staticmethod
    def _check_not_finished(t: FrozenTrial) -> None:
        if t.state.is_finished():
            raise RuntimeError(f"trial {t.trial_id} is already finished ({t.state.name})")

    # -- heartbeat -----------------------------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        with self._lock:
            self._heartbeats[trial_id] = time.time()

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        now = time.time()
        with self._lock:
            out = []
            for t in self._get_study(study_id).trials:
                if t.state != TrialState.RUNNING:
                    continue
                hb = self._heartbeats.get(t.trial_id)
                if hb is not None and now - hb > grace_seconds:
                    out.append(t.trial_id)
            return out
