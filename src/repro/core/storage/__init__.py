"""Storage backends (paper §4): in-memory (lightweight), SQLite (RDB),
append-only journal file (NFS-scale fleets)."""

from __future__ import annotations

from .base import BaseStorage, StudySummary
from .inmemory import InMemoryStorage
from .journal import JournalStorage
from .sqlite import SQLiteStorage

__all__ = [
    "BaseStorage",
    "StudySummary",
    "InMemoryStorage",
    "SQLiteStorage",
    "JournalStorage",
    "get_storage",
]


def get_storage(storage: "str | BaseStorage | None") -> BaseStorage:
    """Resolve a storage URL / object, mirroring the paper's Fig. 7 usage:

    * ``None``             -> fresh :class:`InMemoryStorage`
    * ``sqlite:///path``   -> :class:`SQLiteStorage`
    * ``journal://path``   -> :class:`JournalStorage`
    * ``*.db`` / ``*.sqlite`` path -> :class:`SQLiteStorage`
    * ``*.journal`` / ``*.log`` path -> :class:`JournalStorage`
    """
    if storage is None:
        return InMemoryStorage()
    if isinstance(storage, BaseStorage):
        return storage
    if storage.startswith("sqlite:///"):
        return SQLiteStorage(storage)
    if storage.startswith("journal://"):
        return JournalStorage(storage)
    if storage.endswith((".db", ".sqlite", ".sqlite3")):
        return SQLiteStorage(storage)
    if storage.endswith((".journal", ".log", ".jsonl")):
        return JournalStorage(storage)
    raise ValueError(
        f"cannot infer storage backend from {storage!r}; use sqlite:/// or journal:// URLs"
    )
