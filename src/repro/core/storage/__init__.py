"""Storage backends (paper §4): in-memory (lightweight), SQLite (RDB),
append-only journal file (NFS-scale fleets), and a networked client/server
pair (``remote://``) for fleets without any shared filesystem.  See DESIGN.md
for the backend matrix and the remote protocol."""

from __future__ import annotations

from .base import BaseStorage, StudySummary, get_trials_since
from .cached import CachedStorage
from .client import RemoteStorage
from .cluster import ShardedStorage
from .inmemory import InMemoryStorage
from .journal import JournalStorage
from .server import StorageServer
from .sqlite import SQLiteStorage

__all__ = [
    "BaseStorage",
    "StudySummary",
    "InMemoryStorage",
    "SQLiteStorage",
    "JournalStorage",
    "RemoteStorage",
    "CachedStorage",
    "ShardedStorage",
    "StorageServer",
    "get_storage",
    "get_trials_since",
]


def get_storage(storage: "str | BaseStorage | None", cache: bool = False) -> BaseStorage:
    """Resolve a storage URL / object, mirroring the paper's Fig. 7 usage:

    * ``None``             -> fresh :class:`InMemoryStorage`
    * ``sqlite:///path``   -> :class:`SQLiteStorage`
    * ``journal://path``   -> :class:`JournalStorage`
    * ``remote://host:port`` -> :class:`RemoteStorage` speaking to a
      :class:`StorageServer` (no shared filesystem needed; see DESIGN.md)
    * ``remote://a:p1,b:p2`` (comma-sharded host list) ->
      :class:`ShardedStorage` consistent-hashing studies across a server
      pool; ``+`` within a shard lists failover candidates
    * ``*.db`` / ``*.sqlite`` path -> :class:`SQLiteStorage`
    * ``*.journal`` / ``*.log`` path -> :class:`JournalStorage`

    ``cache=True`` wraps the resolved backend in :class:`CachedStorage`, the
    client-side proxy that makes ``get_all_trials`` incremental (recommended
    for workers talking to a ``remote://`` server).
    """
    backend = _resolve(storage)
    if cache and not isinstance(backend, CachedStorage):
        backend = CachedStorage(backend)
    return backend


def _resolve(storage: "str | BaseStorage | None") -> BaseStorage:
    if storage is None:
        return InMemoryStorage()
    if isinstance(storage, BaseStorage):
        return storage
    if storage.startswith("sqlite:///"):
        return SQLiteStorage(storage)
    if storage.startswith("journal://"):
        return JournalStorage(storage)
    if storage.startswith(("remote://", "remote+tls://")):
        if "," in storage:
            return ShardedStorage(storage)
        return RemoteStorage(storage)
    if storage.endswith((".db", ".sqlite", ".sqlite3")):
        return SQLiteStorage(storage)
    if storage.endswith((".journal", ".log", ".jsonl")):
        return JournalStorage(storage)
    raise ValueError(
        f"cannot infer storage backend from {storage!r}; "
        "use sqlite:///, journal://, or remote:// URLs"
    )
