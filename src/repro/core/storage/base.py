"""Abstract storage API.

Every worker in a distributed study shares progress exclusively through an
implementation of :class:`BaseStorage` (paper §4, Fig. 6).  The API is
deliberately small and transactional at the single-call level so backends can
be implemented over an RDB, a journal file, or an in-process dict.

Concurrency contract (what samplers/pruners may assume):

* ``create_new_trial`` atomically assigns a unique, dense trial ``number``.
* ``set_trial_state_values`` is atomic; transitioning RUNNING->finished is
  last-writer-wins, WAITING->RUNNING returns False if another worker already
  claimed the trial.
* reads (``get_all_trials``) may lag writes from other workers — samplers are
  designed for asynchrony (the paper's ASHA never blocks on peers).
"""

from __future__ import annotations

import datetime
import threading
from typing import Any, Iterable

from .. import telemetry
from ..distributions import BaseDistribution
from ..frozen import FrozenTrial, StudyDirection, TrialState

__all__ = ["BaseStorage", "StudySummary", "get_trials_since"]

# TrialState -> lifecycle event kind for successful set_trial_state_values
# transitions (WAITING releases are bookkeeping, not lifecycle — no event)
_STATE_EVENTS = {
    int(TrialState.RUNNING): telemetry.EV_CLAIMED,
    int(TrialState.COMPLETE): telemetry.EV_COMPLETED,
    int(TrialState.PRUNED): telemetry.EV_PRUNED,
    int(TrialState.FAIL): telemetry.EV_FAILED,
}


class StudySummary:
    def __init__(
        self,
        study_id: int,
        study_name: str,
        directions: list[StudyDirection],
        n_trials: int,
        user_attrs: dict[str, Any] | None = None,
        system_attrs: dict[str, Any] | None = None,
    ):
        self.study_id = study_id
        self.study_name = study_name
        self.directions = directions
        self.n_trials = n_trials
        self.user_attrs = user_attrs or {}
        self.system_attrs = system_attrs or {}

    def __repr__(self) -> str:
        return f"StudySummary(name={self.study_name!r}, n_trials={self.n_trials})"


class BaseStorage:
    # -- study ---------------------------------------------------------------

    def create_new_study(
        self, directions: list[StudyDirection], study_name: str
    ) -> int:
        raise NotImplementedError

    def delete_study(self, study_id: int) -> None:
        raise NotImplementedError

    def get_study_id_from_name(self, study_name: str) -> int:
        raise NotImplementedError

    def get_study_name_from_id(self, study_id: int) -> str:
        raise NotImplementedError

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        raise NotImplementedError

    def get_all_studies(self) -> list[StudySummary]:
        raise NotImplementedError

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        raise NotImplementedError

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        raise NotImplementedError

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        raise NotImplementedError

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        raise NotImplementedError

    # -- trial ---------------------------------------------------------------

    def create_new_trial(
        self, study_id: int, template_trial: FrozenTrial | None = None
    ) -> int:
        raise NotImplementedError

    def create_new_trials(
        self, study_id: int, n: int, template_trial: FrozenTrial | None = None
    ) -> list[int]:
        """Create ``n`` trials; the batched form ``Study.ask(n)`` uses.
        Backends with request batching (``remote://``) override this to claim
        all ids in one round trip."""
        return [self.create_new_trial(study_id, template_trial) for _ in range(n)]

    def set_trial_param(
        self,
        trial_id: int,
        param_name: str,
        param_value_internal: float,
        distribution: BaseDistribution,
    ) -> None:
        raise NotImplementedError

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        """Atomically set state (and final values).  Returns False iff the
        transition was a WAITING->RUNNING claim lost to another worker."""
        raise NotImplementedError

    def set_trial_intermediate_value(
        self, trial_id: int, step: int, intermediate_value: float
    ) -> None:
        raise NotImplementedError

    def set_trial_intermediate_vector(
        self, trial_id: int, step: int, values: "Iterable[float]"
    ) -> None:
        """Persist a per-objective intermediate vector at ``step`` (multi-
        objective learning curves).  Composed from existing primitives — the
        vector rides an ``iv_vec:<step>`` system attr and objective 0 lands
        in the scalar stream — so every backend, both wire protocols, the op
        journal and replication support it with no schema change.  Callers
        that scalarize for pruning (``Trial.report`` with a Pareto-aware
        pruner) write the attr themselves and keep the fused op's scalar."""
        from ..frozen import iv_vec_key

        values = [float(v) for v in values]
        if not values:
            raise ValueError("intermediate vector must be non-empty")
        self.set_trial_system_attr(trial_id, iv_vec_key(step), values)
        self.set_trial_intermediate_value(trial_id, int(step), values[0])

    # class-level: guards lazy creation of per-instance store dicts
    _iv_stores_lock = threading.Lock()

    def report_and_prune(
        self,
        study_id: int,
        trial_id: int,
        step: int,
        value: float,
        pruner_spec: dict,
        direction: "StudyDirection | int",
    ) -> bool:
        """Fused report→prune: persist one intermediate value and return the
        prune decision against this backend's peer data, in a single storage
        operation.

        ``pruner_spec`` is the wire form from ``BasePruner.spec()``;
        ``direction`` the study's optimization direction.  The decision runs
        the pruner's vectorized ``decide`` against a per-study
        :class:`~repro.core.records.IntermediateValueStore` hosted *on this
        backend* — for ``remote://`` that means the server evaluates with its
        own (always-warm) peer data and a worker's ``trial.report()`` +
        ``should_prune()`` costs exactly one round trip, instead of
        set-value + trial refetch + a full peer re-read.

        This default implementation serves every in-process backend
        (in-memory / sqlite / journal); :class:`RemoteStorage` forwards it as
        one RPC and :class:`CachedStorage` batches it with any buffered
        write-behind ops.
        """
        with telemetry.span("storage.report_and_prune"):
            self.set_trial_intermediate_value(trial_id, int(step), float(value))
            if pruner_spec.get("name") in ("nop", "none"):
                return False  # nothing to rank: skip the store refresh entirely
            from ..pruners import pruner_from_spec

            pruner = pruner_from_spec(pruner_spec)
            store = self._intermediate_store(study_id)
            store.refresh()
            trial = self.get_trial(trial_id)
            return bool(pruner.decide(StudyDirection(direction), store, trial))

    def _intermediate_store(self, study_id: int):
        """The per-study intermediate-value store hosted on this backend,
        created lazily (kept warm across fused calls).  Hosted stores track a
        per-trial dirty set — every ``set_trial_intermediate_value`` on this
        backend notes the written trial via :meth:`_note_iv_dirty`, so a
        refresh re-encodes only the changed RUNNING rows, O(changed trials)
        instead of O(rows past the watermark)."""
        from ..records import IntermediateValueStore

        with BaseStorage._iv_stores_lock:
            stores = self.__dict__.setdefault("_iv_stores", {})
            store = stores.get(study_id)
            if store is None:
                stores[study_id] = store = IntermediateValueStore(
                    self, study_id, track_dirty=True
                )
            return store

    def _note_iv_dirty(self, trial_id: int, study_id: "int | None" = None) -> None:
        """Tell the hosted intermediate-value store one trial's reports
        changed.  ``study_id`` scopes the note to the owning study's store
        (every backend can resolve it cheaply); a foreign-study note would
        otherwise poison that store's dirty tracking with an unknown id and
        degrade its refresh back to full re-encodes.  Backends call this from
        ``set_trial_intermediate_value`` **after releasing their own lock**
        (a hosted store's refresh takes the store lock first, then reads
        through the backend — noting under the backend lock would invert
        that order and deadlock)."""
        with BaseStorage._iv_stores_lock:
            stores = self.__dict__.get("_iv_stores")
            if not stores:
                return
            if study_id is not None:
                store = stores.get(study_id)
                targets = [store] if store is not None else []
            else:
                targets = list(stores.values())
        for store in targets:
            store.note_dirty(trial_id)

    def _drop_intermediate_store(self, study_id: int) -> None:
        """Evict a deleted study's store — backends call this from
        ``delete_study`` so a long-lived server does not pin one warm matrix
        per study it ever pruned for."""
        with BaseStorage._iv_stores_lock:
            stores = self.__dict__.get("_iv_stores")
            if stores is not None:
                stores.pop(study_id, None)

    # -- trial lifecycle event trace -------------------------------------------

    # class-level: guards lazy creation of per-instance event-log dicts
    # (same hosting pattern as the intermediate-value stores above)
    _event_logs_lock = threading.Lock()

    def _event_log(self, study_id: int) -> "telemetry.TrialEventLog":
        with BaseStorage._event_logs_lock:
            logs = self.__dict__.setdefault("_event_logs", {})
            log = logs.get(study_id)
            if log is None:
                logs[study_id] = log = telemetry.TrialEventLog()
            return log

    def _record_event(
        self, study_id: int, kind: int, number: int, step: int = -1
    ) -> None:
        """Append one lifecycle event to the study's hosted trace.  Backends
        call this from their mutation methods **after releasing their own
        lock** (the log takes its own leaf lock; keeping the orders disjoint
        mirrors the ``_note_iv_dirty`` rule)."""
        self._event_log(study_id).append(kind, number, step=step)

    def _record_state_event(
        self, study_id: int, state: TrialState, number: int
    ) -> None:
        """Event for a *successful* ``set_trial_state_values`` transition:
        RUNNING means the trial was claimed, finished states map directly;
        a WAITING (re-)release is queue bookkeeping and records nothing."""
        kind = _STATE_EVENTS.get(int(state))
        if kind is not None:
            self._record_event(study_id, kind, number)

    def get_trial_events(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Columnar trial-lifecycle trace of a study, from event ``since`` on
        (:meth:`telemetry.TrialEventLog.snapshot` wire format: parallel JSON
        lists + interned worker table).  The trace lives on the backend that
        executed the mutations, so over ``remote://`` one RPC returns the
        server-side fleet-wide sequence."""
        return self._event_log(study_id).snapshot(since)

    def _drop_event_log(self, study_id: int) -> None:
        with BaseStorage._event_logs_lock:
            logs = self.__dict__.get("_event_logs")
            if logs is not None:
                logs.pop(study_id, None)

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        raise NotImplementedError

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        raise NotImplementedError

    def get_trial(self, trial_id: int) -> FrozenTrial:
        raise NotImplementedError

    def get_all_trials(
        self,
        study_id: int,
        deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        """All trials of a study, ordered by ``number``.

        ``since`` restricts the result to trials with ``number >= since`` —
        the incremental-fetch hook :class:`CachedStorage` uses to avoid
        re-reading finished trials on every ``ask``.  Backends that predate
        the parameter still work through :func:`get_trials_since`.
        """
        raise NotImplementedError

    def get_n_trials(
        self, study_id: int, states: tuple[TrialState, ...] | None = None
    ) -> int:
        return len(self.get_all_trials(study_id, deepcopy=False, states=states))

    def get_trial_id_from_study_and_number(self, study_id: int, number: int) -> int:
        for t in self.get_all_trials(study_id, deepcopy=False):
            if t.number == number:
                return t.trial_id
        from ..exceptions import TrialNotFoundError

        raise TrialNotFoundError(f"no trial number {number} in study {study_id}")

    def get_trials_revision(self, study_id: int) -> int:
        """Monotonic per-study counter, bumped by **every** trial mutation —
        including in-place updates to RUNNING trials that a number-based
        ``get_all_trials(since=...)`` poll alone cannot distinguish from "no
        change".  Readers (``CachedStorage``, ``ObservationStore``) poll it to
        skip suffix fetches entirely when nothing moved.  Backends that cannot
        provide one raise ``NotImplementedError``; callers must then fall back
        to always refetching."""
        raise NotImplementedError

    # -- columnar block fetch ---------------------------------------------------

    supports_block_fetch = False
    """Whether the block RPCs below are worth attempting over this backend.
    In-process backends keep it False (``ObservationStore`` ingests their
    trial objects directly, there is nothing to save); ``RemoteStorage``
    flips it on when wire protocol v2 is negotiated."""

    def get_observation_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Observations of *finished* trials with ``number >= since``, as a
        dict of contiguous numpy columns (see
        :func:`.serde.build_observation_block` for the exact layout).  Over
        wire protocol v2 this is the near-memcpy refresh path of
        :class:`~repro.core.records.ObservationStore`."""
        from .serde import build_observation_block

        trials = get_trials_since(self, study_id, since, deepcopy=False)
        return build_observation_block(trials, len(self.get_study_directions(study_id)))

    def get_iv_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Intermediate-value curves of trials with ``number >= since`` in
        CSR layout (see :func:`.serde.build_iv_block`)."""
        from .serde import build_iv_block

        return build_iv_block(get_trials_since(self, study_id, since, deepcopy=False))

    # -- heartbeat / fault tolerance ------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        """Default: no-op.  Backends that support failover override this."""

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        """Trial ids in RUNNING state whose last heartbeat is older than
        ``grace_seconds`` (i.e. their worker likely died)."""
        return []

    def fail_stale_trials(self, study_id: int, grace_seconds: float) -> list[int]:
        return self.reclaim_stale_trials(study_id, grace_seconds, requeue=False)

    def reclaim_stale_trials(
        self, study_id: int, grace_seconds: float, requeue: bool = False
    ) -> list[int]:
        """Reclaim RUNNING trials whose worker stopped heartbeating: mark them
        FAILed, or — with ``requeue=True`` — hand them back to the WAITING
        queue so another worker's ``ask()`` can claim and re-run them.
        Returns the reclaimed trial ids."""
        target = TrialState.WAITING if requeue else TrialState.FAIL
        reclaimed = []
        for tid in self.get_stale_trial_ids(study_id, grace_seconds):
            if self.set_trial_state_values(tid, target):
                if requeue:
                    # re-arm the staleness clock: whoever claims the requeued
                    # trial gets a full grace period before the next sweep
                    self.record_heartbeat(tid)
                reclaimed.append(tid)
        return reclaimed

    # -- misc ------------------------------------------------------------------

    def _now(self) -> datetime.datetime:
        return datetime.datetime.now()

    def close(self) -> None:
        pass


def get_trials_since(
    storage: BaseStorage,
    study_id: int,
    since: int,
    deepcopy: bool = True,
    states: tuple[TrialState, ...] | None = None,
) -> list[FrozenTrial]:
    """Fetch trials with ``number >= since``, falling back to a full read +
    filter for backends whose ``get_all_trials`` does not accept ``since``."""
    try:
        return storage.get_all_trials(study_id, deepcopy=deepcopy, states=states, since=since)
    except TypeError:
        trials = storage.get_all_trials(study_id, deepcopy=deepcopy, states=states)
        return [t for t in trials if t.number >= since]
