"""JSON wire codec for the remote storage protocol.

Everything that crosses the ``remote://`` socket is JSON; the handful of rich
types in the storage API (``FrozenTrial``, ``BaseDistribution``,
``StudySummary``, ``TrialState``/``StudyDirection``, ``datetime``) are encoded
as tagged objects so the decoder can reconstruct them without ambiguity.
Parameter *values* need no tagging: the suggest API guarantees external reprs
are JSON-native (see ``CategoricalDistribution``).
"""

from __future__ import annotations

import datetime
from typing import Any

from ..distributions import distribution_to_json, json_to_distribution
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import StudySummary

__all__ = ["pack", "unpack"]

_TRIAL = "__frozen_trial__"
_DIST = "__distribution__"
_SUMMARY = "__study_summary__"
_STATE = "__trial_state__"
_DIRECTION = "__study_direction__"
_DATETIME = "__datetime__"


def pack(obj: Any) -> Any:
    """Recursively convert a storage-API value into pure-JSON structures."""
    # enum checks must precede the primitive check: IntEnum instances are ints
    if isinstance(obj, TrialState):
        return {_STATE: int(obj)}
    if isinstance(obj, StudyDirection):
        return {_DIRECTION: int(obj)}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, datetime.datetime):
        return {_DATETIME: obj.isoformat()}
    if isinstance(obj, FrozenTrial):
        return {
            _TRIAL: {
                "number": obj.number,
                "state": int(obj.state),
                "values": obj.values,
                # attrs/params may legally hold rich values (e.g. datetimes in
                # user_attrs) -> pack recursively, symmetric with unpack below
                "params": pack(obj.params),
                "distributions": {
                    k: distribution_to_json(d) for k, d in obj.distributions.items()
                },
                "intermediate_values": {str(k): v for k, v in obj.intermediate_values.items()},
                "user_attrs": pack(obj.user_attrs),
                "system_attrs": pack(obj.system_attrs),
                "trial_id": obj.trial_id,
                "datetime_start": pack(obj.datetime_start),
                "datetime_complete": pack(obj.datetime_complete),
            }
        }
    if isinstance(obj, StudySummary):
        return {
            _SUMMARY: {
                "study_id": obj.study_id,
                "study_name": obj.study_name,
                "directions": [int(d) for d in obj.directions],
                "n_trials": obj.n_trials,
                "user_attrs": pack(obj.user_attrs),
                "system_attrs": pack(obj.system_attrs),
            }
        }
    # distributions have no common tag field; detect by duck type
    if hasattr(obj, "_asdict") and hasattr(obj, "to_internal_repr"):
        return {_DIST: distribution_to_json(obj)}
    if isinstance(obj, (list, tuple)):
        return [pack(v) for v in obj]
    if isinstance(obj, dict):
        return {str(k): pack(v) for k, v in obj.items()}
    raise TypeError(f"cannot serialize {type(obj).__name__} for the storage protocol")


def unpack(obj: Any) -> Any:
    """Inverse of :func:`pack`."""
    if isinstance(obj, list):
        return [unpack(v) for v in obj]
    if not isinstance(obj, dict):
        return obj
    if _STATE in obj:
        return TrialState(obj[_STATE])
    if _DIRECTION in obj:
        return StudyDirection(obj[_DIRECTION])
    if _DATETIME in obj:
        return datetime.datetime.fromisoformat(obj[_DATETIME])
    if _DIST in obj:
        return json_to_distribution(obj[_DIST])
    if _TRIAL in obj:
        d = obj[_TRIAL]
        return FrozenTrial(
            number=d["number"],
            state=TrialState(d["state"]),
            values=d["values"],
            params=unpack(d["params"]),
            distributions={k: json_to_distribution(s) for k, s in d["distributions"].items()},
            intermediate_values={int(k): v for k, v in d["intermediate_values"].items()},
            user_attrs=unpack(d["user_attrs"]),
            system_attrs=unpack(d["system_attrs"]),
            trial_id=d["trial_id"],
            datetime_start=unpack(d["datetime_start"]),
            datetime_complete=unpack(d["datetime_complete"]),
        )
    if _SUMMARY in obj:
        d = obj[_SUMMARY]
        return StudySummary(
            d["study_id"],
            d["study_name"],
            [StudyDirection(x) for x in d["directions"]],
            d["n_trials"],
            unpack(d["user_attrs"]),
            unpack(d["system_attrs"]),
        )
    return {k: unpack(v) for k, v in obj.items()}
