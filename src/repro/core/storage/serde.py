"""Wire codecs for the remote storage protocol.

Two codecs share this module:

* **v1 (JSON)** — :func:`pack` / :func:`unpack`.  Everything that crosses the
  ``remote://`` socket is JSON; the handful of rich types in the storage API
  (``FrozenTrial``, ``BaseDistribution``, ``StudySummary``,
  ``TrialState``/``StudyDirection``, ``datetime``) are encoded as tagged
  objects so the decoder can reconstruct them without ambiguity.  Parameter
  *values* need no tagging: the suggest API guarantees external reprs are
  JSON-native (see ``CategoricalDistribution``).

* **v2 (binary)** — :func:`bdumps` / :func:`bloads`.  A msgpack-free tagged
  binary format (one tag byte per value, big-endian ``struct`` scalars,
  length-prefixed strings) whose headline feature is a native ``ndarray``
  tag: dtype + shape header followed by the raw C-order buffer, decoded with
  ``np.frombuffer`` over the received frame — zero copy.  Negotiated per
  connection via the ``hello`` RPC (see ``server.py``); both codecs decode
  to *identical* Python values so a study is bit-identical under either.

The columnar **block builders** (:func:`build_observation_block` /
:func:`build_iv_block`) also live here: they flatten a trial delta into the
dict-of-arrays layout that ``ObservationStore`` / ``IntermediateValueStore``
ingest as a near-memcpy on the client.  Internal (model-space) values are
computed with the exact one-element ``to_internal`` call the client-side
per-trial path uses, so the resulting matrices are bit-identical.
"""

from __future__ import annotations

import datetime
import struct
from typing import Any

import numpy as np

from ..distributions import distribution_to_json, json_to_distribution
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import StudySummary

__all__ = [
    "pack",
    "unpack",
    "bdumps",
    "bjoin",
    "bloads",
    "build_observation_block",
    "build_iv_block",
    "BINARY_MAGIC",
]

_TRIAL = "__frozen_trial__"
_DIST = "__distribution__"
_SUMMARY = "__study_summary__"
_STATE = "__trial_state__"
_DIRECTION = "__study_direction__"
_DATETIME = "__datetime__"


def pack(obj: Any) -> Any:
    """Recursively convert a storage-API value into pure-JSON structures."""
    # enum checks must precede the primitive check: IntEnum instances are ints
    if isinstance(obj, TrialState):
        return {_STATE: int(obj)}
    if isinstance(obj, StudyDirection):
        return {_DIRECTION: int(obj)}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, datetime.datetime):
        return {_DATETIME: obj.isoformat()}
    if isinstance(obj, FrozenTrial):
        return {
            _TRIAL: {
                "number": obj.number,
                "state": int(obj.state),
                "values": obj.values,
                # attrs/params may legally hold rich values (e.g. datetimes in
                # user_attrs) -> pack recursively, symmetric with unpack below
                "params": pack(obj.params),
                "distributions": {
                    k: distribution_to_json(d) for k, d in obj.distributions.items()
                },
                "intermediate_values": {str(k): v for k, v in obj.intermediate_values.items()},
                "user_attrs": pack(obj.user_attrs),
                "system_attrs": pack(obj.system_attrs),
                "trial_id": obj.trial_id,
                "datetime_start": pack(obj.datetime_start),
                "datetime_complete": pack(obj.datetime_complete),
            }
        }
    if isinstance(obj, StudySummary):
        return {
            _SUMMARY: {
                "study_id": obj.study_id,
                "study_name": obj.study_name,
                "directions": [int(d) for d in obj.directions],
                "n_trials": obj.n_trials,
                "user_attrs": pack(obj.user_attrs),
                "system_attrs": pack(obj.system_attrs),
            }
        }
    # distributions have no common tag field; detect by duck type
    if hasattr(obj, "_asdict") and hasattr(obj, "to_internal_repr"):
        return {_DIST: distribution_to_json(obj)}
    if isinstance(obj, (list, tuple)):
        return [pack(v) for v in obj]
    if isinstance(obj, dict):
        return {str(k): pack(v) for k, v in obj.items()}
    raise TypeError(f"cannot serialize {type(obj).__name__} for the storage protocol")


def unpack(obj: Any) -> Any:
    """Inverse of :func:`pack`."""
    if isinstance(obj, list):
        return [unpack(v) for v in obj]
    if not isinstance(obj, dict):
        return obj
    if _STATE in obj:
        return TrialState(obj[_STATE])
    if _DIRECTION in obj:
        return StudyDirection(obj[_DIRECTION])
    if _DATETIME in obj:
        return datetime.datetime.fromisoformat(obj[_DATETIME])
    if _DIST in obj:
        return json_to_distribution(obj[_DIST])
    if _TRIAL in obj:
        d = obj[_TRIAL]
        return FrozenTrial(
            number=d["number"],
            state=TrialState(d["state"]),
            values=d["values"],
            params=unpack(d["params"]),
            distributions={k: json_to_distribution(s) for k, s in d["distributions"].items()},
            intermediate_values={int(k): v for k, v in d["intermediate_values"].items()},
            user_attrs=unpack(d["user_attrs"]),
            system_attrs=unpack(d["system_attrs"]),
            trial_id=d["trial_id"],
            datetime_start=unpack(d["datetime_start"]),
            datetime_complete=unpack(d["datetime_complete"]),
        )
    if _SUMMARY in obj:
        d = obj[_SUMMARY]
        return StudySummary(
            d["study_id"],
            d["study_name"],
            [StudyDirection(x) for x in d["directions"]],
            d["n_trials"],
            unpack(d["user_attrs"]),
            unpack(d["system_attrs"]),
        )
    return {k: unpack(v) for k, v in obj.items()}


# ---------------------------------------------------------------------------
# Binary codec (wire protocol v2)
# ---------------------------------------------------------------------------

#: first payload byte of every v2 frame, in both directions.  A JSON payload
#: can never start with this byte (0xB2 is not valid leading UTF-8), so a
#: misrouted frame fails loudly instead of decoding to garbage.
BINARY_MAGIC = 0xB2

_B_NONE = 0x00
_B_FALSE = 0x01
_B_TRUE = 0x02
_B_INT = 0x03       # >q
_B_FLOAT = 0x04     # >d
_B_STR = 0x05       # u32 byte length + utf-8
_B_BYTES = 0x06     # u32 length + raw
_B_LIST = 0x07      # u32 count + items
_B_DICT = 0x08      # u32 count + (u32+utf8 key, value) pairs; keys str()-ed
_B_NDARRAY = 0x09   # u8 dtype-str len + ascii dtype + u8 ndim + u32 dims + raw
_B_STATE = 0x0A     # u8 TrialState
_B_DIRECTION = 0x0B  # u8 StudyDirection
_B_DATETIME = 0x0C  # u32+utf8 isoformat (mirrors the v1 tagged encoding)
_B_DIST = 0x0D      # u32+utf8 distribution_to_json
_B_TRIAL = 0x0E     # FrozenTrial, fixed field order (see _benc)
_B_SUMMARY = 0x0F   # StudySummary, fixed field order
_B_BIGINT = 0x10    # u32+ascii decimal; ints outside the i64 range

_S_I64 = struct.Struct(">q")
_S_F64 = struct.Struct(">d")
_S_U32 = struct.Struct(">I")


def _benc_str(s: str, buf: bytearray) -> None:
    b = s.encode("utf-8")
    buf += _S_U32.pack(len(b))
    buf += b


def _benc(obj: Any, buf: bytearray) -> None:
    # exact-type dispatch first (hot path); enum/numpy/subclass stragglers
    # fall through to the isinstance chain below, where enum checks must
    # precede the int fallback (TrialState is an IntEnum)
    t = type(obj)
    if obj is None:
        buf.append(_B_NONE)
    elif t is bool:
        buf.append(_B_TRUE if obj else _B_FALSE)
    elif t is int:
        if -(2**63) <= obj < 2**63:
            buf.append(_B_INT)
            buf += _S_I64.pack(obj)
        else:
            buf.append(_B_BIGINT)
            _benc_str(str(obj), buf)
    elif t is float:
        buf.append(_B_FLOAT)
        buf += _S_F64.pack(obj)
    elif t is str:
        buf.append(_B_STR)
        _benc_str(obj, buf)
    elif t is list or t is tuple:
        buf.append(_B_LIST)
        buf += _S_U32.pack(len(obj))
        for v in obj:
            _benc(v, buf)
    elif t is dict:
        buf.append(_B_DICT)
        buf += _S_U32.pack(len(obj))
        for k, v in obj.items():
            # str(k) mirrors v1's JSON key stringification so both protocols
            # decode to identical dicts
            _benc_str(k if type(k) is str else str(k), buf)
            _benc(v, buf)
    elif t is bytes:
        buf.append(_B_BYTES)
        buf += _S_U32.pack(len(obj))
        buf += obj
    elif isinstance(obj, TrialState):
        buf.append(_B_STATE)
        buf.append(int(obj))
    elif isinstance(obj, StudyDirection):
        buf.append(_B_DIRECTION)
        buf.append(int(obj))
    elif isinstance(obj, datetime.datetime):
        buf.append(_B_DATETIME)
        _benc_str(obj.isoformat(), buf)
    elif isinstance(obj, FrozenTrial):
        buf.append(_B_TRIAL)
        buf += _S_I64.pack(obj.number)
        buf.append(int(obj.state))
        _benc(obj.values, buf)
        _benc(obj.params, buf)
        buf += _S_U32.pack(len(obj.distributions))
        for k, d in obj.distributions.items():
            _benc_str(k, buf)
            _benc_str(distribution_to_json(d), buf)
        buf += _S_U32.pack(len(obj.intermediate_values))
        for s, v in obj.intermediate_values.items():
            buf += _S_I64.pack(int(s))
            _benc(v, buf)
        _benc(obj.user_attrs, buf)
        _benc(obj.system_attrs, buf)
        buf += _S_I64.pack(obj.trial_id)
        _benc(obj.datetime_start, buf)
        _benc(obj.datetime_complete, buf)
    elif isinstance(obj, StudySummary):
        buf.append(_B_SUMMARY)
        buf += _S_I64.pack(obj.study_id)
        _benc_str(obj.study_name, buf)
        buf += _S_U32.pack(len(obj.directions))
        for d in obj.directions:
            buf.append(int(d))
        buf += _S_I64.pack(obj.n_trials)
        _benc(obj.user_attrs, buf)
        _benc(obj.system_attrs, buf)
    elif isinstance(obj, np.ndarray):
        arr = np.ascontiguousarray(obj)
        dt = arr.dtype.str.encode("ascii")
        if arr.dtype.hasobject:
            raise TypeError("cannot serialize object-dtype arrays")
        buf.append(_B_NDARRAY)
        buf.append(len(dt))
        buf += dt
        buf.append(arr.ndim)
        for dim in arr.shape:
            buf += _S_U32.pack(dim)
        buf += arr.tobytes()
    elif isinstance(obj, (bool, np.bool_)):
        buf.append(_B_TRUE if obj else _B_FALSE)
    elif isinstance(obj, (int, np.integer)):
        _benc(int(obj), buf)
    elif isinstance(obj, (float, np.floating)):
        buf.append(_B_FLOAT)
        buf += _S_F64.pack(float(obj))
    elif isinstance(obj, str):
        buf.append(_B_STR)
        _benc_str(obj, buf)
    elif hasattr(obj, "_asdict") and hasattr(obj, "to_internal_repr"):
        buf.append(_B_DIST)
        _benc_str(distribution_to_json(obj), buf)
    elif isinstance(obj, (list, tuple)):
        buf.append(_B_LIST)
        buf += _S_U32.pack(len(obj))
        for v in obj:
            _benc(v, buf)
    elif isinstance(obj, dict):
        buf.append(_B_DICT)
        buf += _S_U32.pack(len(obj))
        for k, v in obj.items():
            _benc_str(k if type(k) is str else str(k), buf)
            _benc(v, buf)
    else:
        raise TypeError(
            f"cannot serialize {type(obj).__name__} for the binary storage protocol"
        )


def bdumps(obj: Any) -> bytes:
    """Encode a storage-API value into the v2 binary format (sans magic)."""
    buf = bytearray()
    _benc(obj, buf)
    return bytes(buf)


def _bdec_str(mv: memoryview, off: int) -> tuple[str, int]:
    (n,) = _S_U32.unpack_from(mv, off)
    off += 4
    if off + n > len(mv):
        raise ValueError("truncated string in binary payload")
    return str(mv[off : off + n], "utf-8"), off + n


def _bdec(mv: memoryview, off: int) -> tuple[Any, int]:
    tag = mv[off]
    off += 1
    if tag == _B_NONE:
        return None, off
    if tag == _B_FALSE:
        return False, off
    if tag == _B_TRUE:
        return True, off
    if tag == _B_INT:
        (v,) = _S_I64.unpack_from(mv, off)
        return v, off + 8
    if tag == _B_FLOAT:
        (v,) = _S_F64.unpack_from(mv, off)
        return v, off + 8
    if tag == _B_STR:
        return _bdec_str(mv, off)
    if tag == _B_BIGINT:
        s, off = _bdec_str(mv, off)
        return int(s), off
    if tag == _B_BYTES:
        (n,) = _S_U32.unpack_from(mv, off)
        off += 4
        if off + n > len(mv):
            raise ValueError("truncated bytes in binary payload")
        return bytes(mv[off : off + n]), off + n
    if tag == _B_LIST:
        (n,) = _S_U32.unpack_from(mv, off)
        off += 4
        out = []
        for _ in range(n):
            v, off = _bdec(mv, off)
            out.append(v)
        return out, off
    if tag == _B_DICT:
        (n,) = _S_U32.unpack_from(mv, off)
        off += 4
        d = {}
        for _ in range(n):
            k, off = _bdec_str(mv, off)
            d[k], off = _bdec(mv, off)
        return d, off
    if tag == _B_NDARRAY:
        dtn = mv[off]
        off += 1
        dt = np.dtype(str(mv[off : off + dtn], "ascii"))
        off += dtn
        ndim = mv[off]
        off += 1
        shape = []
        count = 1
        for _ in range(ndim):
            (dim,) = _S_U32.unpack_from(mv, off)
            off += 4
            shape.append(dim)
            count *= dim
        nbytes = dt.itemsize * count
        if off + nbytes > len(mv):
            raise ValueError("truncated array in binary payload")
        # zero copy: the array is a read-only view over the received frame
        arr = np.frombuffer(mv[off : off + nbytes], dtype=dt).reshape(shape)
        return arr, off + nbytes
    if tag == _B_STATE:
        return TrialState(mv[off]), off + 1
    if tag == _B_DIRECTION:
        return StudyDirection(mv[off]), off + 1
    if tag == _B_DATETIME:
        s, off = _bdec_str(mv, off)
        return datetime.datetime.fromisoformat(s), off
    if tag == _B_DIST:
        s, off = _bdec_str(mv, off)
        return json_to_distribution(s), off
    if tag == _B_TRIAL:
        (number,) = _S_I64.unpack_from(mv, off)
        off += 8
        state = TrialState(mv[off])
        off += 1
        values, off = _bdec(mv, off)
        params, off = _bdec(mv, off)
        (nd,) = _S_U32.unpack_from(mv, off)
        off += 4
        dists = {}
        for _ in range(nd):
            k, off = _bdec_str(mv, off)
            s, off = _bdec_str(mv, off)
            dists[k] = json_to_distribution(s)
        (ni,) = _S_U32.unpack_from(mv, off)
        off += 4
        ivs = {}
        for _ in range(ni):
            (step,) = _S_I64.unpack_from(mv, off)
            off += 8
            ivs[step], off = _bdec(mv, off)
        user_attrs, off = _bdec(mv, off)
        system_attrs, off = _bdec(mv, off)
        (trial_id,) = _S_I64.unpack_from(mv, off)
        off += 8
        dt_start, off = _bdec(mv, off)
        dt_complete, off = _bdec(mv, off)
        return (
            FrozenTrial(
                number=number,
                state=state,
                values=values,
                params=params,
                distributions=dists,
                intermediate_values=ivs,
                user_attrs=user_attrs,
                system_attrs=system_attrs,
                trial_id=trial_id,
                datetime_start=dt_start,
                datetime_complete=dt_complete,
            ),
            off,
        )
    if tag == _B_SUMMARY:
        (study_id,) = _S_I64.unpack_from(mv, off)
        off += 8
        name, off = _bdec_str(mv, off)
        (nd,) = _S_U32.unpack_from(mv, off)
        off += 4
        directions = [StudyDirection(mv[off + i]) for i in range(nd)]
        off += nd
        (n_trials,) = _S_I64.unpack_from(mv, off)
        off += 8
        user_attrs, off = _bdec(mv, off)
        system_attrs, off = _bdec(mv, off)
        return StudySummary(study_id, name, directions, n_trials, user_attrs, system_attrs), off
    raise ValueError(f"bad binary tag 0x{tag:02x}")


def bjoin(blobs: "list[bytes]") -> bytes:
    """Assemble pre-encoded items (each a :func:`bdumps` payload) into one
    encoded list, without re-encoding — the server's batched-response path."""
    return bytes([_B_LIST]) + _S_U32.pack(len(blobs)) + b"".join(blobs)


def bloads(data: "bytes | bytearray | memoryview") -> Any:
    """Inverse of :func:`bdumps`.  Raises ``ValueError``/``struct.error`` on
    malformed input — never crashes past the payload bounds."""
    mv = memoryview(data)
    try:
        obj, off = _bdec(mv, 0)
    except IndexError:
        raise ValueError("truncated binary payload") from None
    if off != len(mv):
        raise ValueError("trailing bytes in binary payload")
    return obj


# ---------------------------------------------------------------------------
# Columnar block builders (shared by server dispatch and tests)
# ---------------------------------------------------------------------------

_GRID_ATTR = "grid_sampler:grid_id"  # mirrors records._GRID_ATTR (wire constant)


def _iv_items(trial) -> list:
    # deepcopy=False on in-process backends hands out live dict refs: a
    # concurrent report can mutate mid-iteration, so snapshot with retry
    # (same policy as IntermediateValueStore._ingest)
    for _ in range(3):
        try:
            return list(trial.intermediate_values.items())
        except RuntimeError:  # pragma: no cover - dict-resize race
            continue
    return list(trial.intermediate_values.items())


def build_observation_block(trials, n_objectives: int) -> dict:
    """Flatten finished trials into the ``ObservationStore`` ingest layout.

    One row per *finished* trial, in input order (the order the client-side
    per-trial path would have appended them).  ``internal`` columns are
    computed with one-element ``to_internal`` calls — the exact arithmetic
    ``ObservationStore._append`` runs — so ingest is bit-identical to the
    per-trial path.  Distributions are interned per parameter by identical
    JSON (``dist_idx`` indexes the ``dists`` side table), which preserves
    bounds drift across trials.
    """
    rows = [t for t in trials if t.state.is_finished()]
    k = len(rows)
    m = int(n_objectives)
    numbers = np.empty(k, dtype=np.int64)
    states = np.empty(k, dtype=np.int8)
    values = np.full(k, np.nan)
    values_len = np.zeros(k, dtype=np.int64)
    values_mat = np.full((k, m), np.nan)
    last_iv = np.full(k, np.nan)
    grid_ids = np.full(k, -1, dtype=np.int64)
    params: dict[str, dict] = {}
    interned: dict[str, dict] = {}
    for i, t in enumerate(rows):
        numbers[i] = t.number
        states[i] = int(t.state)
        vals = t.values or []
        if vals:
            values[i] = vals[0]
        values_len[i] = len(vals)
        if len(vals) == m:
            values_mat[i, :] = vals
        last = t.last_step
        if last is not None:
            last_iv[i] = t.intermediate_values[last]
        gid = t.system_attrs.get(_GRID_ATTR)
        if gid is not None:
            grid_ids[i] = int(gid)
        for name, dist in t.distributions.items():
            ent = params.get(name)
            if ent is None:
                ent = params[name] = {
                    "internal": np.full(k, np.nan),
                    "dist_idx": np.full(k, -1, dtype=np.int64),
                    "dists": [],
                }
                interned[name] = {}
            dj = distribution_to_json(dist)
            idx = interned[name].get(dj)
            if idx is None:
                idx = len(ent["dists"])
                ent["dists"].append(dj)
                interned[name][dj] = idx
            ent["dist_idx"][i] = idx
            # one-element to_internal: bit-identical to the client-side path
            ent["internal"][i] = float(dist.to_internal([t.params[name]])[0])
    return {
        "n": k,
        "n_objectives": m,
        "numbers": numbers,
        "states": states,
        "values": values,
        "values_len": values_len,
        "values_mat": values_mat,
        "last_iv": last_iv,
        "grid_ids": grid_ids,
        "params": params,
    }


_IV_VEC_PREFIX = "iv_vec:"  # mirrors frozen.IV_VEC_PREFIX (wire constant)


def _iv_vec_items(trial) -> list:
    # same live-dict snapshot policy as _iv_items, over system attrs
    for _ in range(3):
        try:
            return [
                (int(k[len(_IV_VEC_PREFIX):]), [float(x) for x in v])
                for k, v in trial.system_attrs.items()
                if isinstance(k, str) and k.startswith(_IV_VEC_PREFIX)
            ]
        except (RuntimeError, TypeError, ValueError):  # pragma: no cover
            continue
    return []


def build_iv_block(trials) -> dict:
    """Flatten a trial delta into the ``IntermediateValueStore`` ingest
    layout: CSR (``rowptr``/``steps``/``vals``) over *all* trials in input
    order — RUNNING rows included, since the IV store tracks live trials.

    Per-objective vector reports (``iv_vec:<step>`` system attrs) travel as
    a second flat CSR (``vec_numbers``/``vec_steps``/``vec_ptr``/``vec_vals``)
    appended **only when at least one trial carries vectors** — scalar
    studies stay byte-identical on the wire."""
    k = len(trials)
    numbers = np.empty(k, dtype=np.int64)
    states = np.empty(k, dtype=np.int8)
    trial_ids = np.empty(k, dtype=np.int64)
    rowptr = np.zeros(k + 1, dtype=np.int64)
    steps: list[int] = []
    vals: list[float] = []
    vec_numbers: list[int] = []
    vec_steps: list[int] = []
    vec_ptr: list[int] = [0]
    vec_vals: list[float] = []
    for i, t in enumerate(trials):
        numbers[i] = t.number
        states[i] = int(t.state)
        trial_ids[i] = t.trial_id
        items = _iv_items(t)
        rowptr[i + 1] = rowptr[i] + len(items)
        for s, v in items:
            steps.append(int(s))
            vals.append(v)
        for s, vec in _iv_vec_items(t):
            vec_numbers.append(t.number)
            vec_steps.append(s)
            vec_vals.extend(vec)
            vec_ptr.append(len(vec_vals))
    block = {
        "n": k,
        "numbers": numbers,
        "states": states,
        "trial_ids": trial_ids,
        "rowptr": rowptr,
        "steps": np.asarray(steps, dtype=np.int64),
        "vals": np.asarray(vals, dtype=np.float64),
    }
    if vec_numbers:
        block["vec_numbers"] = np.asarray(vec_numbers, dtype=np.int64)
        block["vec_steps"] = np.asarray(vec_steps, dtype=np.int64)
        block["vec_ptr"] = np.asarray(vec_ptr, dtype=np.int64)
        block["vec_vals"] = np.asarray(vec_vals, dtype=np.float64)
    return block
