"""``RemoteStorage`` — the client half of the networked storage service.

Connects to a :class:`~repro.core.storage.server.StorageServer` via a
``remote://host:port`` URL and implements the full :class:`BaseStorage`
contract by forwarding each call as one JSON-RPC frame (see server.py for the
wire format).

Design points:

* **Per-thread connections** — ``study.optimize(n_jobs=k)`` threads each get
  their own socket, so responses can never interleave.
* **Retry-on-reconnect** — a dropped connection is re-dialed transparently.
  Idempotent calls (all reads, value-overwriting writes) are retried; calls
  whose *effect* is not idempotent (``create_new_trial``,
  ``create_new_study``, the WAITING->RUNNING claim) are only retried when the
  request provably never reached the wire, otherwise
  :class:`RetryableStorageError` is raised for the caller to decide.
* **Atomic compare-and-set** — ``set_trial_state_values`` executes inside the
  single server process against the wrapped backend, so ``ask()``'s
  WAITING-claim race stays exactly-once across machines.
"""

from __future__ import annotations

import json
import os
import socket
import ssl
import threading
import time
from typing import Any, Iterable

from .. import telemetry
from ..exceptions import (
    DuplicatedStudyError,
    RetryableStorageError,
    StorageInternalError,
    StudyNotFoundError,
    TrialNotFoundError,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary
from .serde import BINARY_MAGIC, bdumps, bloads, pack, unpack
from .server import recv_frame, send_frame

_MAGIC = bytes([BINARY_MAGIC])

__all__ = ["RemoteStorage", "parse_remote_url"]

# server-side exception type name -> client-side class to re-raise
_ERROR_TYPES: dict[str, type[Exception]] = {
    "StudyNotFoundError": StudyNotFoundError,
    "TrialNotFoundError": TrialNotFoundError,
    "DuplicatedStudyError": DuplicatedStudyError,
    "StorageInternalError": StorageInternalError,
    "RetryableStorageError": RetryableStorageError,
    "RuntimeError": RuntimeError,
    "ValueError": ValueError,
    "TypeError": TypeError,
    "KeyError": KeyError,
    "PermissionError": PermissionError,
    "NotImplementedError": NotImplementedError,
}

# Calls that may NOT be blindly re-sent after a torn connection: re-executing
# them would create a second trial/study or turn a won claim into a lost one.
_NON_IDEMPOTENT = frozenset(
    {"create_new_study", "create_new_trial", "create_new_trials", "set_trial_state_values"}
)


def parse_remote_url(url: str) -> tuple[str, int]:
    host, port, _, _ = parse_remote_url_auth(url)
    return host, port


def parse_remote_url_auth(url: str) -> tuple[str, int, "str | None", bool]:
    """Parse ``remote[+tls]://[token@]host:port`` into
    (host, port, token, tls)."""
    tls = False
    if url.startswith("remote+tls://"):
        tls = True
        hostport = url[len("remote+tls://"):].rstrip("/")
    elif url.startswith("remote://"):
        hostport = url[len("remote://"):].rstrip("/")
    else:
        raise ValueError(f"not a remote:// URL: {url!r}")
    token: str | None = None
    if "@" in hostport:
        token, _, hostport = hostport.rpartition("@")
        token = token or None
    host, sep, port = hostport.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"remote:// URL needs host:port, got {url!r}")
    return host, int(port), token, tls


class RemoteStorage(BaseStorage):
    """Storage proxy speaking the length-prefixed remote protocol.

    Args:
        url: ``remote://host:port`` (or ``remote+tls://host:port``) of a
            running :class:`StorageServer`.  A shared-secret token may be
            embedded as ``remote://token@host:port``.
        timeout: per-call socket timeout in seconds.
        retries: reconnect attempts per call before giving up.
        auth_token: shared secret for servers started with one.  Falls back
            to the URL userinfo, then the ``REPRO_STORAGE_TOKEN`` env var.
            Sent once per connection as an ``auth`` handshake frame; the
            server drops unauthenticated connections when configured.
        protocol: highest wire protocol to negotiate.  ``2`` (default) sends
            a ``hello`` after auth and switches the connection to binary
            frames when the server agrees; a JSON-only server answers with
            an unknown-method error and the client silently stays on v1.
            ``1`` pins the client to legacy JSON frames.
        tls_ca: PEM bundle to verify the server certificate against for
            ``remote+tls://`` URLs (falls back to ``$REPRO_STORAGE_TLS_CA``,
            then the system trust store).
    """

    def __init__(
        self, url: str, timeout: float = 30.0, retries: int = 3,
        auth_token: "str | None" = None, protocol: int = 2,
        tls_ca: "str | None" = None,
    ):
        self._host, self._port, url_token, self._tls = parse_remote_url_auth(url)
        self._auth_token = auth_token or url_token or os.environ.get("REPRO_STORAGE_TOKEN")
        scheme = "remote+tls" if self._tls else "remote"
        self._url = f"{scheme}://{self._host}:{self._port}"  # token never echoed
        self._timeout = timeout
        self._retries = max(1, retries)
        self._protocol = protocol
        self._ssl_context: ssl.SSLContext | None = None
        if self._tls:
            cafile = tls_ca or os.environ.get("REPRO_STORAGE_TLS_CA")
            self._ssl_context = ssl.create_default_context(cafile=cafile)
        # set once the server answers hello with an unknown-method error:
        # later connections (and re-dials) skip the doomed negotiation
        self._server_is_v1 = False
        self._local = threading.local()
        self._id_lock = threading.Lock()
        self._next_id = 0
        self._call("ping")  # fail fast on a bad address (or a bad token)

    @property
    def url(self) -> str:
        return self._url

    @property
    def protocol(self) -> int:
        """The wire protocol negotiated on this thread's connection (dials
        one if the thread has never talked to the server)."""
        if getattr(self._local, "sock", None) is None:
            self._call("ping")
        return getattr(self._local, "proto", 1)

    @property
    def supports_block_fetch(self) -> bool:
        """Whether the columnar block RPCs are worth attempting (callers
        still handle ``NotImplementedError`` — negotiation is per-thread)."""
        if self._protocol < 2 or self._server_is_v1:
            return False
        return True

    # -- transport -------------------------------------------------------------

    def _sock(self) -> socket.socket:
        sock = getattr(self._local, "sock", None)
        if sock is None:
            sock = socket.create_connection((self._host, self._port), timeout=self._timeout)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                if self._ssl_context is not None:
                    sock = self._ssl_context.wrap_socket(
                        sock, server_hostname=self._host
                    )
            except BaseException:
                sock.close()
                raise
            telemetry.inc("client.connects")
            if getattr(self._local, "ever_connected", False):
                telemetry.inc("client.reconnects")  # re-dial after a torn socket
            self._local.ever_connected = True
            self._local.sock = sock
            self._local.proto = 1
            if self._auth_token is not None:
                self._authenticate(sock)
            if self._protocol >= 2 and not self._server_is_v1:
                self._negotiate(sock)
        return sock

    def _negotiate(self, sock: socket.socket) -> None:
        """Offer wire protocol v2 via a JSON ``hello``; on agreement the
        connection switches to binary frames for everything that follows."""
        request = {
            "id": self._req_id(), "method": "hello",
            "params": [{"protocol": min(self._protocol, 2)}],
        }
        try:
            send_frame(sock, json.dumps(request).encode())
            body = recv_frame(sock)
        except (OSError, ConnectionError):
            self._drop_sock()
            raise
        if body is None:
            self._drop_sock()
            raise ConnectionError("server closed the connection during hello")
        response = json.loads(body)
        if response.get("ok"):
            if int(response["result"].get("protocol", 1)) >= 2:
                self._local.proto = 2
                telemetry.inc("client.protocol_v2_connects")
        else:
            # pre-v2 server: "unknown storage method 'hello'" — remember and
            # stay on JSON so re-dials skip the wasted round trip
            self._server_is_v1 = True
            telemetry.inc("client.protocol_fallbacks")

    def _authenticate(self, sock: socket.socket) -> None:
        """Per-connection handshake: the first frame carries the shared
        secret; everything else is rejected by a token-protected server."""
        request = {"id": self._req_id(), "method": "auth", "params": [self._auth_token]}
        try:
            send_frame(sock, json.dumps(request).encode())
            body = recv_frame(sock)
        except (OSError, ConnectionError):
            self._drop_sock()
            raise
        if body is None:
            self._drop_sock()
            raise ConnectionError("server closed the connection during auth")
        try:
            self._unwrap(json.loads(body))  # raises PermissionError on a bad token
        except Exception:
            # the server drops rejected connections: never cache the socket,
            # or the next call would surface a torn-connection error instead
            # of the real auth failure
            self._drop_sock()
            raise

    def _drop_sock(self) -> None:
        sock = getattr(self._local, "sock", None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
            self._local.sock = None
            self._local.proto = 1  # the next dial renegotiates
            # the server's per-connection spec cache died with the socket;
            # dropping here (never at connect time) keeps a def registered at
            # encode time valid for the send that follows on a fresh dial
            self._local.spec_ids = {}

    def _req_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id

    def _encode_payload(self, request: Any, proto: int) -> bytes:
        if proto == 2:
            # binary frames carry rich params natively — no pack() pass
            return _MAGIC + bdumps(request)
        if isinstance(request, list):
            wire = [{**r, "params": pack(r["params"])} for r in request]
        else:
            wire = {**request, "params": pack(request["params"])}
        return json.dumps(wire).encode()

    def _roundtrip(self, request: Any, payloads: dict[int, bytes]) -> Any:
        """Send one frame, read one frame.  ``payloads`` caches the encoded
        request per protocol, so the bytes survive the retry loop (a re-dial
        that renegotiates the same protocol re-sends without re-encoding).
        Transport failures carry a ``_rpc_sent`` attribute."""
        try:
            sock = self._sock()
        except PermissionError:
            raise  # bad auth token: surface immediately, never retry
        except (OSError, ConnectionError) as e:
            # connect/auth-transport failure: the request never hit the wire
            e._rpc_sent = False  # type: ignore[attr-defined]
            raise
        proto = getattr(self._local, "proto", 1)
        payload = payloads.get(proto)
        if payload is None:
            payload = payloads[proto] = self._encode_payload(request, proto)
        sent = False
        try:
            send_frame(sock, payload)
            sent = True
            telemetry.inc("client.frames_out")
            telemetry.inc("client.bytes_out", len(payload))
            body = recv_frame(sock)
        except (OSError, ConnectionError) as e:
            self._drop_sock()
            e._rpc_sent = sent  # type: ignore[attr-defined]
            raise
        if body is None:
            self._drop_sock()
            e = ConnectionError("server closed the connection")
            e._rpc_sent = True  # type: ignore[attr-defined]
            raise e
        telemetry.inc("client.frames_in")
        telemetry.inc("client.bytes_in", len(body))
        if proto == 2:
            if not body or body[0] != BINARY_MAGIC:
                self._drop_sock()
                e = ConnectionError("malformed binary frame from server")
                e._rpc_sent = True  # type: ignore[attr-defined]
                raise e
            return bloads(memoryview(body)[1:]), True
        return json.loads(body), False

    def _call_raw(self, request: Any, *, idempotent: bool) -> tuple[Any, bool]:
        """Returns ``(decoded_response, rich)`` — ``rich`` meaning the
        response came over v2 and needs no serde unpack."""
        payloads: dict[int, bytes] = {}
        last: Exception | None = None
        for attempt in range(self._retries):
            try:
                return self._roundtrip(request, payloads)
            except PermissionError:
                raise  # auth rejection is terminal (PermissionError < OSError)
            except (OSError, ConnectionError) as e:
                last = e
                sent = getattr(e, "_rpc_sent", True)
                if sent and not idempotent:
                    raise RetryableStorageError(
                        f"connection to {self._url} died after a non-idempotent "
                        f"request was sent; cannot safely retry: {e}"
                    ) from e
                if attempt < self._retries - 1:
                    telemetry.inc("client.retries")
                    time.sleep(0.05 * (attempt + 1))
        raise RetryableStorageError(f"cannot reach storage server {self._url}: {last}") from last

    # -- pruner-spec interning ---------------------------------------------------

    _SPEC_DEF = "__spec_def__"
    _SPEC_REF = "__spec_ref__"

    def _spec_wire(self, study_id: int, spec: dict) -> dict:
        """Intern a pruner spec per (connection, study): the full spec
        travels once as ``{__spec_def__: {id, spec}}``, every later fused
        report of the same study sends the ~20-byte ``{__spec_ref__: id}``
        instead.  The server's cache is per-connection, so a re-dialed
        socket starts clean on both sides (see ``_sock``/``_drop_sock``)."""
        ids = getattr(self._local, "spec_ids", None)
        if ids is None:
            ids = self._local.spec_ids = {}
        key = (study_id, json.dumps(spec, sort_keys=True))
        ref = ids.get(key)
        if ref is not None:
            return {self._SPEC_REF: ref}
        ref = len(ids)
        ids[key] = ref
        return {self._SPEC_DEF: {"id": ref, "spec": spec}}

    def _encode_params(self, method: str, params: list) -> list:
        if (
            method == "report_and_prune"
            and len(params) >= 6
            and isinstance(params[4], dict)
            and self._SPEC_DEF not in params[4]
            and self._SPEC_REF not in params[4]
        ):
            params = list(params)
            params[4] = self._spec_wire(params[0], params[4])
        return params

    @staticmethod
    def _is_spec_ref_miss(e: Exception) -> bool:
        return isinstance(e, ValueError) and "pruner spec ref" in str(e)

    def _call(self, method: str, *params: Any) -> Any:
        # per-method RPC latency: measured around the full retry loop, so a
        # re-dialed call's percentiles include what the worker actually waited
        t0 = time.perf_counter() if telemetry.enabled() else 0.0
        try:
            return self._call_timed(method, params)
        finally:
            if telemetry.enabled():
                telemetry.observe(f"client.rpc.{method}", time.perf_counter() - t0)

    def _call_timed(self, method: str, params: tuple) -> Any:
        for attempt in (0, 1):
            encoded = self._encode_params(method, list(params))
            request = {"id": self._req_id(), "method": method, "params": encoded}
            try:
                response, rich = self._call_raw(
                    request, idempotent=method not in _NON_IDEMPOTENT
                )
                return self._unwrap(response, rich)
            except ValueError as e:
                # a spec ref can outlive its server-side cache when the
                # connection is torn between encode and send: resend once
                # with the cache cleared (the full spec travels again)
                if attempt == 0 and self._is_spec_ref_miss(e):
                    self._local.spec_ids = {}
                    continue
                raise
        raise AssertionError("unreachable")  # pragma: no cover

    def call_batch(self, calls: list[tuple[str, tuple]]) -> list[Any]:
        """Execute many calls in one round trip (server-side request batching).

        Used by :class:`CachedStorage` to flush buffered writes.  The batch is
        idempotent-retried only if *every* call in it is idempotent; a
        spec-ref cache miss (see ``_spec_wire``) likewise resends the whole
        batch once — every op in a spec-carrying batch is an overwrite, so
        the replay is safe.
        """
        idempotent = all(m not in _NON_IDEMPOTENT for m, _ in calls)
        telemetry.inc("client.batched_ops", len(calls))
        with telemetry.span("client.rpc.call_batch"):
            return self._call_batch_inner(calls, idempotent)

    def _call_batch_inner(self, calls: list[tuple[str, tuple]], idempotent: bool) -> list[Any]:
        for attempt in (0, 1):
            request = [
                {
                    "id": self._req_id(),
                    "method": m,
                    "params": self._encode_params(m, list(p)),
                }
                for m, p in calls
            ]
            responses, rich = self._call_raw(request, idempotent=idempotent)
            try:
                return [self._unwrap(r, rich) for r in responses]
            except ValueError as e:
                if attempt == 0 and self._is_spec_ref_miss(e):
                    self._local.spec_ids = {}
                    continue
                raise
        raise AssertionError("unreachable")  # pragma: no cover

    @staticmethod
    def _unwrap(response: dict, rich: bool = False) -> Any:
        if response.get("ok"):
            result = response.get("result")
            # v2 responses decode straight to rich objects; v1 JSON results
            # carry serde tags that unpack() resolves
            return result if rich else unpack(result)
        err = response.get("error") or {}
        cls = _ERROR_TYPES.get(err.get("type", ""), StorageInternalError)
        raise cls(err.get("message", "remote storage error"))

    # -- study -----------------------------------------------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        return self._call("create_new_study", list(directions), study_name)

    def delete_study(self, study_id: int) -> None:
        self._call("delete_study", study_id)

    def get_study_id_from_name(self, study_name: str) -> int:
        return self._call("get_study_id_from_name", study_name)

    def get_study_name_from_id(self, study_id: int) -> str:
        return self._call("get_study_name_from_id", study_id)

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        return self._call("get_study_directions", study_id)

    def get_all_studies(self) -> list[StudySummary]:
        return self._call("get_all_studies")

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        self._call("set_study_user_attr", study_id, key, value)

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        self._call("set_study_system_attr", study_id, key, value)

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        return self._call("get_study_user_attrs", study_id)

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        return self._call("get_study_system_attrs", study_id)

    # -- trial -----------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        return self._call("create_new_trial", study_id, template_trial)

    def create_new_trials(
        self, study_id: int, n: int, template_trial: FrozenTrial | None = None
    ) -> list[int]:
        if n <= 0:
            return []
        if n == 1:
            return [self.create_new_trial(study_id, template_trial)]
        # one native RPC: n trials claimed in a single dispatch (the batched
        # per-trial fallback cost one dispatch per trial inside the frame)
        return self._call("create_new_trials", study_id, int(n), template_trial)

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution,
    ) -> None:
        self._call("set_trial_param", trial_id, param_name, float(param_value_internal), distribution)

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        vs = [float(v) for v in values] if values is not None else None
        return self._call("set_trial_state_values", trial_id, state, vs)

    def set_trial_intermediate_value(
        self, trial_id: int, step: int, intermediate_value: float
    ) -> None:
        self._call("set_trial_intermediate_value", trial_id, int(step), float(intermediate_value))

    def report_and_prune(
        self, study_id: int, trial_id: int, step: int, value: float,
        pruner_spec: dict, direction,
    ) -> bool:
        """Fused report→prune in one frame: the server writes the value and
        evaluates the pruner spec against its own warm peer store.  Safe to
        retry on a torn connection (the write is an overwrite, the decision
        a pure read).  The spec itself is interned per (connection, study)
        — sent once in full, then as a short ref (see ``_spec_wire``)."""
        return bool(
            self._call(
                "report_and_prune", study_id, trial_id, int(step), float(value),
                pruner_spec, direction,
            )
        )

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._call("set_trial_user_attr", trial_id, key, value)

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._call("set_trial_system_attr", trial_id, key, value)

    def get_trial(self, trial_id: int) -> FrozenTrial:
        return self._call("get_trial", trial_id)

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        states_list = list(states) if states is not None else None
        return self._call("get_all_trials", study_id, deepcopy, states_list, since)

    def get_n_trials(self, study_id: int, states: tuple[TrialState, ...] | None = None) -> int:
        states_list = list(states) if states is not None else None
        return self._call("get_n_trials", study_id, states_list)

    def get_trial_id_from_study_and_number(self, study_id: int, number: int) -> int:
        return self._call("get_trial_id_from_study_and_number", study_id, number)

    def get_trials_revision(self, study_id: int) -> int:
        return self._call("get_trials_revision", study_id)

    # -- columnar block fetch (wire protocol v2) ---------------------------------

    def get_observation_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Finished-trial observations since a revision as raw numpy columns
        (one frame, near-memcpy decode).  Raises ``NotImplementedError`` on a
        v1 connection — callers fall back to ``get_all_trials(since=)``."""
        return self._call("get_observation_block", study_id, int(since))

    def get_iv_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Intermediate-value curves since a revision as CSR numpy columns.
        Raises ``NotImplementedError`` on a v1 connection."""
        return self._call("get_iv_block", study_id, int(since))

    # -- heartbeat ---------------------------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        self._call("record_heartbeat", trial_id)

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._call("get_stale_trial_ids", study_id, float(grace_seconds))

    def fail_stale_trials(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._call("fail_stale_trials", study_id, float(grace_seconds))

    # -- telemetry ---------------------------------------------------------------

    def get_trial_events(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """The server-side trial-lifecycle trace (columnar wire dict): events
        from every worker of the fleet, in server execution order."""
        return self._call("get_trial_events", study_id, int(since))

    def get_server_metrics(self) -> dict[str, Any]:
        """The server's always-on metrics surface (see
        ``_RPCServer.server_metrics``): per-method call counts / latency
        percentiles / bytes, active connections, auth failures, cache hits."""
        return self._call("get_server_metrics")

    # -- misc ---------------------------------------------------------------------

    def close(self) -> None:
        """Close this thread's connection (other threads' sockets close on GC)."""
        self._drop_sock()
