"""``RemoteStorage`` — the client half of the networked storage service.

Connects to a :class:`~repro.core.storage.server.StorageServer` via a
``remote://host:port`` URL and implements the full :class:`BaseStorage`
contract by forwarding each call as one JSON-RPC frame (see server.py for the
wire format).

Design points:

* **Per-thread connections** — ``study.optimize(n_jobs=k)`` threads each get
  their own socket, so responses can never interleave.
* **Retry-on-reconnect** — a dropped connection is re-dialed transparently.
  Idempotent calls (all reads, value-overwriting writes) are retried; calls
  whose *effect* is not idempotent (``create_new_trial``,
  ``create_new_study``, the WAITING->RUNNING claim) are only retried when the
  request provably never reached the wire, otherwise
  :class:`RetryableStorageError` is raised for the caller to decide.
* **Atomic compare-and-set** — ``set_trial_state_values`` executes inside the
  single server process against the wrapped backend, so ``ask()``'s
  WAITING-claim race stays exactly-once across machines.
* **Failover** — a URL may list ``+``-separated candidates
  (``remote://primary:p1+replica:p2``).  The client validates role and epoch
  at connect time (cluster extras ride the ``hello``), refuses replicas and
  stale-epoch primaries, and rotates to the next candidate with jittered
  exponential backoff under a per-RPC deadline.  Non-idempotent calls carry
  an ``op`` id; against a dedup-capable server a torn-connection retransmit
  can never double-execute, so even ``tell`` survives a mid-flight failover.
"""

from __future__ import annotations

import json
import os
import random
import socket
import ssl
import threading
import time
import uuid
from typing import Any, Iterable

from .. import telemetry
from ..exceptions import (
    DuplicatedStudyError,
    RetryableStorageError,
    StorageInternalError,
    StorageUnavailableError,
    StudyNotFoundError,
    TrialNotFoundError,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary
from .serde import BINARY_MAGIC, bdumps, bloads, pack, unpack
from .server import recv_frame, send_frame

_MAGIC = bytes([BINARY_MAGIC])

__all__ = ["RemoteStorage", "parse_remote_url", "parse_remote_candidates"]

# server-side exception type name -> client-side class to re-raise
_ERROR_TYPES: dict[str, type[Exception]] = {
    "StudyNotFoundError": StudyNotFoundError,
    "TrialNotFoundError": TrialNotFoundError,
    "DuplicatedStudyError": DuplicatedStudyError,
    "StorageInternalError": StorageInternalError,
    "RetryableStorageError": RetryableStorageError,
    "StorageUnavailableError": StorageUnavailableError,
    "RuntimeError": RuntimeError,
    "ValueError": ValueError,
    "TypeError": TypeError,
    "KeyError": KeyError,
    "PermissionError": PermissionError,
    "NotImplementedError": NotImplementedError,
}

# Calls that may NOT be blindly re-sent after a torn connection: re-executing
# them would create a second trial/study or turn a won claim into a lost one.
# (Against a dedup-capable server they travel with an ``op`` id and become
# safely retransmittable — see ``_call_raw``.)
_NON_IDEMPOTENT = frozenset(
    {"create_new_study", "create_new_trial", "create_new_trials", "set_trial_state_values"}
)

# methods that carry an ``op`` idempotency token (the server's dedup window
# also caches the fused report's prune decision)
_OP_STAMPED = _NON_IDEMPOTENT | {"report_and_prune"}


def parse_remote_url(url: str) -> tuple[str, int]:
    host, port, _, _ = parse_remote_url_auth(url)
    return host, port


def parse_remote_url_auth(url: str) -> tuple[str, int, "str | None", bool]:
    """Parse ``remote[+tls]://[token@]host:port`` into
    (host, port, token, tls) — the *first* candidate of a failover list."""
    candidates, token, tls = parse_remote_candidates(url)
    host, port = candidates[0]
    return host, port, token, tls


def parse_remote_candidates(
    url: str,
) -> tuple[list[tuple[str, int]], "str | None", bool]:
    """Parse ``remote[+tls]://[token@]h1:p1[+h2:p2...]`` into
    (candidates, token, tls).  ``+``-separated host:port pairs are failover
    candidates for the *same* logical node (primary first, then replicas);
    sharding across *different* nodes uses commas and is handled one level
    up by :class:`~repro.core.storage.cluster.ShardedStorage`."""
    tls = False
    if url.startswith("remote+tls://"):
        tls = True
        hostport = url[len("remote+tls://"):].rstrip("/")
    elif url.startswith("remote://"):
        hostport = url[len("remote://"):].rstrip("/")
    else:
        raise ValueError(f"not a remote:// URL: {url!r}")
    token: str | None = None
    if "@" in hostport:
        token, _, hostport = hostport.rpartition("@")
        token = token or None
    candidates: list[tuple[str, int]] = []
    for part in hostport.split("+"):
        host, sep, port = part.rpartition(":")
        if not sep or not port.isdigit():
            raise ValueError(f"remote:// URL needs host:port, got {url!r}")
        candidates.append((host, int(port)))
    if not candidates:
        raise ValueError(f"remote:// URL has no candidates: {url!r}")
    return candidates, token, tls


class RemoteStorage(BaseStorage):
    """Storage proxy speaking the length-prefixed remote protocol.

    Args:
        url: ``remote://host:port`` (or ``remote+tls://host:port``) of a
            running :class:`StorageServer`.  A shared-secret token may be
            embedded as ``remote://token@host:port``.
        timeout: per-call socket timeout in seconds.
        retries: reconnect attempts per call before giving up.
        auth_token: shared secret for servers started with one.  Falls back
            to the URL userinfo, then the ``REPRO_STORAGE_TOKEN`` env var.
            Sent once per connection as an ``auth`` handshake frame; the
            server drops unauthenticated connections when configured.
        protocol: highest wire protocol to negotiate.  ``2`` (default) sends
            a ``hello`` after auth and switches the connection to binary
            frames when the server agrees; a JSON-only server answers with
            an unknown-method error and the client silently stays on v1.
            ``1`` pins the client to legacy JSON frames.
        tls_ca: PEM bundle to verify the server certificate against for
            ``remote+tls://`` URLs (falls back to ``$REPRO_STORAGE_TLS_CA``,
            then the system trust store).
        rpc_deadline: wall-clock budget per logical call, in seconds.  All
            reconnects, candidate rotations, and backoff sleeps for one call
            must fit inside it; ``None`` disables the budget (``retries``
            still caps attempts).
        backoff_base / backoff_cap: jittered exponential backoff between
            reconnect attempts — sleep ``min(cap, base * 2^k) * uniform(0.5,
            1.5)``.
        backoff_seed: seed for the backoff/jitter RNG (deterministic chaos
            tests); ``None`` seeds from the OS.
    """

    def __init__(
        self, url: str, timeout: float = 30.0, retries: int = 3,
        auth_token: "str | None" = None, protocol: int = 2,
        tls_ca: "str | None" = None, rpc_deadline: "float | None" = 60.0,
        backoff_base: float = 0.05, backoff_cap: float = 2.0,
        backoff_seed: "int | None" = None,
    ):
        self._candidates, url_token, self._tls = parse_remote_candidates(url)
        self._host, self._port = self._candidates[0]
        self._auth_token = auth_token or url_token or os.environ.get("REPRO_STORAGE_TOKEN")
        scheme = "remote+tls" if self._tls else "remote"
        # token never echoed
        self._url = f"{scheme}://" + "+".join(f"{h}:{p}" for h, p in self._candidates)
        self._timeout = timeout
        self._retries = max(1, retries)
        self._protocol = protocol
        self._rpc_deadline = rpc_deadline
        self._backoff_base = backoff_base
        self._backoff_cap = backoff_cap
        self._rng = random.Random(backoff_seed)
        self._ssl_context: ssl.SSLContext | None = None
        if self._tls:
            cafile = tls_ca or os.environ.get("REPRO_STORAGE_TLS_CA")
            self._ssl_context = ssl.create_default_context(cafile=cafile)
        # set once the server answers hello with an unknown-method error:
        # later connections (and re-dials) skip the doomed negotiation
        self._server_is_v1 = False
        # -- failover state (shared across threads; races are benign — a
        # stale _active just costs one extra dial) --
        self._active = 0           # index of the candidate serving us
        self._epoch_seen = 0       # highest primary epoch ever observed
        self._dedup_ok = False     # server keeps an op-id dedup window
        self._client_uid = uuid.uuid4().hex[:12]  # namespace for op ids
        self._local = threading.local()
        self._id_lock = threading.Lock()
        self._next_id = 0
        self._call("ping")  # fail fast on a bad address (or a bad token)

    @property
    def url(self) -> str:
        return self._url

    @property
    def protocol(self) -> int:
        """The wire protocol negotiated on this thread's connection (dials
        one if the thread has never talked to the server)."""
        if getattr(self._local, "sock", None) is None:
            self._call("ping")
        return getattr(self._local, "proto", 1)

    @property
    def supports_block_fetch(self) -> bool:
        """Whether the columnar block RPCs are worth attempting (callers
        still handle ``NotImplementedError`` — negotiation is per-thread)."""
        if self._protocol < 2 or self._server_is_v1:
            return False
        return True

    # -- transport -------------------------------------------------------------

    def _sock(self) -> socket.socket:
        sock = getattr(self._local, "sock", None)
        if sock is not None:
            return sock
        n = len(self._candidates)
        last: Exception | None = None
        start = self._active
        for k in range(n):
            idx = (start + k) % n
            host, port = self._candidates[idx]
            try:
                sock = self._dial(host, port)
            except PermissionError:
                raise  # bad token: the next candidate shares it, don't spin
            except (OSError, ConnectionError) as e:
                last = e
                self._drop_sock()
                continue
            if idx != self._active:
                self._active = idx
                telemetry.inc("client.failovers")
            return sock
        assert last is not None
        raise last

    def _dial(self, host: str, port: int) -> socket.socket:
        sock = socket.create_connection((host, port), timeout=self._timeout)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self._ssl_context is not None:
                sock = self._ssl_context.wrap_socket(sock, server_hostname=host)
        except BaseException:
            sock.close()
            raise
        telemetry.inc("client.connects")
        if getattr(self._local, "ever_connected", False):
            telemetry.inc("client.reconnects")  # re-dial after a torn socket
        self._local.ever_connected = True
        self._local.sock = sock
        self._local.proto = 1
        if self._auth_token is not None:
            self._authenticate(sock)
        hello_info: "dict | None" = None
        if self._protocol >= 2 and not self._server_is_v1:
            hello_info = self._negotiate(sock)
        self._validate_cluster(sock, hello_info)
        return sock

    def _negotiate(self, sock: socket.socket) -> "dict | None":
        """Offer wire protocol v2 via a JSON ``hello``; on agreement the
        connection switches to binary frames for everything that follows.
        Returns the hello result (which carries the cluster extras
        ``role``/``epoch``/``dedup`` on fault-tolerant servers)."""
        request = {
            "id": self._req_id(), "method": "hello",
            "params": [{"protocol": min(self._protocol, 2)}],
        }
        try:
            send_frame(sock, json.dumps(request).encode())
            body = recv_frame(sock)
        except (OSError, ConnectionError):
            self._drop_sock()
            raise
        if body is None:
            self._drop_sock()
            raise ConnectionError("server closed the connection during hello")
        response = json.loads(body)
        if response.get("ok"):
            if int(response["result"].get("protocol", 1)) >= 2:
                self._local.proto = 2
                telemetry.inc("client.protocol_v2_connects")
            return response["result"]
        # pre-v2 server: "unknown storage method 'hello'" — remember and
        # stay on JSON so re-dials skip the wasted round trip
        self._server_is_v1 = True
        telemetry.inc("client.protocol_fallbacks")
        return None

    # -- cluster awareness -----------------------------------------------------

    def _validate_cluster(self, sock: socket.socket, hello_info: "dict | None") -> None:
        """Refuse un-promoted replicas and fenced (stale-epoch) primaries at
        connect time, so a worker never writes into a node that will lose the
        failover.  Raises ``ConnectionError`` — ``_sock`` rotates onward."""
        info = hello_info if hello_info and "role" in hello_info else None
        if info is None:
            # v1 connection (or a hello that carried no cluster extras):
            # probe explicitly, but only when there is actually a failover
            # list — a single legacy server shouldn't pay the round trip
            if len(self._candidates) <= 1:
                return
            info = self._cluster_info_rpc(sock)
            if info is None:
                return  # legacy server: no cluster support, nothing to check
        role = info.get("role", "primary")
        epoch = int(info.get("epoch", 1))
        if role != "primary" and len(self._candidates) > 1:
            # an explicit single-node URL aimed at a replica stays usable for
            # diagnostic reads (writes get StorageUnavailableError from the
            # server); with a failover list we keep hunting for the primary
            raise ConnectionError(
                f"candidate is a {role} (epoch {epoch}); looking for the primary"
            )
        if epoch < self._epoch_seen:
            # an old primary restarted after its replica was promoted: writing
            # to it would fork history.  Treat it as dead until it re-syncs.
            raise ConnectionError(
                f"fenced primary: epoch {epoch} < highest seen {self._epoch_seen}"
            )
        self._epoch_seen = max(self._epoch_seen, epoch)
        if info.get("dedup"):
            self._dedup_ok = True

    def _cluster_info_rpc(self, sock: socket.socket) -> "dict | None":
        proto = getattr(self._local, "proto", 1)
        request = {"id": self._req_id(), "method": "get_cluster_info", "params": []}
        send_frame(sock, self._encode_payload(request, proto))
        body = recv_frame(sock)
        if body is None:
            raise ConnectionError("server closed the connection during cluster probe")
        if proto == 2 and body and body[0] == BINARY_MAGIC:
            response, rich = bloads(memoryview(body)[1:]), True
        else:
            response, rich = json.loads(body), False
        try:
            return self._unwrap(response, rich)
        except Exception:
            return None  # unknown-method error: a server without cluster support

    def _authenticate(self, sock: socket.socket) -> None:
        """Per-connection handshake: the first frame carries the shared
        secret; everything else is rejected by a token-protected server."""
        request = {"id": self._req_id(), "method": "auth", "params": [self._auth_token]}
        try:
            send_frame(sock, json.dumps(request).encode())
            body = recv_frame(sock)
        except (OSError, ConnectionError):
            self._drop_sock()
            raise
        if body is None:
            self._drop_sock()
            raise ConnectionError("server closed the connection during auth")
        try:
            self._unwrap(json.loads(body))  # raises PermissionError on a bad token
        except Exception:
            # the server drops rejected connections: never cache the socket,
            # or the next call would surface a torn-connection error instead
            # of the real auth failure
            self._drop_sock()
            raise

    def _drop_sock(self) -> None:
        sock = getattr(self._local, "sock", None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
            self._local.sock = None
            self._local.proto = 1  # the next dial renegotiates
            # the server's per-connection spec cache died with the socket;
            # dropping here (never at connect time) keeps a def registered at
            # encode time valid for the send that follows on a fresh dial
            self._local.spec_ids = {}

    def _req_id(self) -> int:
        with self._id_lock:
            self._next_id += 1
            return self._next_id

    def _encode_payload(self, request: Any, proto: int) -> bytes:
        if proto == 2:
            # binary frames carry rich params natively — no pack() pass
            return _MAGIC + bdumps(request)
        if isinstance(request, list):
            wire = [{**r, "params": pack(r["params"])} for r in request]
        else:
            wire = {**request, "params": pack(request["params"])}
        return json.dumps(wire).encode()

    def _roundtrip(self, request: Any, payloads: dict[int, bytes]) -> Any:
        """Send one frame, read one frame.  ``payloads`` caches the encoded
        request per protocol, so the bytes survive the retry loop (a re-dial
        that renegotiates the same protocol re-sends without re-encoding).
        Transport failures carry a ``_rpc_sent`` attribute."""
        try:
            sock = self._sock()
        except PermissionError:
            raise  # bad auth token: surface immediately, never retry
        except (OSError, ConnectionError) as e:
            # connect/auth-transport failure: the request never hit the wire
            e._rpc_sent = False  # type: ignore[attr-defined]
            raise
        proto = getattr(self._local, "proto", 1)
        payload = payloads.get(proto)
        if payload is None:
            payload = payloads[proto] = self._encode_payload(request, proto)
        sent = False
        try:
            send_frame(sock, payload)
            sent = True
            telemetry.inc("client.frames_out")
            telemetry.inc("client.bytes_out", len(payload))
            body = recv_frame(sock)
        except (OSError, ConnectionError) as e:
            self._drop_sock()
            e._rpc_sent = sent  # type: ignore[attr-defined]
            raise
        if body is None:
            self._drop_sock()
            e = ConnectionError("server closed the connection")
            e._rpc_sent = True  # type: ignore[attr-defined]
            raise e
        telemetry.inc("client.frames_in")
        telemetry.inc("client.bytes_in", len(body))
        if proto == 2:
            if not body or body[0] != BINARY_MAGIC:
                self._drop_sock()
                e = ConnectionError("malformed binary frame from server")
                e._rpc_sent = True  # type: ignore[attr-defined]
                raise e
            return bloads(memoryview(body)[1:]), True
        return json.loads(body), False

    def _sleep_backoff(self, k: int, deadline: "float | None") -> None:
        """Jittered exponential backoff before attempt ``k+1`` (k >= 1),
        clamped so the sleep never overshoots the per-call deadline."""
        d = min(self._backoff_cap, self._backoff_base * (2 ** min(k - 1, 8)))
        d *= 0.5 + self._rng.random()
        if deadline is not None:
            d = min(d, max(0.0, deadline - time.monotonic()))
        if d > 0:
            telemetry.inc("client.backoff_ms", int(d * 1000))
            time.sleep(d)

    def _call_raw(
        self, request: Any, *, idempotent: bool, deduped: bool = False,
        deadline: "float | None" = None,
    ) -> tuple[Any, bool]:
        """Returns ``(decoded_response, rich)`` — ``rich`` meaning the
        response came over v2 and needs no serde unpack.

        ``deduped`` marks a request stamped with an ``op`` id: against a
        dedup-capable server it may be retransmitted even after it hit the
        wire (re-execution is suppressed server-side), which closes the
        torn-``tell`` window that plain non-idempotent calls must refuse.
        """
        payloads: dict[int, bytes] = {}
        last: Exception | None = None
        for attempt in range(1, self._retries + 1):
            try:
                return self._roundtrip(request, payloads)
            except PermissionError:
                raise  # auth rejection is terminal (PermissionError < OSError)
            except (OSError, ConnectionError) as e:
                last = e
                sent = getattr(e, "_rpc_sent", True)
                if sent and not idempotent:
                    if not (deduped and self._dedup_ok):
                        raise RetryableStorageError(
                            f"connection to {self._url} died after a non-idempotent "
                            f"request was sent; cannot safely retry: {e}"
                        ) from e
                    telemetry.inc("client.dedup_retransmits")
                if attempt >= self._retries:
                    break
                if deadline is not None and time.monotonic() >= deadline:
                    break
                telemetry.inc("client.retries")
                self._sleep_backoff(attempt, deadline)
        raise RetryableStorageError(f"cannot reach storage server {self._url}: {last}") from last

    # -- pruner-spec interning ---------------------------------------------------

    _SPEC_DEF = "__spec_def__"
    _SPEC_REF = "__spec_ref__"

    def _spec_wire(self, study_id: int, spec: dict) -> dict:
        """Intern a pruner spec per (connection, study): the full spec
        travels once as ``{__spec_def__: {id, spec}}``, every later fused
        report of the same study sends the ~20-byte ``{__spec_ref__: id}``
        instead.  The server's cache is per-connection, so a re-dialed
        socket starts clean on both sides (see ``_sock``/``_drop_sock``)."""
        ids = getattr(self._local, "spec_ids", None)
        if ids is None:
            ids = self._local.spec_ids = {}
        key = (study_id, json.dumps(spec, sort_keys=True))
        ref = ids.get(key)
        if ref is not None:
            return {self._SPEC_REF: ref}
        ref = len(ids)
        ids[key] = ref
        return {self._SPEC_DEF: {"id": ref, "spec": spec}}

    def _encode_params(self, method: str, params: list) -> list:
        if (
            method == "report_and_prune"
            and len(params) >= 6
            and isinstance(params[4], dict)
            and self._SPEC_DEF not in params[4]
            and self._SPEC_REF not in params[4]
        ):
            params = list(params)
            params[4] = self._spec_wire(params[0], params[4])
        return params

    @staticmethod
    def _is_spec_ref_miss(e: Exception) -> bool:
        return isinstance(e, ValueError) and "pruner spec ref" in str(e)

    def _call(self, method: str, *params: Any) -> Any:
        # per-method RPC latency: measured around the full retry loop, so a
        # re-dialed call's percentiles include what the worker actually waited
        t0 = time.perf_counter() if telemetry.enabled() else 0.0
        try:
            return self._call_timed(method, params)
        finally:
            if telemetry.enabled():
                telemetry.observe(f"client.rpc.{method}", time.perf_counter() - t0)

    def _deadline(self) -> "float | None":
        if self._rpc_deadline is None:
            return None
        return time.monotonic() + self._rpc_deadline

    def _rotate(self) -> None:
        """Advance the shared candidate cursor past the node that just
        refused us, so the next dial starts at its neighbour."""
        if len(self._candidates) > 1:
            self._active = (self._active + 1) % len(self._candidates)

    def _op_id(self) -> str:
        return f"{self._client_uid}:{self._req_id()}"

    def _call_timed(self, method: str, params: tuple) -> Any:
        deadline = self._deadline()
        op_id = self._op_id() if method in _OP_STAMPED else None
        spec_retry = True
        unavailable = 0
        while True:
            encoded = self._encode_params(method, list(params))
            request = {"id": self._req_id(), "method": method, "params": encoded}
            if op_id is not None:
                request["op"] = op_id  # stable across every retransmit
            try:
                response, rich = self._call_raw(
                    request, idempotent=method not in _NON_IDEMPOTENT,
                    deduped=op_id is not None, deadline=deadline,
                )
                return self._unwrap(response, rich)
            except StorageUnavailableError:
                # a not-yet-promoted replica (or mid-failover node) answered:
                # drop the socket, rotate, and retry until the deadline
                unavailable += 1
                self._drop_sock()
                self._rotate()
                if deadline is not None and time.monotonic() >= deadline:
                    raise
                if len(self._candidates) <= 1 and unavailable >= self._retries:
                    raise
                telemetry.inc("client.unavailable_retries")
                self._sleep_backoff(unavailable, deadline)
            except ValueError as e:
                # a spec ref can outlive its server-side cache when the
                # connection is torn between encode and send: resend once
                # with the cache cleared (the full spec travels again)
                if spec_retry and self._is_spec_ref_miss(e):
                    spec_retry = False
                    self._local.spec_ids = {}
                    continue
                raise

    def call_batch(self, calls: list[tuple[str, tuple]]) -> list[Any]:
        """Execute many calls in one round trip (server-side request batching).

        Used by :class:`CachedStorage` to flush buffered writes.  The batch is
        idempotent-retried only if *every* call in it is idempotent; a
        spec-ref cache miss (see ``_spec_wire``) likewise resends the whole
        batch once — every op in a spec-carrying batch is an overwrite, so
        the replay is safe.
        """
        idempotent = all(m not in _NON_IDEMPOTENT for m, _ in calls)
        telemetry.inc("client.batched_ops", len(calls))
        with telemetry.span("client.rpc.call_batch"):
            return self._call_batch_inner(calls, idempotent)

    def _call_batch_inner(self, calls: list[tuple[str, tuple]], idempotent: bool) -> list[Any]:
        deadline = self._deadline()
        # op ids are minted ONCE and survive every resend of the batch: a
        # replayed batch whose first half already executed turns into dedup
        # hits instead of double-executions
        op_ids = [self._op_id() if m in _OP_STAMPED else None for m, _ in calls]
        spec_retry = True
        unavailable = 0
        while True:
            request = []
            for (m, p), op in zip(calls, op_ids):
                r = {
                    "id": self._req_id(),
                    "method": m,
                    "params": self._encode_params(m, list(p)),
                }
                if op is not None:
                    r["op"] = op
                request.append(r)
            try:
                responses, rich = self._call_raw(
                    request, idempotent=idempotent, deduped=True, deadline=deadline,
                )
                return [self._unwrap(r, rich) for r in responses]
            except StorageUnavailableError:
                unavailable += 1
                self._drop_sock()
                self._rotate()
                if deadline is not None and time.monotonic() >= deadline:
                    raise
                if len(self._candidates) <= 1 and unavailable >= self._retries:
                    raise
                telemetry.inc("client.unavailable_retries")
                self._sleep_backoff(unavailable, deadline)
            except ValueError as e:
                if spec_retry and self._is_spec_ref_miss(e):
                    spec_retry = False
                    self._local.spec_ids = {}
                    continue
                raise

    @staticmethod
    def _unwrap(response: dict, rich: bool = False) -> Any:
        if response.get("ok"):
            result = response.get("result")
            # v2 responses decode straight to rich objects; v1 JSON results
            # carry serde tags that unpack() resolves
            return result if rich else unpack(result)
        err = response.get("error") or {}
        cls = _ERROR_TYPES.get(err.get("type", ""), StorageInternalError)
        raise cls(err.get("message", "remote storage error"))

    # -- study -----------------------------------------------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        return self._call("create_new_study", list(directions), study_name)

    def delete_study(self, study_id: int) -> None:
        self._call("delete_study", study_id)

    def get_study_id_from_name(self, study_name: str) -> int:
        return self._call("get_study_id_from_name", study_name)

    def get_study_name_from_id(self, study_id: int) -> str:
        return self._call("get_study_name_from_id", study_id)

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        return self._call("get_study_directions", study_id)

    def get_all_studies(self) -> list[StudySummary]:
        return self._call("get_all_studies")

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        self._call("set_study_user_attr", study_id, key, value)

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        self._call("set_study_system_attr", study_id, key, value)

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        return self._call("get_study_user_attrs", study_id)

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        return self._call("get_study_system_attrs", study_id)

    # -- trial -----------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        return self._call("create_new_trial", study_id, template_trial)

    def create_new_trials(
        self, study_id: int, n: int, template_trial: FrozenTrial | None = None
    ) -> list[int]:
        if n <= 0:
            return []
        if n == 1:
            return [self.create_new_trial(study_id, template_trial)]
        # one native RPC: n trials claimed in a single dispatch (the batched
        # per-trial fallback cost one dispatch per trial inside the frame)
        return self._call("create_new_trials", study_id, int(n), template_trial)

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution,
    ) -> None:
        self._call("set_trial_param", trial_id, param_name, float(param_value_internal), distribution)

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        vs = [float(v) for v in values] if values is not None else None
        return self._call("set_trial_state_values", trial_id, state, vs)

    def set_trial_intermediate_value(
        self, trial_id: int, step: int, intermediate_value: float
    ) -> None:
        self._call("set_trial_intermediate_value", trial_id, int(step), float(intermediate_value))

    def report_and_prune(
        self, study_id: int, trial_id: int, step: int, value: float,
        pruner_spec: dict, direction,
    ) -> bool:
        """Fused report→prune in one frame: the server writes the value and
        evaluates the pruner spec against its own warm peer store.  Safe to
        retry on a torn connection (the write is an overwrite, the decision
        a pure read).  The spec itself is interned per (connection, study)
        — sent once in full, then as a short ref (see ``_spec_wire``)."""
        return bool(
            self._call(
                "report_and_prune", study_id, trial_id, int(step), float(value),
                pruner_spec, direction,
            )
        )

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._call("set_trial_user_attr", trial_id, key, value)

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._call("set_trial_system_attr", trial_id, key, value)

    def get_trial(self, trial_id: int) -> FrozenTrial:
        return self._call("get_trial", trial_id)

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        states_list = list(states) if states is not None else None
        return self._call("get_all_trials", study_id, deepcopy, states_list, since)

    def get_n_trials(self, study_id: int, states: tuple[TrialState, ...] | None = None) -> int:
        states_list = list(states) if states is not None else None
        return self._call("get_n_trials", study_id, states_list)

    def get_trial_id_from_study_and_number(self, study_id: int, number: int) -> int:
        return self._call("get_trial_id_from_study_and_number", study_id, number)

    def get_trials_revision(self, study_id: int) -> int:
        return self._call("get_trials_revision", study_id)

    # -- columnar block fetch (wire protocol v2) ---------------------------------

    def get_observation_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Finished-trial observations since a revision as raw numpy columns
        (one frame, near-memcpy decode).  Raises ``NotImplementedError`` on a
        v1 connection — callers fall back to ``get_all_trials(since=)``."""
        return self._call("get_observation_block", study_id, int(since))

    def get_iv_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Intermediate-value curves since a revision as CSR numpy columns.
        Raises ``NotImplementedError`` on a v1 connection."""
        return self._call("get_iv_block", study_id, int(since))

    # -- heartbeat ---------------------------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        self._call("record_heartbeat", trial_id)

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._call("get_stale_trial_ids", study_id, float(grace_seconds))

    def fail_stale_trials(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._call("fail_stale_trials", study_id, float(grace_seconds))

    def reclaim_stale_trials(
        self, study_id: int, grace_seconds: float, requeue: bool = False
    ) -> list[int]:
        return self._call(
            "reclaim_stale_trials", study_id, float(grace_seconds), bool(requeue)
        )

    # -- telemetry ---------------------------------------------------------------

    def get_trial_events(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """The server-side trial-lifecycle trace (columnar wire dict): events
        from every worker of the fleet, in server execution order."""
        return self._call("get_trial_events", study_id, int(since))

    def get_server_metrics(self) -> dict[str, Any]:
        """The server's always-on metrics surface (see
        ``_RPCServer.server_metrics``): per-method call counts / latency
        percentiles / bytes, active connections, auth failures, cache hits."""
        return self._call("get_server_metrics")

    # -- misc ---------------------------------------------------------------------

    def close(self) -> None:
        """Close this thread's connection (other threads' sockets close on GC)."""
        self._drop_sock()
