"""Append-only journal-file storage.

``sqlite`` over NFS is unreliable (POSIX lock emulation); at pod scale the
robust shared-filesystem design is an *append-only operation log* guarded by
``fcntl`` range locks — every write appends one JSON line; every read replays
the suffix of the log it has not seen.  This is the storage we recommend for
1000+ worker fleets without a DB host.  (Modern Optuna reached the same
conclusion with its ``JournalStorage``.)

Crash-safety: every append is fsync'd by default (``fsync=False`` — or a
``journal://path?fsync=0`` URL — trades the guarantee for throughput on
fast local disks).  A torn final line (a worker died mid-write) is invisible
to readers — they only consume up to the final newline — and is *repaired*
on the next append: whoever takes the exclusive lock truncates the torn tail
(with a warning) before writing, so the log can never glue two half-lines
together.  Corrupt interior lines are skipped with a warning.
"""

from __future__ import annotations

import json
import os
import threading
import time
import uuid
import warnings
from typing import Any, Iterable

from .. import telemetry as _telemetry
from ..distributions import (
    BaseDistribution,
    check_distribution_compatibility,
    distribution_to_json,
    json_to_distribution,
)
from ..exceptions import (
    DuplicatedStudyError,
    StudyNotFoundError,
    TrialNotFoundError,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary

try:
    import fcntl

    _HAS_FCNTL = True
except ImportError:  # pragma: no cover - non-posix
    _HAS_FCNTL = False

__all__ = ["JournalStorage"]


def _dt(ts: float):
    import datetime

    return datetime.datetime.fromtimestamp(ts)

# op codes
_CREATE_STUDY = "create_study"
_DELETE_STUDY = "delete_study"
_CREATE_TRIAL = "create_trial"
_SET_PARAM = "set_param"
_SET_STATE = "set_state"
_SET_IV = "set_iv"
_SET_TATTR = "set_tattr"
_SET_SATTR = "set_sattr"
_HEARTBEAT = "heartbeat"


class _FileLock:
    """Advisory exclusive lock on <path>.lock (fcntl; degrades to a process
    lock where fcntl is unavailable)."""

    def __init__(self, path: str):
        self._path = path + ".lock"
        self._tlock = threading.Lock()
        self._fd: int | None = None

    def __enter__(self):
        self._tlock.acquire()
        if _HAS_FCNTL:
            self._fd = os.open(self._path, os.O_CREAT | os.O_RDWR)
            fcntl.flock(self._fd, fcntl.LOCK_EX)
        return self

    def __exit__(self, *exc):
        if self._fd is not None:
            fcntl.flock(self._fd, fcntl.LOCK_UN)
            os.close(self._fd)
            self._fd = None
        self._tlock.release()
        return False


class _Replay:
    """In-memory state rebuilt by replaying the journal."""

    def __init__(self):
        self.studies: dict[int, dict] = {}
        self.name_to_id: dict[str, int] = {}
        self.trials: dict[int, FrozenTrial] = {}
        self.study_trials: dict[int, list[int]] = {}
        self.trial_study: dict[int, int] = {}
        self.heartbeats: dict[int, float] = {}
        self.revisions: dict[int, int] = {}  # study_id -> trial-mutation count
        self.next_study_id = 0
        self.next_trial_id = 0

    def _bump(self, trial_id: int) -> None:
        sid = self.trial_study.get(trial_id)
        if sid is not None:
            self.revisions[sid] = self.revisions.get(sid, 0) + 1

    def apply(self, op: dict) -> None:
        kind = op["op"]
        if kind == _CREATE_STUDY:
            sid = op["study_id"]
            self.studies[sid] = {
                "name": op["name"],
                "directions": [StudyDirection(d) for d in op["directions"]],
                "user_attrs": {},
                "system_attrs": {},
            }
            self.name_to_id[op["name"]] = sid
            self.study_trials[sid] = []
            self.next_study_id = max(self.next_study_id, sid + 1)
        elif kind == _DELETE_STUDY:
            sid = op["study_id"]
            if sid in self.studies:
                self.name_to_id.pop(self.studies[sid]["name"], None)
                for tid in self.study_trials.pop(sid, []):
                    self.trials.pop(tid, None)
                    self.trial_study.pop(tid, None)
                self.revisions.pop(sid, None)
                del self.studies[sid]
        elif kind == _CREATE_TRIAL:
            tid = op["trial_id"]
            sid = op["study_id"]
            if sid not in self.studies:
                return
            number = len(self.study_trials[sid])
            t = FrozenTrial(
                number=number,
                state=TrialState(op["state"]),
                values=op.get("values"),
                trial_id=tid,
                datetime_start=(
                    _dt(op["ts"]) if "ts" in op and op["state"] != int(TrialState.WAITING) else None
                ),
            )
            t.system_attrs["journal:study_id"] = sid
            for name, (val, dist_json) in op.get("params", {}).items():
                dist = json_to_distribution(dist_json)
                t.params[name] = dist.to_external_repr(val)
                t.distributions[name] = dist
            for k, v in op.get("user_attrs", {}).items():
                t.user_attrs[k] = v
            for k, v in op.get("system_attrs", {}).items():
                t.system_attrs[k] = v
            self.trials[tid] = t
            self.study_trials[sid].append(tid)
            self.trial_study[tid] = sid
            self.next_trial_id = max(self.next_trial_id, tid + 1)
            self._bump(tid)
        elif kind == _SET_PARAM:
            t = self.trials.get(op["trial_id"])
            if t is None:
                return
            dist = json_to_distribution(op["dist"])
            t.params[op["name"]] = dist.to_external_repr(op["value"])
            t.distributions[op["name"]] = dist
            self._bump(op["trial_id"])
        elif kind == _SET_STATE:
            t = self.trials.get(op["trial_id"])
            if t is None:
                return
            new_state = TrialState(op["state"])
            if new_state == TrialState.RUNNING and t.state != TrialState.WAITING:
                return  # lost claim; replay keeps first claimant
            t.state = new_state
            if op.get("values") is not None:
                t.values = op["values"]
            if "ts" in op:
                if new_state == TrialState.RUNNING:
                    t.datetime_start = _dt(op["ts"])
                elif new_state.is_finished():
                    t.datetime_complete = _dt(op["ts"])
            self._bump(op["trial_id"])
        elif kind == _SET_IV:
            t = self.trials.get(op["trial_id"])
            if t is not None:
                t.intermediate_values[int(op["step"])] = op["value"]
                self._bump(op["trial_id"])
        elif kind == _SET_TATTR:
            t = self.trials.get(op["trial_id"])
            if t is not None:
                (t.system_attrs if op["sys"] else t.user_attrs)[op["key"]] = op["value"]
                self._bump(op["trial_id"])
        elif kind == _SET_SATTR:
            s = self.studies.get(op["study_id"])
            if s is not None:
                s["system_attrs" if op["sys"] else "user_attrs"][op["key"]] = op["value"]
        elif kind == _HEARTBEAT:
            self.heartbeats[op["trial_id"]] = op["t"]


class JournalStorage(BaseStorage):
    def __init__(self, path: str, fsync: bool = True):
        if path.startswith("journal://"):
            path = path[len("journal://"):]
        if "?" in path:
            path, _, query = path.partition("?")
            for kv in query.split("&"):
                k, _, v = kv.partition("=")
                if k == "fsync":
                    fsync = v not in ("0", "false", "no")
        self._path = path
        self._fsync = fsync
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._lock = _FileLock(path)
        self._worker_id = uuid.uuid4().hex[:12]
        self._offset = 0
        self._replay = _Replay()
        self._mem_lock = threading.RLock()
        with self._lock:
            if not os.path.exists(path):
                with open(path, "a"):
                    pass
        self._sync()

    # -- journal io -------------------------------------------------------------

    def _sync_locked(self) -> None:
        """Replay any journal suffix we have not seen (caller holds file lock)."""
        with open(self._path, "rb") as f:
            f.seek(self._offset)
            data = f.read()
        if not data:
            return
        # only consume up to the final newline (a torn last line is in-flight)
        end = data.rfind(b"\n")
        if end < 0:
            return
        chunk = data[: end + 1]
        for line in chunk.splitlines():
            if not line.strip():
                continue
            try:
                op = json.loads(line)
            except json.JSONDecodeError:
                # a crash can only tear the FINAL line (appends are atomic
                # under the lock), so interior garbage means external damage
                warnings.warn(
                    f"journal {self._path}: skipping corrupt line "
                    f"({line[:80]!r}...)", RuntimeWarning, stacklevel=4,
                )
                _telemetry.inc("journal.corrupt_lines")
                continue
            self._replay.apply(op)
        self._offset += len(chunk)

    def _sync(self) -> None:
        with self._mem_lock, self._lock:
            self._sync_locked()

    def _repair_torn_tail_locked(self) -> None:
        """Truncate a torn final line before appending (caller holds BOTH
        locks and has just run ``_sync_locked``, so ``_offset`` sits at the
        last complete line).  Under the exclusive flock nobody can be
        mid-append, so any bytes past the final newline are a dead writer's
        half-finished line — appending after them would fuse two records."""
        try:
            size = os.path.getsize(self._path)
        except OSError:
            return
        if size > self._offset:
            warnings.warn(
                f"journal {self._path}: truncating {size - self._offset} "
                "bytes of torn final line left by a crashed writer",
                RuntimeWarning, stacklevel=4,
            )
            _telemetry.inc("journal.torn_truncates")
            os.truncate(self._path, self._offset)

    def _write_locked(self, line: str) -> None:
        with open(self._path, "a") as f:
            f.write(line)
            f.flush()
            if self._fsync:
                os.fsync(f.fileno())

    def _append(self, op: dict) -> None:
        line = json.dumps(op, separators=(",", ":")) + "\n"
        with self._mem_lock, self._lock:
            self._sync_locked()
            self._repair_torn_tail_locked()
            self._write_locked(line)
            self._replay.apply(op)
            self._offset += len(line.encode())

    def _append_with(self, make_op) -> Any:
        """Append an op computed under the lock (for atomic id/number assignment)."""
        with self._mem_lock, self._lock:
            self._sync_locked()
            self._repair_torn_tail_locked()
            op, result = make_op(self._replay)
            line = json.dumps(op, separators=(",", ":")) + "\n"
            self._write_locked(line)
            self._replay.apply(op)
            self._offset += len(line.encode())
            return result

    # -- study --------------------------------------------------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        def op(rep: _Replay):
            if study_name in rep.name_to_id:
                raise DuplicatedStudyError(study_name)
            sid = rep.next_study_id
            return (
                {"op": _CREATE_STUDY, "study_id": sid, "name": study_name,
                 "directions": [int(d) for d in directions]},
                sid,
            )

        return self._append_with(op)

    def delete_study(self, study_id: int) -> None:
        self._append({"op": _DELETE_STUDY, "study_id": study_id})
        self._drop_intermediate_store(study_id)
        self._drop_event_log(study_id)

    def get_study_id_from_name(self, study_name: str) -> int:
        self._sync()
        with self._mem_lock:
            if study_name not in self._replay.name_to_id:
                raise StudyNotFoundError(study_name)
            return self._replay.name_to_id[study_name]

    def get_study_name_from_id(self, study_id: int) -> str:
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            return self._replay.studies[study_id]["name"]

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            return list(self._replay.studies[study_id]["directions"])

    def get_all_studies(self) -> list[StudySummary]:
        self._sync()
        with self._mem_lock:
            return [
                StudySummary(
                    sid, s["name"], list(s["directions"]),
                    len(self._replay.study_trials.get(sid, [])),
                    dict(s["user_attrs"]), dict(s["system_attrs"]),
                )
                for sid, s in self._replay.studies.items()
            ]

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        self._append({"op": _SET_SATTR, "study_id": study_id, "sys": 0, "key": key, "value": value})

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        self._append({"op": _SET_SATTR, "study_id": study_id, "sys": 1, "key": key, "value": value})

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            return dict(self._replay.studies[study_id]["user_attrs"])

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            return dict(self._replay.studies[study_id]["system_attrs"])

    def _check_study(self, study_id: int) -> None:
        if study_id not in self._replay.studies:
            raise StudyNotFoundError(study_id)

    # -- trial ----------------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        def op(rep: _Replay):
            if study_id not in rep.studies:
                raise StudyNotFoundError(study_id)
            tid = rep.next_trial_id
            body: dict[str, Any] = {
                "op": _CREATE_TRIAL, "trial_id": tid, "study_id": study_id,
                "state": int(template_trial.state if template_trial else TrialState.RUNNING),
                "ts": time.time(),
            }
            if template_trial is not None:
                if template_trial.values:
                    body["values"] = template_trial.values
                body["params"] = {
                    name: (dist.to_internal_repr(template_trial.params[name]),
                           distribution_to_json(dist))
                    for name, dist in template_trial.distributions.items()
                }
                body["user_attrs"] = template_trial.user_attrs
                body["system_attrs"] = template_trial.system_attrs
            return body, tid

        tid = self._append_with(op)
        with self._mem_lock:
            number = self._replay.trials[tid].number
        self._record_event(study_id, _telemetry.EV_CREATED, number)
        return tid

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution: BaseDistribution,
    ) -> None:
        with self._mem_lock:
            t = self._trial(trial_id)
            if t.state.is_finished():
                raise RuntimeError(f"trial {trial_id} is already finished")
            if param_name in t.distributions:
                check_distribution_compatibility(t.distributions[param_name], distribution)
        self._append({
            "op": _SET_PARAM, "trial_id": trial_id, "name": param_name,
            "value": float(param_value_internal), "dist": distribution_to_json(distribution),
        })

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        def op(rep: _Replay):
            t = rep.trials.get(trial_id)
            if t is None:
                raise TrialNotFoundError(trial_id)
            ok = not (state == TrialState.RUNNING and t.state != TrialState.WAITING)
            body = {
                "op": _SET_STATE, "trial_id": trial_id, "state": int(state),
                "values": [float(v) for v in values] if values is not None else None,
                "by": self._worker_id,
                "ts": time.time(),
            }
            return body, ok

        ok = self._append_with(op)
        if ok:
            with self._mem_lock:
                sid = self._replay.trial_study.get(trial_id)
                number = self._replay.trials[trial_id].number
            if sid is not None:
                self._record_state_event(sid, state, number)
        return ok

    def set_trial_intermediate_value(self, trial_id: int, step: int, intermediate_value: float) -> None:
        with self._mem_lock:
            t = self._trial(trial_id)
            if t.state.is_finished():
                raise RuntimeError(f"trial {trial_id} is already finished")
        self._append({
            "op": _SET_IV, "trial_id": trial_id, "step": int(step),
            "value": float(intermediate_value),
        })
        with self._mem_lock:
            sid = self._replay.trial_study.get(trial_id)
            number = self._replay.trials[trial_id].number
        self._note_iv_dirty(trial_id, sid)  # after append: stores lock store-first
        if sid is not None:
            self._record_event(sid, _telemetry.EV_REPORTED, number, step=int(step))

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._append({"op": _SET_TATTR, "trial_id": trial_id, "sys": 0, "key": key, "value": value})

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        self._append({"op": _SET_TATTR, "trial_id": trial_id, "sys": 1, "key": key, "value": value})

    def _trial(self, trial_id: int) -> FrozenTrial:
        if trial_id not in self._replay.trials:
            raise TrialNotFoundError(trial_id)
        return self._replay.trials[trial_id]

    def get_trial(self, trial_id: int) -> FrozenTrial:
        self._sync()
        with self._mem_lock:
            return self._trial(trial_id).copy()

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            tids = self._replay.study_trials[study_id]
            if since is not None:
                tids = tids[since:]  # study_trials is ordered by number
            ts = [self._replay.trials[tid] for tid in tids]
            if states is not None:
                ts = [t for t in ts if t.state in states]
            return [t.copy() for t in ts] if deepcopy else ts

    def get_trials_revision(self, study_id: int) -> int:
        # the journal must be replayed to learn the revision, so this does not
        # avoid I/O like the RDB/in-memory counters do — but it keeps the
        # revision *semantics* uniform across backends (every trial mutation,
        # including in-place RUNNING updates, bumps it exactly once)
        self._sync()
        with self._mem_lock:
            self._check_study(study_id)
            return self._replay.revisions.get(study_id, 0)

    # -- heartbeat --------------------------------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        self._append({"op": _HEARTBEAT, "trial_id": trial_id, "t": time.time()})

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        self._sync()
        now = time.time()
        with self._mem_lock:
            out = []
            for tid in self._replay.study_trials.get(study_id, []):
                t = self._replay.trials[tid]
                hb = self._replay.heartbeats.get(tid)
                if t.state == TrialState.RUNNING and hb is not None and now - hb > grace_seconds:
                    out.append(tid)
            return out
