"""Deterministic chaos harness for the storage service.

Fault injection lives *inside* the server reactor — no iptables, no proxies,
no timing races.  A :class:`FaultInjector` is handed to
:class:`~repro.core.storage.server.StorageServer` and consulted at two
points of the event loop:

* ``on_accept()`` — just after ``accept()``; ``True`` drops the fresh
  connection before the client sees a single byte.
* ``on_frame()`` — after a request frame is fully decoded (and past auth);
  the verdict is applied to the *response*:

  - ``"drop_conn"``   — tear the connection down without answering (the
    request was **not** executed: the classic mid-flight cut),
  - ``"blackhole"``   — execute the request but discard the response (the
    nastiest case: a ``tell`` that *happened* but looks lost — exactly what
    the op-id dedup window exists for),
  - ``("delay", s)``  — answer after ``s`` seconds (reordering/timeout),
  - ``None``          — no fault.

Faults are armed by count (``drop_next_frames(2)``) or probabilistically
(``random_drop(0.01)``) from a seeded RNG, so a chaos run is exactly
reproducible.  :class:`ChaosCluster` bundles the rest of the lab: a sharded
server pool with optional replicas, one seeded injector per shard, and
kill / promote / restart controls for failover drills.
"""

from __future__ import annotations

import random
import threading
import time
from typing import Any

from .base import BaseStorage
from .cached import CachedStorage
from .client import RemoteStorage
from .cluster import ShardedStorage
from .inmemory import InMemoryStorage
from .server import StorageServer

__all__ = ["FaultInjector", "ChaosCluster"]


class FaultInjector:
    """Seeded, thread-safe fault schedule for one server's reactor.

    Counted rules fire once per matching event and then disarm; the
    probabilistic rule (``random_drop``) stays armed until ``clear()``.
    Counted rules take precedence over the probabilistic one, and at most
    one fault fires per frame, so schedules compose predictably.
    """

    def __init__(self, seed: "int | None" = None):
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._drop_connects = 0
        self._drop_frames = 0
        self._blackholes = 0
        self._delays = 0
        self._delay_seconds = 0.0
        self._drop_rate = 0.0
        self.stats = {
            "dropped_connects": 0,
            "dropped_frames": 0,
            "blackholed_frames": 0,
            "delayed_frames": 0,
        }

    # -- arming ------------------------------------------------------------

    def drop_connects(self, n: int = 1) -> "FaultInjector":
        """Refuse the next ``n`` fresh connections at accept time."""
        with self._lock:
            self._drop_connects += int(n)
        return self

    def drop_next_frames(self, n: int = 1) -> "FaultInjector":
        """Cut the connection on the next ``n`` frames *before* executing
        them (request lost in flight)."""
        with self._lock:
            self._drop_frames += int(n)
        return self

    def blackhole_next(self, n: int = 1) -> "FaultInjector":
        """Execute the next ``n`` requests but swallow their responses
        (effect happened, client sees a dead connection)."""
        with self._lock:
            self._blackholes += int(n)
        return self

    def delay_next(self, n: int = 1, seconds: float = 0.2) -> "FaultInjector":
        """Hold the next ``n`` responses for ``seconds``."""
        with self._lock:
            self._delays += int(n)
            self._delay_seconds = float(seconds)
        return self

    def random_drop(self, rate: float) -> "FaultInjector":
        """Drop each frame (pre-execution) with probability ``rate``, from
        the injector's seeded RNG."""
        with self._lock:
            self._drop_rate = float(rate)
        return self

    def clear(self) -> None:
        """Disarm everything (counted and probabilistic)."""
        with self._lock:
            self._drop_connects = 0
            self._drop_frames = 0
            self._blackholes = 0
            self._delays = 0
            self._drop_rate = 0.0

    @property
    def armed(self) -> bool:
        with self._lock:
            return bool(
                self._drop_connects or self._drop_frames or self._blackholes
                or self._delays or self._drop_rate
            )

    # -- reactor hooks -----------------------------------------------------

    def on_accept(self) -> bool:
        with self._lock:
            if self._drop_connects > 0:
                self._drop_connects -= 1
                self.stats["dropped_connects"] += 1
                return True
        return False

    def on_frame(self) -> Any:
        with self._lock:
            if self._drop_frames > 0:
                self._drop_frames -= 1
                self.stats["dropped_frames"] += 1
                return "drop_conn"
            if self._blackholes > 0:
                self._blackholes -= 1
                self.stats["blackholed_frames"] += 1
                return "blackhole"
            if self._delays > 0:
                self._delays -= 1
                self.stats["delayed_frames"] += 1
                return ("delay", self._delay_seconds)
            if self._drop_rate > 0.0 and self._rng.random() < self._drop_rate:
                self.stats["dropped_frames"] += 1
                return "drop_conn"
        return None


class ChaosCluster:
    """A self-contained sharded storage lab: ``n_shards`` primaries (each
    with a seeded :class:`FaultInjector`), optional journal-replicated
    replicas, and failover controls.

    Args:
        n_shards: number of independent shards (1 = a single server).
        replicated: shard indices that also get a tailing replica.
        sync_replication: hold client write responses until the replica
            acks (the zero-lost-tells mode; see server.py).
        seed: base RNG seed; shard ``i``'s injector uses ``seed + i``.
        backend_factory: storage constructor per node (default
            :class:`InMemoryStorage`).
        reclaim_grace / reclaim_requeue: enable the server-side
            stale-RUNNING sweeper on every primary.
    """

    def __init__(
        self,
        n_shards: int = 2,
        replicated: "tuple[int, ...] | list[int]" = (),
        sync_replication: bool = True,
        seed: int = 0,
        auth_token: "str | None" = None,
        backend_factory: Any = InMemoryStorage,
        reclaim_grace: "float | None" = None,
        reclaim_requeue: bool = False,
        reclaim_interval: float = 1.0,
    ):
        self.injectors: list[FaultInjector] = []
        self.primaries: list[StorageServer] = []
        self.replicas: dict[int, StorageServer] = {}
        self._auth_token = auth_token
        replicated = set(replicated)
        for i in range(n_shards):
            inj = FaultInjector(seed=seed + i)
            primary = StorageServer(
                backend_factory(),
                auth_token=auth_token,
                journal=i in replicated,
                sync_replication=sync_replication and i in replicated,
                fault_injector=inj,
                reclaim_grace=reclaim_grace,
                reclaim_requeue=reclaim_requeue,
                reclaim_interval=reclaim_interval,
            )
            primary.start()
            self.injectors.append(inj)
            self.primaries.append(primary)
        for i in replicated:
            replica = StorageServer(
                backend_factory(),
                replicate_from=self.primaries[i].url,
                auth_token=auth_token,
            )
            replica.start()
            self.replicas[i] = replica

    # -- addressing --------------------------------------------------------

    def shard_netloc(self, i: int) -> str:
        """``host:port[+replica_host:port]`` — the failover candidate list
        of shard ``i`` (primary first, like a worker would be configured)."""
        loc = self.primaries[i].url.split("://", 1)[1]
        replica = self.replicas.get(i)
        if replica is not None:
            loc += "+" + replica.url.split("://", 1)[1]
        return loc

    @property
    def url(self) -> str:
        """The whole cluster as one ``remote://`` URL (shards comma-joined,
        failover candidates ``+``-joined)."""
        netlocs = ",".join(self.shard_netloc(i) for i in range(len(self.primaries)))
        token = f"{self._auth_token}@" if self._auth_token else ""
        return f"remote://{token}{netlocs}"

    def storage(self, cache: bool = False, **client_kwargs: Any) -> BaseStorage:
        """A client for the cluster: :class:`ShardedStorage` when there are
        multiple shards, a plain :class:`RemoteStorage` for one."""
        if len(self.primaries) > 1:
            st: BaseStorage = ShardedStorage(self.url, **client_kwargs)
        else:
            st = RemoteStorage(self.url, **client_kwargs)
        return CachedStorage(st) if cache else st

    # -- failure controls --------------------------------------------------

    def kill_primary(self, i: int) -> None:
        """Hard-kill shard ``i``'s primary: no flush, no goodbye — in-flight
        responses and buffered outbytes are gone."""
        self.primaries[i].kill()

    def promote_replica(self, i: int) -> StorageServer:
        """Promote shard ``i``'s replica to primary (next epoch).  Clients
        holding the shard's candidate list fail over on their next call."""
        replica = self.replicas[i]
        replica.promote()
        return replica

    def restart_primary(self, i: int) -> StorageServer:
        """Restart a killed primary on its original port, state intact (a
        crash-restart from snapshot).  If its replica was promoted meanwhile
        the old primary comes back *fenced*: its stale epoch makes every
        cluster-aware client refuse it."""
        return self.primaries[i].restart()

    def wait_replicated(self, i: int, timeout: float = 10.0) -> None:
        """Block until shard ``i``'s replica has applied every journaled op
        the primary has accepted (a write barrier for tests)."""
        primary, replica = self.primaries[i], self.replicas[i]
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if replica.replication_state()["applied_seq"] >= primary.replication_state()["seq"]:
                return
            time.sleep(0.01)
        raise TimeoutError(
            f"shard {i} replica lag: primary seq "
            f"{primary.replication_state()['seq']}, replica applied "
            f"{replica.replication_state()['applied_seq']}"
        )

    def journal_seq(self, i: int) -> int:
        journal = self.primaries[i].journal
        return journal.end_seq if journal is not None else 0

    def stop(self) -> None:
        """Stop every node (kill-safe: already-killed primaries are fine)."""
        for replica in self.replicas.values():
            try:
                replica.stop()
            except Exception:
                pass
        for primary in self.primaries:
            try:
                primary.stop()
            except Exception:
                pass

    def __enter__(self) -> "ChaosCluster":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()
