"""``ShardedStorage`` — client-side shard router over a pool of storage nodes.

A ``remote://`` URL whose host list is comma-separated fans the study space
out over several independent :class:`~repro.core.storage.server.StorageServer`
processes::

    remote://token@a:7000,b:7000,c:7000          # three shards
    remote://a:7000+a2:7001,b:7000               # shard 0 has a failover pair

Commas separate **shards** (different data); ``+`` separates **failover
candidates** of one shard (same data: primary, then replicas — handled
entirely inside :class:`~repro.core.storage.client.RemoteStorage`).

Design points:

* **Study placement** — a study lives wholly on one shard, chosen by
  consistent-hashing its *name* (SHA-1 ring, 64 virtual nodes per shard).
  Placement is a pure function of (name, shard count), so every worker
  process routes identically with no coordination and no metadata service.
* **ID virtualization** — shard-local ids are interleaved into a global id
  space: ``gid = local * n_shards + shard``.  Decoding is arithmetic
  (``shard = gid % n``, ``local = gid // n``), so routing a trial id never
  needs a directory lookup.  Trial *numbers* are untouched — they are dense
  per study and a study never spans shards.
* **Full contract** — the router implements the complete
  :class:`BaseStorage` surface including the columnar block RPCs (only
  ``iv_block.trial_ids`` needs rewriting; observation blocks and trial-event
  traces are keyed by per-study numbers) and ``call_batch`` (calls are
  grouped per shard, flushed as one frame each, results re-assembled in
  request order).
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Any, Iterable

from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary
from .client import RemoteStorage

__all__ = ["ShardedStorage", "HashRing", "parse_sharded_url"]


def parse_sharded_url(url: str) -> list[str]:
    """Split a comma-sharded ``remote://`` URL into one URL per shard, each
    keeping the scheme and (token) userinfo: ``remote://t@a:1,b:2`` ->
    ``["remote://t@a:1", "remote://t@b:2"]``."""
    for scheme in ("remote+tls://", "remote://"):
        if url.startswith(scheme):
            rest = url[len(scheme):].rstrip("/")
            break
    else:
        raise ValueError(f"not a remote:// URL: {url!r}")
    userinfo = ""
    if "@" in rest:
        userinfo, _, rest = rest.rpartition("@")
        userinfo += "@"
    shards = [part for part in rest.split(",") if part]
    if not shards:
        raise ValueError(f"sharded remote:// URL has no shards: {url!r}")
    return [f"{scheme}{userinfo}{part}" for part in shards]


class HashRing:
    """Consistent-hash ring: SHA-1 points, ``vnodes`` virtual nodes per
    shard.  Stable across processes and Python runs (no randomized hashing),
    so every worker computes the same placement."""

    def __init__(self, n_shards: int, vnodes: int = 64):
        points: list[tuple[int, int]] = []
        for shard in range(n_shards):
            for v in range(vnodes):
                points.append((self._hash(f"shard:{shard}:vnode:{v}"), shard))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._owners = [s for _, s in points]

    @staticmethod
    def _hash(key: str) -> int:
        return int.from_bytes(hashlib.sha1(key.encode()).digest()[:8], "big")

    def lookup(self, key: str) -> int:
        i = bisect.bisect(self._hashes, self._hash(key)) % len(self._hashes)
        return self._owners[i]


# batched methods whose first param is a trial id (routed arithmetically)
_TID_FIRST = frozenset(
    {
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "record_heartbeat",
    }
)


class ShardedStorage(BaseStorage):
    """Route :class:`BaseStorage` calls across a pool of storage servers.

    Args:
        url: comma-sharded ``remote://`` URL (see module docstring), or a
            pre-split list of one URL per shard.  Shard order is part of the
            id encoding — every worker must list shards identically.
        **client_kwargs: forwarded to every per-shard
            :class:`RemoteStorage` (``timeout``, ``retries``,
            ``rpc_deadline``, ``auth_token``, ``backoff_seed``, ...).
    """

    def __init__(self, url: "str | list[str]", **client_kwargs: Any):
        urls = parse_sharded_url(url) if isinstance(url, str) else list(url)
        if not urls:
            raise ValueError("ShardedStorage needs at least one shard URL")
        self._shards: list[RemoteStorage] = [
            RemoteStorage(u, **client_kwargs) for u in urls
        ]
        self._n = len(self._shards)
        self._ring = HashRing(self._n)
        self._url = ",".join(s.url for s in self._shards)

    @property
    def url(self) -> str:
        return self._url

    @property
    def n_shards(self) -> int:
        return self._n

    @property
    def shards(self) -> list[RemoteStorage]:
        return list(self._shards)

    @property
    def supports_block_fetch(self) -> bool:
        return all(s.supports_block_fetch for s in self._shards)

    # -- id virtualization ------------------------------------------------------

    def _gid(self, local_id: int, shard: int) -> int:
        return local_id * self._n + shard

    def _split(self, gid: int) -> tuple[int, int]:
        """global id -> (shard index, shard-local id)"""
        gid = int(gid)
        return gid % self._n, gid // self._n

    def shard_of_study(self, study_name: str) -> int:
        return self._ring.lookup(study_name)

    def _globalize_trial(self, t: FrozenTrial, shard: int) -> FrozenTrial:
        t._trial_id = self._gid(t._trial_id, shard)
        return t

    # -- study -----------------------------------------------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        shard = self._ring.lookup(study_name)
        return self._gid(self._shards[shard].create_new_study(directions, study_name), shard)

    def delete_study(self, study_id: int) -> None:
        shard, sid = self._split(study_id)
        self._shards[shard].delete_study(sid)

    def get_study_id_from_name(self, study_name: str) -> int:
        shard = self._ring.lookup(study_name)
        return self._gid(self._shards[shard].get_study_id_from_name(study_name), shard)

    def get_study_name_from_id(self, study_id: int) -> str:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_study_name_from_id(sid)

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_study_directions(sid)

    def get_all_studies(self) -> list[StudySummary]:
        out: list[StudySummary] = []
        for shard, client in enumerate(self._shards):
            for summary in client.get_all_studies():
                summary.study_id = self._gid(summary.study_id, shard)
                out.append(summary)
        return out

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        shard, sid = self._split(study_id)
        self._shards[shard].set_study_user_attr(sid, key, value)

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        shard, sid = self._split(study_id)
        self._shards[shard].set_study_system_attr(sid, key, value)

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_study_user_attrs(sid)

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_study_system_attrs(sid)

    # -- trial -----------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        shard, sid = self._split(study_id)
        return self._gid(self._shards[shard].create_new_trial(sid, template_trial), shard)

    def create_new_trials(
        self, study_id: int, n: int, template_trial: FrozenTrial | None = None
    ) -> list[int]:
        shard, sid = self._split(study_id)
        return [
            self._gid(tid, shard)
            for tid in self._shards[shard].create_new_trials(sid, n, template_trial)
        ]

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float, distribution
    ) -> None:
        shard, tid = self._split(trial_id)
        self._shards[shard].set_trial_param(tid, param_name, param_value_internal, distribution)

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        shard, tid = self._split(trial_id)
        return self._shards[shard].set_trial_state_values(tid, state, values)

    def set_trial_intermediate_value(
        self, trial_id: int, step: int, intermediate_value: float
    ) -> None:
        shard, tid = self._split(trial_id)
        self._shards[shard].set_trial_intermediate_value(tid, step, intermediate_value)

    def report_and_prune(
        self, study_id: int, trial_id: int, step: int, value: float,
        pruner_spec: dict, direction,
    ) -> bool:
        shard, sid = self._split(study_id)
        _, tid = self._split(trial_id)
        return self._shards[shard].report_and_prune(sid, tid, step, value, pruner_spec, direction)

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        shard, tid = self._split(trial_id)
        self._shards[shard].set_trial_user_attr(tid, key, value)

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        shard, tid = self._split(trial_id)
        self._shards[shard].set_trial_system_attr(tid, key, value)

    def get_trial(self, trial_id: int) -> FrozenTrial:
        shard, tid = self._split(trial_id)
        return self._globalize_trial(self._shards[shard].get_trial(tid), shard)

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        shard, sid = self._split(study_id)
        trials = self._shards[shard].get_all_trials(sid, deepcopy, states, since)
        return [self._globalize_trial(t, shard) for t in trials]

    def get_n_trials(self, study_id: int, states: tuple[TrialState, ...] | None = None) -> int:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_n_trials(sid, states)

    def get_trial_id_from_study_and_number(self, study_id: int, number: int) -> int:
        shard, sid = self._split(study_id)
        return self._gid(
            self._shards[shard].get_trial_id_from_study_and_number(sid, number), shard
        )

    def get_trials_revision(self, study_id: int) -> int:
        shard, sid = self._split(study_id)
        return self._shards[shard].get_trials_revision(sid)

    # -- columnar block fetch -----------------------------------------------------

    def get_observation_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        # keyed by per-study trial numbers: nothing to rewrite
        shard, sid = self._split(study_id)
        return self._shards[shard].get_observation_block(sid, since)

    def get_iv_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        shard, sid = self._split(study_id)
        block = self._shards[shard].get_iv_block(sid, since)
        ids = block.get("trial_ids")
        if ids is not None:
            if isinstance(ids, list):
                block["trial_ids"] = [self._gid(t, shard) for t in ids]
            else:  # numpy column straight off the v2 wire
                block["trial_ids"] = ids * self._n + shard
        return block

    # -- heartbeat ---------------------------------------------------------------

    def record_heartbeat(self, trial_id: int) -> None:
        shard, tid = self._split(trial_id)
        self._shards[shard].record_heartbeat(tid)

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        shard, sid = self._split(study_id)
        return [
            self._gid(t, shard)
            for t in self._shards[shard].get_stale_trial_ids(sid, grace_seconds)
        ]

    def fail_stale_trials(self, study_id: int, grace_seconds: float) -> list[int]:
        shard, sid = self._split(study_id)
        return [
            self._gid(t, shard)
            for t in self._shards[shard].fail_stale_trials(sid, grace_seconds)
        ]

    def reclaim_stale_trials(
        self, study_id: int, grace_seconds: float, requeue: bool = False
    ) -> list[int]:
        shard, sid = self._split(study_id)
        return [
            self._gid(t, shard)
            for t in self._shards[shard].reclaim_stale_trials(sid, grace_seconds, requeue)
        ]

    # -- telemetry ---------------------------------------------------------------

    def get_trial_events(self, study_id: int, since: int = 0) -> dict[str, Any]:
        # keyed by per-study trial numbers: nothing to rewrite
        shard, sid = self._split(study_id)
        return self._shards[shard].get_trial_events(sid, since)

    def get_server_metrics(self) -> dict[str, Any]:
        return {"shards": [s.get_server_metrics() for s in self._shards]}

    # -- batching ----------------------------------------------------------------

    def call_batch(self, calls: list[tuple[str, tuple]]) -> list[Any]:
        """Per-shard request batching: calls are routed by their embedded
        study/trial id, sent as ONE frame per touched shard, and the results
        re-assembled in request order (ids in results re-globalized)."""
        routed: dict[int, list[tuple[int, str, tuple]]] = {}
        for pos, (method, params) in enumerate(calls):
            shard, local = self._translate_call(method, params)
            routed.setdefault(shard, []).append((pos, method, local))
        results: list[Any] = [None] * len(calls)
        for shard, entries in routed.items():
            batch = [(m, p) for _, m, p in entries]
            out = self._shards[shard].call_batch(batch)
            for (pos, method, _), res in zip(entries, out):
                results[pos] = self._translate_result(method, res, shard)
        return results

    def _translate_call(self, method: str, params: tuple) -> tuple[int, tuple]:
        params = tuple(params)
        if method in _TID_FIRST:
            shard, tid = self._split(params[0])
            return shard, (tid,) + params[1:]
        if method == "report_and_prune":
            shard, sid = self._split(params[0])
            _, tid = self._split(params[1])
            return shard, (sid, tid) + params[2:]
        if method in (
            "create_new_trial", "create_new_trials", "get_all_trials", "get_n_trials",
            "get_trial_id_from_study_and_number", "get_trials_revision",
            "get_observation_block", "get_iv_block", "get_trial_events",
            "get_stale_trial_ids", "fail_stale_trials", "reclaim_stale_trials",
            "delete_study", "get_study_name_from_id", "get_study_directions",
            "set_study_user_attr", "set_study_system_attr",
            "get_study_user_attrs", "get_study_system_attrs",
        ):
            shard, sid = self._split(params[0])
            return shard, (sid,) + params[1:]
        raise ValueError(f"cannot route batched method {method!r} across shards")

    def _translate_result(self, method: str, result: Any, shard: int) -> Any:
        if method in ("create_new_trial", "get_trial_id_from_study_and_number"):
            return self._gid(result, shard)
        if method in ("create_new_trials", "get_stale_trial_ids",
                      "fail_stale_trials", "reclaim_stale_trials"):
            return [self._gid(t, shard) for t in result]
        if method == "get_trial":
            return self._globalize_trial(result, shard)
        if method == "get_all_trials":
            return [self._globalize_trial(t, shard) for t in result]
        return result

    # -- misc ---------------------------------------------------------------------

    def close(self) -> None:
        for s in self._shards:
            s.close()
