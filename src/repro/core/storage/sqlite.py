"""SQLite-backed storage — the paper's ``sqlite:///...`` distributed backend.

Multiple worker *processes* (possibly on different nodes over a shared
filesystem for small fleets, or one DB host) coordinate through this backend
exactly as in paper Fig. 7: run the same script N times with the same storage
URL and study name.

Implementation notes:

* WAL journal mode + ``busy_timeout`` + IMMEDIATE transactions for writers.
* Trial ``number`` assignment happens inside the INSERT transaction, so
  numbers are dense and unique under concurrency.
* All values stored as floats/JSON (internal reprs; see distributions.py).
* Retries with exponential backoff on ``database is locked``.
"""

from __future__ import annotations

import datetime
import json
import os
import sqlite3
import threading
import time
from typing import Any, Iterable

from .. import telemetry as _telemetry
from ..distributions import (
    BaseDistribution,
    check_distribution_compatibility,
    distribution_to_json,
    json_to_distribution,
)
from ..exceptions import (
    DuplicatedStudyError,
    StorageInternalError,
    StudyNotFoundError,
    TrialNotFoundError,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary

__all__ = ["SQLiteStorage"]

_SCHEMA = """
CREATE TABLE IF NOT EXISTS studies (
    study_id   INTEGER PRIMARY KEY AUTOINCREMENT,
    study_name TEXT UNIQUE NOT NULL,
    directions TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS study_attrs (
    study_id INTEGER NOT NULL,
    is_system INTEGER NOT NULL,
    key TEXT NOT NULL,
    value_json TEXT,
    PRIMARY KEY (study_id, is_system, key)
);
CREATE TABLE IF NOT EXISTS trials (
    trial_id  INTEGER PRIMARY KEY AUTOINCREMENT,
    study_id  INTEGER NOT NULL,
    number    INTEGER NOT NULL,
    state     INTEGER NOT NULL,
    values_json TEXT,
    datetime_start TEXT,
    datetime_complete TEXT,
    UNIQUE (study_id, number)
);
CREATE INDEX IF NOT EXISTS idx_trials_study ON trials (study_id);
CREATE TABLE IF NOT EXISTS trial_params (
    trial_id INTEGER NOT NULL,
    param_name TEXT NOT NULL,
    param_value REAL NOT NULL,
    distribution_json TEXT NOT NULL,
    PRIMARY KEY (trial_id, param_name)
);
CREATE TABLE IF NOT EXISTS trial_intermediate_values (
    trial_id INTEGER NOT NULL,
    step INTEGER NOT NULL,
    value REAL,
    PRIMARY KEY (trial_id, step)
);
CREATE TABLE IF NOT EXISTS trial_attrs (
    trial_id INTEGER NOT NULL,
    is_system INTEGER NOT NULL,
    key TEXT NOT NULL,
    value_json TEXT,
    PRIMARY KEY (trial_id, is_system, key)
);
CREATE TABLE IF NOT EXISTS trial_heartbeats (
    trial_id INTEGER PRIMARY KEY,
    heartbeat_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS study_revisions (
    study_id INTEGER PRIMARY KEY,
    revision INTEGER NOT NULL
);
"""

_MAX_RETRIES = 16


def _retry(fn):
    def wrapper(*args, **kwargs):
        delay = 0.005
        for attempt in range(_MAX_RETRIES):
            try:
                return fn(*args, **kwargs)
            except sqlite3.OperationalError as e:
                if "locked" not in str(e) and "busy" not in str(e):
                    raise
                if attempt == _MAX_RETRIES - 1:
                    raise StorageInternalError(f"sqlite stayed locked: {e}") from e
                time.sleep(delay)
                delay = min(delay * 2, 0.5)

    wrapper.__name__ = fn.__name__
    return wrapper


class SQLiteStorage(BaseStorage):
    """Storage over a sqlite database file.

    Accepts either a filesystem path or a ``sqlite:///path`` URL.
    """

    def __init__(self, url_or_path: str):
        path = url_or_path
        if path.startswith("sqlite:///"):
            path = path[len("sqlite:///"):]
        self._path = path or ":memory:"
        if self._path != ":memory:":
            d = os.path.dirname(os.path.abspath(self._path))
            os.makedirs(d, exist_ok=True)
        self._local = threading.local()
        self._conn().executescript(_SCHEMA)

    # one connection per thread; sqlite connections are not thread-safe
    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self._path, timeout=30.0, isolation_level=None)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.execute("PRAGMA busy_timeout=30000")
            self._local.conn = conn
        return conn

    class _Tx:
        def __init__(self, conn: sqlite3.Connection, immediate: bool):
            self.conn = conn
            self.immediate = immediate

        def __enter__(self) -> sqlite3.Cursor:
            self.cur = self.conn.cursor()
            self.cur.execute("BEGIN IMMEDIATE" if self.immediate else "BEGIN")
            return self.cur

        def __exit__(self, exc_type, exc, tb):
            if exc_type is None:
                self.conn.execute("COMMIT")
            else:
                self.conn.execute("ROLLBACK")
            self.cur.close()
            return False

    def _tx(self, immediate: bool = True) -> "_Tx":
        return SQLiteStorage._Tx(self._conn(), immediate)

    # -- study ---------------------------------------------------------------

    @_retry
    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        try:
            with self._tx() as cur:
                cur.execute(
                    "INSERT INTO studies (study_name, directions) VALUES (?, ?)",
                    (study_name, json.dumps([int(d) for d in directions])),
                )
                return cur.lastrowid
        except sqlite3.IntegrityError:
            raise DuplicatedStudyError(study_name)

    @_retry
    def delete_study(self, study_id: int) -> None:
        with self._tx() as cur:
            cur.execute("SELECT trial_id FROM trials WHERE study_id=?", (study_id,))
            tids = [r[0] for r in cur.fetchall()]
            for table in ("trial_params", "trial_intermediate_values", "trial_attrs", "trial_heartbeats"):
                cur.executemany(f"DELETE FROM {table} WHERE trial_id=?", [(t,) for t in tids])
            cur.execute("DELETE FROM trials WHERE study_id=?", (study_id,))
            cur.execute("DELETE FROM study_attrs WHERE study_id=?", (study_id,))
            cur.execute("DELETE FROM study_revisions WHERE study_id=?", (study_id,))
            cur.execute("DELETE FROM studies WHERE study_id=?", (study_id,))
        self._drop_intermediate_store(study_id)
        self._drop_event_log(study_id)

    @_retry
    def get_study_id_from_name(self, study_name: str) -> int:
        cur = self._conn().execute(
            "SELECT study_id FROM studies WHERE study_name=?", (study_name,)
        )
        row = cur.fetchone()
        if row is None:
            raise StudyNotFoundError(study_name)
        return row[0]

    @_retry
    def get_study_name_from_id(self, study_id: int) -> str:
        cur = self._conn().execute(
            "SELECT study_name FROM studies WHERE study_id=?", (study_id,)
        )
        row = cur.fetchone()
        if row is None:
            raise StudyNotFoundError(study_id)
        return row[0]

    @_retry
    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        cur = self._conn().execute(
            "SELECT directions FROM studies WHERE study_id=?", (study_id,)
        )
        row = cur.fetchone()
        if row is None:
            raise StudyNotFoundError(study_id)
        return [StudyDirection(d) for d in json.loads(row[0])]

    @_retry
    def get_all_studies(self) -> list[StudySummary]:
        cur = self._conn().execute("SELECT study_id, study_name, directions FROM studies")
        out = []
        for sid, name, dirs in cur.fetchall():
            n = self._conn().execute(
                "SELECT COUNT(*) FROM trials WHERE study_id=?", (sid,)
            ).fetchone()[0]
            out.append(
                StudySummary(
                    sid, name, [StudyDirection(d) for d in json.loads(dirs)], n,
                    self.get_study_user_attrs(sid), self.get_study_system_attrs(sid),
                )
            )
        return out

    def _set_study_attr(self, study_id: int, key: str, value: Any, is_system: int) -> None:
        with self._tx() as cur:
            cur.execute(
                "INSERT OR REPLACE INTO study_attrs (study_id, is_system, key, value_json)"
                " VALUES (?, ?, ?, ?)",
                (study_id, is_system, key, json.dumps(value)),
            )

    def _get_study_attrs(self, study_id: int, is_system: int) -> dict[str, Any]:
        cur = self._conn().execute(
            "SELECT key, value_json FROM study_attrs WHERE study_id=? AND is_system=?",
            (study_id, is_system),
        )
        return {k: json.loads(v) for k, v in cur.fetchall()}

    set_study_user_attr = _retry(lambda self, sid, k, v: self._set_study_attr(sid, k, v, 0))
    set_study_system_attr = _retry(lambda self, sid, k, v: self._set_study_attr(sid, k, v, 1))
    get_study_user_attrs = _retry(lambda self, sid: self._get_study_attrs(sid, 0))
    get_study_system_attrs = _retry(lambda self, sid: self._get_study_attrs(sid, 1))

    # -- trial -----------------------------------------------------------------

    @_retry
    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        with self._tx() as cur:
            cur.execute("SELECT COUNT(*) FROM studies WHERE study_id=?", (study_id,))
            if cur.fetchone()[0] == 0:
                raise StudyNotFoundError(study_id)
            cur.execute(
                "SELECT COALESCE(MAX(number), -1) + 1 FROM trials WHERE study_id=?",
                (study_id,),
            )
            number = cur.fetchone()[0]
            t = template_trial
            state = t.state if t is not None else TrialState.RUNNING
            values = json.dumps(t.values) if t is not None and t.values else None
            start = self._dt(t.datetime_start) if t is not None and t.datetime_start else (
                None if state == TrialState.WAITING else self._dt(self._now())
            )
            cur.execute(
                "INSERT INTO trials (study_id, number, state, values_json, datetime_start)"
                " VALUES (?, ?, ?, ?, ?)",
                (study_id, number, int(state), values, start),
            )
            tid = cur.lastrowid
            if t is not None:
                for name, dist in t.distributions.items():
                    cur.execute(
                        "INSERT INTO trial_params VALUES (?, ?, ?, ?)",
                        (tid, name, dist.to_internal_repr(t.params[name]),
                         distribution_to_json(dist)),
                    )
                for step, v in t.intermediate_values.items():
                    cur.execute(
                        "INSERT INTO trial_intermediate_values VALUES (?, ?, ?)",
                        (tid, step, v),
                    )
                for k, v in t.user_attrs.items():
                    cur.execute("INSERT INTO trial_attrs VALUES (?, 0, ?, ?)", (tid, k, json.dumps(v)))
                for k, v in t.system_attrs.items():
                    cur.execute("INSERT INTO trial_attrs VALUES (?, 1, ?, ?)", (tid, k, json.dumps(v)))
            self._bump_revision(cur, study_id)
        # after commit: the event log takes its own leaf lock
        self._record_event(study_id, _telemetry.EV_CREATED, number)
        return tid

    @staticmethod
    def _bump_revision(cur: sqlite3.Cursor, study_id: int) -> None:
        cur.execute(
            "INSERT INTO study_revisions VALUES (?, 1)"
            " ON CONFLICT(study_id) DO UPDATE SET revision = revision + 1",
            (study_id,),
        )

    @staticmethod
    def _bump_revision_for_trial(cur: sqlite3.Cursor, trial_id: int) -> None:
        cur.execute("SELECT study_id FROM trials WHERE trial_id=?", (trial_id,))
        row = cur.fetchone()
        if row is not None:
            SQLiteStorage._bump_revision(cur, row[0])

    @_retry
    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution: BaseDistribution,
    ) -> None:
        with self._tx() as cur:
            state = self._trial_state(cur, trial_id)
            if state.is_finished():
                raise RuntimeError(f"trial {trial_id} is already finished")
            cur.execute(
                "SELECT distribution_json FROM trial_params WHERE trial_id=? AND param_name=?",
                (trial_id, param_name),
            )
            row = cur.fetchone()
            if row is not None:
                check_distribution_compatibility(json_to_distribution(row[0]), distribution)
            cur.execute(
                "INSERT OR REPLACE INTO trial_params VALUES (?, ?, ?, ?)",
                (trial_id, param_name, float(param_value_internal), distribution_to_json(distribution)),
            )
            self._bump_revision_for_trial(cur, trial_id)

    @_retry
    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        with self._tx() as cur:
            old = self._trial_state(cur, trial_id)
            if state == TrialState.RUNNING and old != TrialState.WAITING:
                return False
            sets = ["state=?"]
            args: list[Any] = [int(state)]
            if values is not None:
                sets.append("values_json=?")
                args.append(json.dumps([float(v) for v in values]))
            if state == TrialState.RUNNING:
                sets.append("datetime_start=?")
                args.append(self._dt(self._now()))
            if state.is_finished():
                sets.append("datetime_complete=?")
                args.append(self._dt(self._now()))
            args.append(trial_id)
            cur.execute(f"UPDATE trials SET {', '.join(sets)} WHERE trial_id=?", args)
            if state.is_finished():
                cur.execute("DELETE FROM trial_heartbeats WHERE trial_id=?", (trial_id,))
            self._bump_revision_for_trial(cur, trial_id)
            cur.execute(
                "SELECT study_id, number FROM trials WHERE trial_id=?", (trial_id,)
            )
            row = cur.fetchone()
        if row is not None:
            self._record_state_event(row[0], state, row[1])
        return True

    @_retry
    def set_trial_intermediate_value(self, trial_id: int, step: int, intermediate_value: float) -> None:
        with self._tx() as cur:
            if self._trial_state(cur, trial_id).is_finished():
                raise RuntimeError(f"trial {trial_id} is already finished")
            cur.execute(
                "INSERT OR REPLACE INTO trial_intermediate_values VALUES (?, ?, ?)",
                (trial_id, int(step), float(intermediate_value)),
            )
            self._bump_revision_for_trial(cur, trial_id)
            cur.execute(
                "SELECT study_id, number FROM trials WHERE trial_id=?", (trial_id,)
            )
            row = cur.fetchone()
        # after commit: stores lock store-first
        self._note_iv_dirty(trial_id, row[0] if row is not None else None)
        if row is not None:
            self._record_event(row[0], _telemetry.EV_REPORTED, row[1], step=int(step))

    def _set_trial_attr(self, trial_id: int, key: str, value: Any, is_system: int) -> None:
        with self._tx() as cur:
            self._trial_state(cur, trial_id)  # existence check
            cur.execute(
                "INSERT OR REPLACE INTO trial_attrs VALUES (?, ?, ?, ?)",
                (trial_id, is_system, key, json.dumps(value)),
            )
            self._bump_revision_for_trial(cur, trial_id)

    set_trial_user_attr = _retry(lambda self, tid, k, v: self._set_trial_attr(tid, k, v, 0))
    set_trial_system_attr = _retry(lambda self, tid, k, v: self._set_trial_attr(tid, k, v, 1))

    @_retry
    def get_trial(self, trial_id: int) -> FrozenTrial:
        conn = self._conn()
        cur = conn.execute(
            "SELECT study_id, number, state, values_json, datetime_start, datetime_complete"
            " FROM trials WHERE trial_id=?",
            (trial_id,),
        )
        row = cur.fetchone()
        if row is None:
            raise TrialNotFoundError(trial_id)
        return self._row_to_trial(trial_id, row)

    def _row_to_trial(self, trial_id: int, row) -> FrozenTrial:
        conn = self._conn()
        _, number, state, values_json, start, complete = row
        params, dists = {}, {}
        for name, val, dist_json in conn.execute(
            "SELECT param_name, param_value, distribution_json FROM trial_params WHERE trial_id=?",
            (trial_id,),
        ):
            dist = json_to_distribution(dist_json)
            params[name] = dist.to_external_repr(val)
            dists[name] = dist
        ivs = {
            s: v for s, v in conn.execute(
                "SELECT step, value FROM trial_intermediate_values WHERE trial_id=?", (trial_id,)
            )
        }
        uattrs, sattrs = {}, {}
        for is_sys, k, v in conn.execute(
            "SELECT is_system, key, value_json FROM trial_attrs WHERE trial_id=?", (trial_id,)
        ):
            (sattrs if is_sys else uattrs)[k] = json.loads(v)
        return FrozenTrial(
            number=number,
            state=TrialState(state),
            values=json.loads(values_json) if values_json else None,
            params=params,
            distributions=dists,
            intermediate_values=ivs,
            user_attrs=uattrs,
            system_attrs=sattrs,
            trial_id=trial_id,
            datetime_start=self._parse_dt(start),
            datetime_complete=self._parse_dt(complete),
        )

    @_retry
    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        conn = self._conn()
        q = (
            "SELECT trial_id, study_id, number, state, values_json, datetime_start,"
            " datetime_complete FROM trials WHERE study_id=?"
        )
        args: list[Any] = [study_id]
        if since is not None:
            q += " AND number >= ?"
            args.append(int(since))
        if states is not None:
            q += f" AND state IN ({','.join('?' * len(states))})"
            args += [int(s) for s in states]
        q += " ORDER BY number"
        out = []
        for row in conn.execute(q, args).fetchall():
            out.append(self._row_to_trial(row[0], row[1:]))
        return out

    @_retry
    def get_n_trials(self, study_id: int, states: tuple[TrialState, ...] | None = None) -> int:
        q = "SELECT COUNT(*) FROM trials WHERE study_id=?"
        args: list[Any] = [study_id]
        if states is not None:
            q += f" AND state IN ({','.join('?' * len(states))})"
            args += [int(s) for s in states]
        return self._conn().execute(q, args).fetchone()[0]

    @_retry
    def get_trials_revision(self, study_id: int) -> int:
        cur = self._conn().execute(
            "SELECT revision FROM study_revisions WHERE study_id=?", (study_id,)
        )
        row = cur.fetchone()
        if row is not None:
            return row[0]
        if self._conn().execute(
            "SELECT COUNT(*) FROM studies WHERE study_id=?", (study_id,)
        ).fetchone()[0] == 0:
            raise StudyNotFoundError(study_id)
        return 0

    @staticmethod
    def _trial_state(cur: sqlite3.Cursor, trial_id: int) -> TrialState:
        cur.execute("SELECT state FROM trials WHERE trial_id=?", (trial_id,))
        row = cur.fetchone()
        if row is None:
            raise TrialNotFoundError(trial_id)
        return TrialState(row[0])

    # -- heartbeat ----------------------------------------------------------------

    @_retry
    def record_heartbeat(self, trial_id: int) -> None:
        with self._tx() as cur:
            cur.execute(
                "INSERT OR REPLACE INTO trial_heartbeats VALUES (?, ?)",
                (trial_id, time.time()),
            )

    @_retry
    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        cutoff = time.time() - grace_seconds
        cur = self._conn().execute(
            "SELECT t.trial_id FROM trials t JOIN trial_heartbeats h"
            " ON t.trial_id = h.trial_id"
            " WHERE t.study_id=? AND t.state=? AND h.heartbeat_at < ?",
            (study_id, int(TrialState.RUNNING), cutoff),
        )
        return [r[0] for r in cur.fetchall()]

    # -- misc -----------------------------------------------------------------------

    @staticmethod
    def _dt(dt: datetime.datetime) -> str:
        return dt.isoformat()

    @staticmethod
    def _parse_dt(s: str | None) -> datetime.datetime | None:
        return datetime.datetime.fromisoformat(s) if s else None

    def close(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None
