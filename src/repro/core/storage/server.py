"""Networked storage server — the piece that turns "N processes on one box"
into "N workers on a fleet" (paper §4's scalable deployment criterion).

A :class:`StorageServer` wraps *any* :class:`BaseStorage` backend and exposes
it over TCP to :class:`~repro.core.storage.client.RemoteStorage` clients.

Protocol
--------
Every frame is a 4-byte big-endian payload length followed by the payload.
Two payload encodings share that framing, negotiated per connection:

* **v1 (JSON, default)** — UTF-8 JSON-RPC.  A request is ``{"id", "method",
  "params"}`` (params encoded with :mod:`.serde`); the response is ``{"id",
  "ok", "result"}`` or ``{"id", "ok": false, "error": {"type", "message"}}``.
  A frame may carry a *list* of requests (a batch); the server executes them
  in order and answers with a list of responses in the same frame — one
  round trip for a whole write-behind flush.

* **v2 (binary)** — negotiated via a ``hello`` RPC (sent as JSON; once the
  server acknowledges ``protocol: 2`` both directions switch).  Payloads are
  one ``0xB2`` magic byte followed by the tagged binary encoding of the same
  request/response dicts (:func:`.serde.bdumps`), whose native ``ndarray``
  tag lets the hot RPCs — ``get_all_trials(since=)`` deltas, batched
  ``create_new_trials``, and the columnar ``get_observation_block`` /
  ``get_iv_block`` snapshots — ship raw numpy buffers instead of JSON trial
  dicts.  Legacy JSON clients never send ``hello`` and keep working
  unchanged; a v2 client talking to a JSON-only server falls back to v1 on
  the hello error.

Concurrency: a single-threaded non-blocking event loop (``selectors``
reactor) with per-connection read/write buffers — no thread per connection,
so a 1k-worker storm costs the server zero GIL thrashing.  Atomicity of each
call (e.g. the WAITING->RUNNING compare-and-set in
``set_trial_state_values``) is delegated to the wrapped backend; since all
dispatch happens on the reactor thread, calls are additionally serialized at
the server.  A connection that violates the protocol (oversized length,
garbage payload, mid-frame stall) is dropped in isolation — the loop and
every other connection keep serving.  Graceful shutdown via
:meth:`StorageServer.stop` — pending responses are flushed, then sockets
close.

Security: ``auth_token`` arms the shared-secret first-frame handshake;
``auth_tokens`` adds *scoped* tokens (read-only and/or study-id allowlists)
whose violations surface as ``PermissionError``.  ``tls_cert``/``tls_key``
wrap the listener in TLS (clients connect via ``remote+tls://``).
"""

from __future__ import annotations

import hmac
import json
import os
import selectors
import socket
import ssl
import struct
import threading
import time
from typing import Any

from .. import telemetry
from .base import BaseStorage, get_trials_since
from .serde import BINARY_MAGIC, bdumps, bjoin, bloads, pack, unpack

__all__ = ["StorageServer", "send_frame", "recv_frame", "MAX_FRAME_BYTES"]

MAX_FRAME_BYTES = 64 * 1024 * 1024  # sanity cap on one frame
MID_FRAME_STALL_SECONDS = 30.0  # max time a peer may stall between bytes of one frame

# The RPC surface: exactly the BaseStorage API (plus ping for liveness).
_METHODS = frozenset(
    {
        "create_new_study",
        "delete_study",
        "get_study_id_from_name",
        "get_study_name_from_id",
        "get_study_directions",
        "get_all_studies",
        "set_study_user_attr",
        "set_study_system_attr",
        "get_study_user_attrs",
        "get_study_system_attrs",
        "create_new_trial",
        "create_new_trials",
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "report_and_prune",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "get_all_trials",
        "get_n_trials",
        "get_trial_id_from_study_and_number",
        "record_heartbeat",
        "get_stale_trial_ids",
        "fail_stale_trials",
        "get_trials_revision",
        "get_trial_events",
        "get_observation_block",
        "get_iv_block",
    }
)

# scope enforcement tables: which methods mutate, and how each method names
# the study it touches (first param is a study_id unless listed here)
_WRITE_METHODS = frozenset(
    {
        "create_new_study",
        "delete_study",
        "set_study_user_attr",
        "set_study_system_attr",
        "create_new_trial",
        "create_new_trials",
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "report_and_prune",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "record_heartbeat",
        "fail_stale_trials",
    }
)
_TRIAL_SCOPED = frozenset(
    {
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "record_heartbeat",
    }
)
# not addressable by one study id — denied outright for study-scoped tokens
_GLOBAL_SCOPED = frozenset({"create_new_study", "get_all_studies"})

# binary-only RPCs: their responses are raw-array blocks that have no JSON
# encoding; v1 clients get a typed NotImplementedError and fall back
_V2_ONLY = frozenset({"get_observation_block", "get_iv_block"})


# -- blocking frame helpers (used by the client; the server is non-blocking) --


def send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(struct.pack(">I", len(payload)) + payload)


def recv_frame(sock: socket.socket) -> bytes | None:
    """Read one length-prefixed frame; ``None`` on clean EOF.

    A ``socket.timeout`` escapes only while *idle* (no byte of the frame seen
    yet) — once a frame has started, reads are retried so a slow peer cannot
    cause a torn frame, but a peer that stalls longer than
    ``MID_FRAME_STALL_SECONDS`` without sending a single byte raises
    ``ConnectionError`` instead of hanging the caller forever.
    """
    header = _recv_exact(sock, 4, allow_idle_timeout=True)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {length} bytes exceeds cap {MAX_FRAME_BYTES}")
    body = _recv_exact(sock, length, allow_idle_timeout=False)
    if body is None:
        raise ConnectionError("connection closed mid-frame")
    return body


def _recv_exact(sock: socket.socket, n: int, allow_idle_timeout: bool) -> bytes | None:
    buf = b""
    stall_deadline: float | None = None
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if allow_idle_timeout and not buf:
                raise
            now = time.monotonic()
            if stall_deadline is None:
                stall_deadline = now + MID_FRAME_STALL_SECONDS
            elif now >= stall_deadline:
                raise ConnectionError(
                    f"peer stalled mid-frame for over {MID_FRAME_STALL_SECONDS}s"
                ) from None
            continue  # mid-frame: give the peer a bounded grace period
        stall_deadline = None  # any progress resets the stall clock
        if not chunk:
            if buf:
                raise ConnectionError("connection closed mid-frame")
            return None
        buf += chunk
    return buf


# -- auth scopes --------------------------------------------------------------


class _Scope:
    """Capabilities of one auth token: ``readonly`` blocks writes,
    ``studies`` (a frozenset of study ids, or None = all) bounds which
    studies the token may touch."""

    __slots__ = ("readonly", "studies")

    def __init__(self, readonly: bool = False, studies: "frozenset[int] | None" = None):
        self.readonly = readonly
        self.studies = studies

    @property
    def unrestricted(self) -> bool:
        return not self.readonly and self.studies is None


_FULL_SCOPE = _Scope()


def _normalize_tokens(auth_token, auth_tokens) -> list[tuple[str, _Scope]]:
    scopes: list[tuple[str, _Scope]] = []
    if auth_token is not None:
        scopes.append((auth_token, _FULL_SCOPE))
    for ent in auth_tokens or []:
        if isinstance(ent, str):
            scopes.append((ent, _FULL_SCOPE))
            continue
        studies = ent.get("studies")
        scopes.append(
            (
                ent["token"],
                _Scope(
                    readonly=bool(ent.get("readonly", False)),
                    studies=(
                        frozenset(int(s) for s in studies) if studies is not None else None
                    ),
                ),
            )
        )
    return scopes


# -- reactor ------------------------------------------------------------------


class _Drop(Exception):
    """Internal: close this connection (protocol violation or dead peer)."""


class _Conn:
    __slots__ = (
        "sock",
        "peer",
        "inbuf",
        "outbuf",
        "authed",
        "scope",
        "proto",
        "specs",
        "closing",
        "handshaking",
        "stall_deadline",
        "mask",
        "closed",
    )

    def __init__(self, sock, peer: str, authed: bool, handshaking: bool):
        self.sock = sock
        self.peer = peer
        self.inbuf = bytearray()
        self.outbuf = bytearray()
        self.authed = authed
        self.scope: "_Scope | None" = _FULL_SCOPE if authed else None
        self.proto = 1
        # per-connection interned pruner specs (client sends each spec once
        # as __spec_def__, then short __spec_ref__ frames; see client.py)
        self.specs: dict[int, dict] = {}
        self.closing = False  # reply flushed, then close (auth rejection)
        self.handshaking = handshaking  # TLS handshake in progress
        self.stall_deadline: "float | None" = (
            time.monotonic() + MID_FRAME_STALL_SECONDS if handshaking else None
        )
        self.mask = selectors.EVENT_READ
        self.closed = False


class _RPCServer:
    """The selectors-based reactor + dispatcher behind :class:`StorageServer`."""

    def __init__(
        self,
        addr: tuple[str, int],
        storage: BaseStorage,
        auth_token: "str | None" = None,
        auth_tokens: "list | None" = None,
        ssl_context: "ssl.SSLContext | None" = None,
        max_protocol: int = 2,
    ):
        self.storage = storage
        self._scopes = _normalize_tokens(auth_token, auth_tokens)
        self.auth_required = bool(self._scopes)
        self.ssl_context = ssl_context
        self.max_protocol = max_protocol
        self.stopping = threading.Event()
        # always-on, server-owned registry: get_server_metrics must work
        # without globally enabling client-side telemetry in this process
        self.metrics = telemetry.MetricsRegistry(enabled=True)
        self.started_at = time.time()
        # trial_id -> study_id, maintained only when a study-scoped token
        # exists (enforcement needs it; unscoped servers skip the memory)
        self._track_trials = any(sc.studies is not None for _, sc in self._scopes)
        self._trial_study: dict[int, int] = {}

        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(addr)
        listener.listen(128)
        listener.setblocking(False)
        self._listener = listener
        self.server_address = listener.getsockname()
        self._sel = selectors.DefaultSelector()
        self._conns: set[_Conn] = set()
        self._last_sweep = time.monotonic()
        self._closed = False

    # -- event loop -----------------------------------------------------------

    def serve_forever(self, poll_interval: float = 0.1) -> None:
        self._sel.register(self._listener, selectors.EVENT_READ, None)
        try:
            while not self.stopping.is_set():
                for key, mask in self._sel.select(poll_interval):
                    if key.data is None:
                        self._accept()
                    else:
                        conn: _Conn = key.data
                        try:
                            self._service(conn, mask)
                        except _Drop:
                            self._close_conn(conn)
                        except Exception:
                            # one connection's failure must never kill the
                            # loop: drop it, keep serving everyone else
                            self.metrics.counter("server.protocol_errors").inc()
                            self._close_conn(conn)
                now = time.monotonic()
                if now - self._last_sweep >= 1.0:
                    self._last_sweep = now
                    self._sweep_stalled(now)
        finally:
            self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in list(self._conns):
            if conn.outbuf and not conn.handshaking and not conn.closed:
                # best-effort flush of pending responses on graceful shutdown
                try:
                    conn.sock.setblocking(True)
                    conn.sock.settimeout(1.0)
                    conn.sock.sendall(bytes(conn.outbuf))
                except Exception:
                    pass
            self._close_conn(conn)
        try:
            self._sel.close()
        except Exception:
            pass
        try:
            self._listener.close()
        except OSError:
            pass

    def _accept(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            handshaking = False
            if self.ssl_context is not None:
                try:
                    sock = self.ssl_context.wrap_socket(
                        sock, server_side=True, do_handshake_on_connect=False
                    )
                except (ssl.SSLError, OSError):
                    try:
                        sock.close()
                    except OSError:
                        pass
                    continue
                handshaking = True
            conn = _Conn(
                sock, "%s:%s" % addr[:2], authed=not self.auth_required,
                handshaking=handshaking,
            )
            try:
                self._sel.register(sock, selectors.EVENT_READ, conn)
            except (ValueError, KeyError, OSError):
                try:
                    sock.close()
                except OSError:
                    pass
                continue
            self._conns.add(conn)
            self.metrics.gauge("server.active_connections").add(1)

    def _service(self, conn: _Conn, mask: int) -> None:
        if conn.closed:
            return
        if conn.handshaking:
            self._tls_handshake(conn)
            return
        if mask & selectors.EVENT_READ:
            self._read(conn)
        if not conn.closed and (mask & selectors.EVENT_WRITE):
            self._write(conn)

    def _tls_handshake(self, conn: _Conn) -> None:
        try:
            conn.sock.do_handshake()
        except ssl.SSLWantReadError:
            self._set_mask(conn, selectors.EVENT_READ)
            return
        except ssl.SSLWantWriteError:
            self._set_mask(conn, selectors.EVENT_WRITE)
            return
        except (ssl.SSLError, OSError):
            raise _Drop from None
        conn.handshaking = False
        conn.stall_deadline = None
        self._set_mask(conn, selectors.EVENT_READ)
        # app data may have arrived piggybacked on the final handshake flight
        self._read(conn)

    def _read(self, conn: _Conn) -> None:
        while True:
            try:
                chunk = conn.sock.recv(1 << 16)
            except (ssl.SSLWantReadError, ssl.SSLWantWriteError):
                break
            except (BlockingIOError, InterruptedError):
                break
            except (ConnectionError, OSError, ssl.SSLError):
                raise _Drop from None
            if not chunk:
                raise _Drop  # EOF
            conn.inbuf += chunk
            conn.stall_deadline = None  # progress resets the stall clock
            if len(conn.inbuf) > MAX_FRAME_BYTES + 4:
                break  # let frame parsing catch up before buffering more
        self._process_inbuf(conn)

    def _process_inbuf(self, conn: _Conn) -> None:
        inbuf = conn.inbuf
        while not conn.closed and not conn.closing:
            if len(inbuf) < 4:
                break
            length = int.from_bytes(inbuf[:4], "big")
            if length > MAX_FRAME_BYTES:
                # oversized length header: unrecoverable framing state
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop
            if len(inbuf) < 4 + length:
                break
            payload = bytes(memoryview(inbuf)[4 : 4 + length])
            del inbuf[: 4 + length]
            self._handle_frame(conn, payload)
        if conn.closed:
            return
        if inbuf and conn.stall_deadline is None:
            # partial frame pending: the peer gets a bounded grace period
            conn.stall_deadline = time.monotonic() + MID_FRAME_STALL_SECONDS

    def _handle_frame(self, conn: _Conn, payload: bytes) -> None:
        self.metrics.counter("server.frames_in").inc()
        self.metrics.counter("server.bytes_in").inc(len(payload))
        if not conn.authed:
            self._handle_auth(conn, payload)
            return
        proto = conn.proto
        if proto == 2:
            if not payload or payload[0] != BINARY_MAGIC:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop
            try:
                request = bloads(memoryview(payload)[1:])
            except Exception:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop from None
        else:
            try:
                request = json.loads(payload)
            except json.JSONDecodeError:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop from None
        batch = isinstance(request, list)
        t0 = time.perf_counter()
        # events the wrapped backend records during dispatch carry the
        # *client* identity, so a fleet-wide trace attributes work to workers
        telemetry.set_worker_context(conn.peer)
        hello_proto = None
        try:
            encoded: list[bytes] = []
            for r in request if batch else [request]:
                response, blob = self.dispatch(
                    r, conn.specs, scope=conn.scope, proto=proto
                )
                encoded.append(blob)
                if (
                    not batch
                    and isinstance(r, dict)
                    and r.get("method") == "hello"
                    and response.get("ok")
                ):
                    hello_proto = response["result"]["protocol"]
        finally:
            telemetry.set_worker_context(None)
        if batch:
            # responses were serialized one by one (for per-method byte
            # accounting); assemble the batch frame compositionally instead
            # of re-serializing the whole list
            if proto == 2:
                body = bytes([BINARY_MAGIC]) + bjoin(encoded)
            else:
                body = b"[" + b",".join(encoded) + b"]"
            # the whole-frame view of a batched flush (tell_batch, the
            # write-behind drain): per-op latencies are recorded by dispatch;
            # this row pins the envelope cost clients feel
            self._note_rpc("batch", t0, len(body))
            self.metrics.counter("server.batched_ops").inc(len(encoded))
        else:
            body = (bytes([BINARY_MAGIC]) + encoded[0]) if proto == 2 else encoded[0]
        self._send(conn, body)
        if hello_proto == 2:
            conn.proto = 2  # every later frame on this connection is binary

    def _handle_auth(self, conn: _Conn, payload: bytes) -> None:
        # the auth handshake is always JSON, whatever gets negotiated later
        try:
            request = json.loads(payload)
        except json.JSONDecodeError:
            self.metrics.counter("server.protocol_errors").inc()
            raise _Drop from None
        scope = self._auth_scope(request)
        if scope is not None:
            conn.authed = True
            conn.scope = scope
            response = {"id": request.get("id"), "ok": True, "result": "ok"}
        else:
            self.metrics.counter("server.auth_failures").inc()
            self.metrics.counter("server.auth_failures.bad_token").inc()
            response = {
                "id": request.get("id") if isinstance(request, dict) else None,
                "ok": False,
                "error": {
                    "type": "PermissionError",
                    "message": "storage server requires an auth token",
                },
            }
            conn.closing = True  # reply, flush, drop
        self._send(conn, json.dumps(response).encode())

    def _auth_scope(self, request: Any) -> "_Scope | None":
        if not isinstance(request, dict) or request.get("method") != "auth":
            return None
        params = request.get("params")
        if not isinstance(params, list) or len(params) != 1 or not isinstance(params[0], str):
            return None
        for token, scope in self._scopes:
            if hmac.compare_digest(params[0], token):
                return scope
        return None

    def _send(self, conn: _Conn, body: bytes) -> None:
        self.metrics.counter("server.frames_out").inc()
        self.metrics.counter("server.bytes_out").inc(len(body))
        conn.outbuf += struct.pack(">I", len(body))
        conn.outbuf += body
        self._write(conn)

    def _write(self, conn: _Conn) -> None:
        while conn.outbuf:
            try:
                n = conn.sock.send(memoryview(conn.outbuf))
            except (ssl.SSLWantWriteError, ssl.SSLWantReadError):
                break
            except (BlockingIOError, InterruptedError):
                break
            except (ConnectionError, OSError, ssl.SSLError):
                raise _Drop from None
            if n == 0:
                break
            del conn.outbuf[:n]
        if conn.outbuf:
            self._set_mask(conn, selectors.EVENT_READ | selectors.EVENT_WRITE)
            if conn.stall_deadline is None:
                # a peer that never drains its responses is as dead as one
                # that stalls mid-frame
                conn.stall_deadline = time.monotonic() + MID_FRAME_STALL_SECONDS
        else:
            self._set_mask(conn, selectors.EVENT_READ)
            if conn.closing:
                self._close_conn(conn)

    def _set_mask(self, conn: _Conn, mask: int) -> None:
        if mask != conn.mask and not conn.closed:
            try:
                self._sel.modify(conn.sock, mask, conn)
                conn.mask = mask
            except (ValueError, KeyError, OSError):
                raise _Drop from None

    def _sweep_stalled(self, now: float) -> None:
        for conn in list(self._conns):
            if conn.stall_deadline is not None and now >= conn.stall_deadline:
                self.metrics.counter("server.stalled_connections").inc()
                self._close_conn(conn)

    def _close_conn(self, conn: _Conn) -> None:
        if conn.closed:
            return
        conn.closed = True
        try:
            self._sel.unregister(conn.sock)
        except (ValueError, KeyError, OSError):
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self._conns.discard(conn)
        self.metrics.gauge("server.active_connections").add(-1)

    # -- dispatch -------------------------------------------------------------

    def dispatch(
        self,
        request: Any,
        conn_specs: "dict[int, dict] | None" = None,
        scope: "_Scope | None" = None,
        proto: int = 1,
    ) -> tuple[dict, bytes]:
        """Execute one RPC; returns ``(response, encoded_response)``.

        The response is serialized exactly once — the returned bytes are both
        the wire payload and the per-method byte-accounting sample."""
        enc = self._enc_json if proto == 1 else self._enc_bin
        if not isinstance(request, dict):
            request = {}
        req_id = request.get("id")
        method = request.get("method")
        t0 = time.perf_counter()
        try:
            if method == "ping":
                response = {"id": req_id, "ok": True, "result": "pong"}
                return response, enc(response)
            if method == "auth":
                # reaching dispatch means no token is required (or the
                # connection already authenticated); accept idempotently
                response = {"id": req_id, "ok": True, "result": "ok"}
                return response, enc(response)
            if method == "hello" and self.max_protocol >= 2:
                response = {"id": req_id, "ok": True, "result": self._hello(request)}
                return response, enc(response)
            if method == "get_server_metrics":
                response = {"id": req_id, "ok": True, "result": self.server_metrics()}
                return response, enc(response)
            if method not in _METHODS:
                raise ValueError(f"unknown storage method {method!r}")
            params = request.get("params") or []
            if proto == 1:
                params = unpack(params)
            if method == "report_and_prune":
                spec = params[4] if len(params) > 4 and isinstance(params[4], dict) else None
                if spec is not None and "__spec_ref__" in spec:
                    self.metrics.counter("server.spec_cache.hits").inc()
                elif spec is not None and "__spec_def__" in spec:
                    self.metrics.counter("server.spec_cache.defs").inc()
                params = _resolve_spec(params, conn_specs)
            self._check_scope(method, params, scope)
            if method in _V2_ONLY and proto == 1:
                raise NotImplementedError(f"{method} requires wire protocol v2")
            result = self._invoke(method, params)
            if self._track_trials:
                self._note_trial_ids(method, params, result)
            response = {
                "id": req_id,
                "ok": True,
                "result": pack(result) if proto == 1 else result,
            }
            # an unserializable result must become a typed error frame, not a
            # dropped connection (the client would silently retry + misreport)
            blob = enc(response)
            self._note_rpc(method, t0, len(blob))
            return response, blob
        except Exception as e:  # every failure maps to a typed client-side raise
            self._note_rpc(method, t0, 0, error=True)
            response = {
                "id": req_id,
                "ok": False,
                "error": {"type": type(e).__name__, "message": str(e)},
            }
            try:
                return response, enc(response)
            except Exception:  # pragma: no cover - unserializable error text
                response = {
                    "id": req_id,
                    "ok": False,
                    "error": {"type": "StorageInternalError", "message": "dispatch failed"},
                }
                return response, enc(response)

    @staticmethod
    def _enc_json(response: dict) -> bytes:
        return json.dumps(response).encode()

    @staticmethod
    def _enc_bin(response: dict) -> bytes:
        return bdumps(response)

    def _hello(self, request: dict) -> dict:
        params = request.get("params") or []
        want = 2
        if params and isinstance(params[0], dict):
            want = int(params[0].get("protocol", 2))
        return {"protocol": max(1, min(want, self.max_protocol, 2))}

    def _check_scope(self, method: str, params: list, scope: "_Scope | None") -> None:
        if scope is None or scope.unrestricted:
            return
        if scope.readonly and method in _WRITE_METHODS:
            self._auth_failure("readonly")
            raise PermissionError(f"token is read-only; {method!r} is a write")
        studies = scope.studies
        if studies is None:
            return
        if method in _GLOBAL_SCOPED:
            self._auth_failure("study_scope")
            raise PermissionError(
                f"token is study-scoped; {method!r} is not study-addressable"
            )
        if method == "get_study_id_from_name":
            # resolve first: the id mapping itself is what the scope protects
            sid = self.storage.get_study_id_from_name(params[0])
        elif method in _TRIAL_SCOPED:
            sid = self._study_of_trial(int(params[0]), studies)
        else:
            sid = int(params[0])
        if sid not in studies:
            self._auth_failure("study_scope")
            raise PermissionError(f"token is not scoped to study {sid}")

    def _auth_failure(self, cause: str) -> None:
        self.metrics.counter("server.auth_failures").inc()
        self.metrics.counter(f"server.auth_failures.{cause}").inc()

    def _study_of_trial(self, trial_id: int, studies: "frozenset[int]") -> int:
        """Resolve a trial-addressed call to its study for scope checks: the
        map fills from create dispatches; unknown ids (trials created by
        another connection) fall back to one scan of the allowed studies."""
        sid = self._trial_study.get(trial_id)
        if sid is None:
            for s in sorted(studies):
                try:
                    for t in self.storage.get_all_trials(s, deepcopy=False):
                        self._trial_study.setdefault(t.trial_id, s)
                except Exception:
                    continue
            sid = self._trial_study.get(trial_id)
        if sid is None:
            self._auth_failure("study_scope")
            raise PermissionError(
                f"trial {trial_id} is outside this token's study scope"
            )
        return sid

    def _note_trial_ids(self, method: str, params: list, result: Any) -> None:
        if method == "create_new_trial" and isinstance(result, int):
            self._trial_study[result] = int(params[0])
        elif method == "create_new_trials" and isinstance(result, list):
            sid = int(params[0])
            for tid in result:
                self._trial_study[tid] = sid
        elif method == "get_trial_id_from_study_and_number" and isinstance(result, int):
            self._trial_study[result] = int(params[0])

    def _note_rpc(self, method: Any, t0: float, nbytes: int, error: bool = False) -> None:
        name = method if isinstance(method, str) else "invalid"
        self.metrics.counter(f"server.rpc.{name}.calls").inc()
        self.metrics.histogram(f"server.rpc.{name}").observe(time.perf_counter() - t0)
        if nbytes:
            self.metrics.counter(f"server.rpc.{name}.bytes_out").inc(nbytes)
        if error:
            self.metrics.counter(f"server.rpc.{name}.errors").inc()

    def server_metrics(self) -> dict[str, Any]:
        """JSON-safe metrics surface: per-method call counts / latency
        percentiles / bytes plus connection- and cache-level counters."""
        snap = self.metrics.snapshot()
        counters = snap["counters"]
        methods: dict[str, Any] = {}
        for name, h in snap["histograms"].items():
            if not name.startswith("server.rpc."):
                continue
            m = name[len("server.rpc."):]
            methods[m] = {
                "calls": counters.get(f"server.rpc.{m}.calls", 0),
                "errors": counters.get(f"server.rpc.{m}.errors", 0),
                "bytes_out": counters.get(f"server.rpc.{m}.bytes_out", 0),
                **{k: h[k] for k in ("count", "mean", "p50", "p95", "p99", "max")},
            }
        return {
            "uptime_s": time.time() - self.started_at,
            "active_connections": snap["gauges"].get("server.active_connections", 0),
            "auth_failures": counters.get("server.auth_failures", 0),
            "auth_failures_by_cause": {
                "bad_token": counters.get("server.auth_failures.bad_token", 0),
                "readonly": counters.get("server.auth_failures.readonly", 0),
                "study_scope": counters.get("server.auth_failures.study_scope", 0),
            },
            "protocol_errors": counters.get("server.protocol_errors", 0),
            "stalled_connections": counters.get("server.stalled_connections", 0),
            "frames_in": counters.get("server.frames_in", 0),
            "frames_out": counters.get("server.frames_out", 0),
            "bytes_in": counters.get("server.bytes_in", 0),
            "bytes_out": counters.get("server.bytes_out", 0),
            "spec_cache_hits": counters.get("server.spec_cache.hits", 0),
            "spec_cache_defs": counters.get("server.spec_cache.defs", 0),
            "batched_ops": counters.get("server.batched_ops", 0),
            "methods": methods,
        }

    def _invoke(self, method: str, params: list[Any]) -> Any:
        if method in ("get_all_trials", "get_n_trials"):
            # states arrives as a wire list; the API takes a tuple
            if method == "get_all_trials":
                study_id, deepcopy, states, since = params
                states = tuple(states) if states is not None else None
                if since is not None:
                    return get_trials_since(
                        self.storage, study_id, since, deepcopy=deepcopy, states=states
                    )
                return self.storage.get_all_trials(study_id, deepcopy=deepcopy, states=states)
            if method == "get_n_trials":
                study_id, states = params
                states = tuple(states) if states is not None else None
                return self.storage.get_n_trials(study_id, states=states)
        return getattr(self.storage, method)(*params)


def _resolve_spec(params: list, conn_specs: "dict[int, dict] | None") -> list:
    """Resolve the pruner-spec param of a fused report: a ``__spec_def__``
    envelope registers the full spec in this connection's cache, a
    ``__spec_ref__`` looks one up, and a raw spec dict (older clients, or
    in-process dispatch without connection state) passes through untouched."""
    if len(params) < 5 or not isinstance(params[4], dict):
        return params
    spec = params[4]
    if "__spec_def__" in spec:
        ent = spec["__spec_def__"]
        params = list(params)
        params[4] = ent["spec"]
        if conn_specs is not None:
            conn_specs[int(ent["id"])] = ent["spec"]
        return params
    if "__spec_ref__" in spec:
        ref = int(spec["__spec_ref__"])
        if conn_specs is None or ref not in conn_specs:
            raise ValueError(
                f"unknown pruner spec ref {ref} (connection lost its spec cache)"
            )
        params = list(params)
        params[4] = conn_specs[ref]
        return params
    return params


class StorageServer:
    """Serve a storage backend over TCP.

    >>> server = StorageServer(SQLiteStorage("study.db")).start()
    >>> server.url          # hand this to workers on other machines
    'remote://10.0.0.5:38211'
    >>> server.stop()

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    Usable as a context manager.

    ``auth_token`` arms a shared-secret handshake: every connection must
    present the token in its first frame (``RemoteStorage`` does this
    automatically for ``remote://token@host:port`` URLs or an explicit
    ``auth_token=``) or it is rejected with ``PermissionError`` and dropped.
    ``auth_tokens`` adds *scoped* tokens — dicts of ``{"token": str,
    "readonly": bool, "studies": [ids] | None}`` — whose violations raise
    ``PermissionError`` on the offending call (the connection survives).

    ``tls_cert``/``tls_key`` (PEM paths) wrap every connection in TLS;
    clients then connect via ``remote+tls://host:port`` (authentication
    still runs inside the encrypted channel).  Without TLS the wire is
    plaintext — run inside a trusted network or tunnel for confidentiality.

    ``max_protocol=1`` pins the server to JSON frames (the ``hello``
    negotiation is answered as an unknown method, exactly like a pre-v2
    server), which v2 clients transparently fall back from.
    """

    def __init__(
        self, storage: BaseStorage, host: str = "127.0.0.1", port: int = 0,
        auth_token: "str | None" = None, auth_tokens: "list | None" = None,
        tls_cert: "str | None" = None, tls_key: "str | None" = None,
        max_protocol: int = 2,
    ):
        if (tls_cert is None) != (tls_key is None):
            raise ValueError("tls_cert and tls_key must be given together")
        self._storage = storage
        self._host = host
        self._requested_port = port
        self._auth_token = auth_token
        self._auth_tokens = auth_tokens
        self._tls_cert = tls_cert
        self._tls_key = tls_key
        self._max_protocol = max_protocol
        self._server: _RPCServer | None = None
        self._thread: threading.Thread | None = None

    def start(self) -> "StorageServer":
        if self._server is not None:
            return self
        ssl_context = None
        if self._tls_cert is not None:
            ssl_context = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
            ssl_context.load_cert_chain(self._tls_cert, self._tls_key)
        self._server = _RPCServer(
            (self._host, self._requested_port), self._storage,
            auth_token=self._auth_token, auth_tokens=self._auth_tokens,
            ssl_context=ssl_context, max_protocol=self._max_protocol,
        )
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.1}, daemon=True
        )
        self._thread.start()
        return self

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_address[1]

    @property
    def tls(self) -> bool:
        return self._tls_cert is not None

    @property
    def url(self) -> str:
        scheme = "remote+tls" if self.tls else "remote"
        return f"{scheme}://{self.host}:{self.port}"

    def get_server_metrics(self) -> dict[str, Any]:
        """The live metrics surface (same payload the ``get_server_metrics``
        RPC returns to :class:`RemoteStorage` clients)."""
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_metrics()

    def stop(self) -> None:
        if self._server is None:
            return
        self._server.stopping.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._server.close()  # idempotent; covers a loop that died early
        self._server = None
        self._thread = None

    def __enter__(self) -> "StorageServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def main(argv: list[str] | None = None) -> None:
    """``python -m repro.core.storage.server sqlite:///study.db --port 9000``"""
    import argparse

    from . import get_storage

    ap = argparse.ArgumentParser(description="serve a storage backend over remote://")
    ap.add_argument("storage", help="backend URL to wrap (sqlite:/// or journal://)")
    ap.add_argument("--host", default="0.0.0.0")
    ap.add_argument("--port", type=int, default=9000)
    ap.add_argument(
        "--auth-token",
        default=os.environ.get("REPRO_STORAGE_TOKEN"),
        help="shared secret; clients connect with remote://TOKEN@host:port "
        "(default: $REPRO_STORAGE_TOKEN)",
    )
    ap.add_argument(
        "--readonly-token",
        default=None,
        help="additional shared secret granting read-only access",
    )
    ap.add_argument("--tls-cert", default=None, help="PEM certificate; enables TLS")
    ap.add_argument("--tls-key", default=None, help="PEM private key; enables TLS")
    ap.add_argument(
        "--max-protocol", type=int, default=2, choices=(1, 2),
        help="1 pins the wire to legacy JSON frames",
    )
    args = ap.parse_args(argv)

    auth_tokens = None
    if args.readonly_token:
        auth_tokens = [{"token": args.readonly_token, "readonly": True}]
    server = StorageServer(
        get_storage(args.storage), host=args.host, port=args.port,
        auth_token=args.auth_token, auth_tokens=auth_tokens,
        tls_cert=args.tls_cert, tls_key=args.tls_key,
        max_protocol=args.max_protocol,
    ).start()
    print(f"serving {args.storage} at {server.url} (ctrl-c to stop)", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        server.stop()


if __name__ == "__main__":
    main()
