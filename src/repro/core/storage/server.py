"""Networked storage server — the piece that turns "N processes on one box"
into "N workers on a fleet" (paper §4's scalable deployment criterion).

A :class:`StorageServer` wraps *any* :class:`BaseStorage` backend and exposes
it over TCP to :class:`~repro.core.storage.client.RemoteStorage` clients.

Protocol
--------
Every frame is a 4-byte big-endian payload length followed by the payload.
Two payload encodings share that framing, negotiated per connection:

* **v1 (JSON, default)** — UTF-8 JSON-RPC.  A request is ``{"id", "method",
  "params"}`` (params encoded with :mod:`.serde`); the response is ``{"id",
  "ok", "result"}`` or ``{"id", "ok": false, "error": {"type", "message"}}``.
  A frame may carry a *list* of requests (a batch); the server executes them
  in order and answers with a list of responses in the same frame — one
  round trip for a whole write-behind flush.

* **v2 (binary)** — negotiated via a ``hello`` RPC (sent as JSON; once the
  server acknowledges ``protocol: 2`` both directions switch).  Payloads are
  one ``0xB2`` magic byte followed by the tagged binary encoding of the same
  request/response dicts (:func:`.serde.bdumps`), whose native ``ndarray``
  tag lets the hot RPCs — ``get_all_trials(since=)`` deltas, batched
  ``create_new_trials``, and the columnar ``get_observation_block`` /
  ``get_iv_block`` snapshots — ship raw numpy buffers instead of JSON trial
  dicts.  Legacy JSON clients never send ``hello`` and keep working
  unchanged; a v2 client talking to a JSON-only server falls back to v1 on
  the hello error.

Concurrency: a single-threaded non-blocking event loop (``selectors``
reactor) with per-connection read/write buffers — no thread per connection,
so a 1k-worker storm costs the server zero GIL thrashing.  Atomicity of each
call (e.g. the WAITING->RUNNING compare-and-set in
``set_trial_state_values``) is delegated to the wrapped backend; since all
dispatch happens on the reactor thread, calls are additionally serialized at
the server.  A connection that violates the protocol (oversized length,
garbage payload, mid-frame stall) is dropped in isolation — the loop and
every other connection keep serving.  Graceful shutdown via
:meth:`StorageServer.stop` — pending responses are flushed, then sockets
close.

Security: ``auth_token`` arms the shared-secret first-frame handshake;
``auth_tokens`` adds *scoped* tokens (read-only and/or study-id allowlists)
whose violations surface as ``PermissionError``.  ``tls_cert``/``tls_key``
wrap the listener in TLS (clients connect via ``remote+tls://``).

Fault tolerance (see DESIGN.md "Cluster"): ``journal=True`` keeps a
replayable in-memory op journal of every write dispatch; a second server
started with ``replicate_from=<url>`` subscribes to that journal over the
ordinary wire protocol (``subscribe_ops``), replays each op into its own
backend, and acks the applied sequence number.  With
``sync_replication=True`` the primary *holds* a write's response until the
replica has acked the op — so any client-visible ack implies replica
durability, the invariant the chaos tests pin.  ``promote()`` turns a
replica into a primary under a bumped epoch; clients validate role + epoch
at connect time and refuse stale or unpromoted nodes.  A deterministic
:class:`~repro.core.storage.chaos.FaultInjector` can be attached to drop /
delay / black-hole frames or connections for chaos testing.
"""

from __future__ import annotations

import heapq
import hmac
import json
import os
import selectors
import socket
import ssl
import struct
import threading
import time
from collections import OrderedDict, deque
from typing import Any

from .. import telemetry
from ..exceptions import StorageUnavailableError
from ..frozen import TrialState
from .base import BaseStorage, get_trials_since
from .serde import BINARY_MAGIC, bdumps, bjoin, bloads, pack, unpack

__all__ = [
    "StorageServer",
    "OpJournal",
    "send_frame",
    "recv_frame",
    "MAX_FRAME_BYTES",
]

MAX_FRAME_BYTES = 64 * 1024 * 1024  # sanity cap on one frame
MID_FRAME_STALL_SECONDS = 30.0  # max time a peer may stall between bytes of one frame

# The RPC surface: exactly the BaseStorage API (plus ping for liveness).
_METHODS = frozenset(
    {
        "create_new_study",
        "delete_study",
        "get_study_id_from_name",
        "get_study_name_from_id",
        "get_study_directions",
        "get_all_studies",
        "set_study_user_attr",
        "set_study_system_attr",
        "get_study_user_attrs",
        "get_study_system_attrs",
        "create_new_trial",
        "create_new_trials",
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "report_and_prune",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "get_all_trials",
        "get_n_trials",
        "get_trial_id_from_study_and_number",
        "record_heartbeat",
        "get_stale_trial_ids",
        "fail_stale_trials",
        "reclaim_stale_trials",
        "get_trials_revision",
        "get_trial_events",
        "get_observation_block",
        "get_iv_block",
    }
)

# scope enforcement tables: which methods mutate, and how each method names
# the study it touches (first param is a study_id unless listed here)
_WRITE_METHODS = frozenset(
    {
        "create_new_study",
        "delete_study",
        "set_study_user_attr",
        "set_study_system_attr",
        "create_new_trial",
        "create_new_trials",
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "report_and_prune",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "record_heartbeat",
        "fail_stale_trials",
        "reclaim_stale_trials",
    }
)
_TRIAL_SCOPED = frozenset(
    {
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "record_heartbeat",
    }
)
# not addressable by one study id — denied outright for study-scoped tokens
_GLOBAL_SCOPED = frozenset({"create_new_study", "get_all_studies"})

# binary-only RPCs: their responses are raw-array blocks that have no JSON
# encoding; v1 clients get a typed NotImplementedError and fall back
_V2_ONLY = frozenset({"get_observation_block", "get_iv_block"})

# methods whose *retransmit* after a torn connection must not re-execute: the
# client stamps them with an ``op`` id, the server remembers the last
# _DEDUP_WINDOW results and answers a replayed frame from memory
_DEDUPED = frozenset(
    {
        "create_new_study",
        "create_new_trial",
        "create_new_trials",
        "set_trial_state_values",
        "report_and_prune",
    }
)
_DEDUP_WINDOW = 8192

# replication stream: ops per frame when pushing a backlog to a new subscriber
_OP_BACKLOG_CHUNK = 500


class OpJournal:
    """Replayable log of every write a server executed, in dispatch order.

    Each entry is ``(seq, op_id, method, params)`` where ``seq`` is the
    entry's index (the log sequence number — dense, starting at 0) and
    ``op_id`` the client's idempotency token (or None).  A replica replays
    entries in order into an empty backend of the same type; because every
    backend assigns study/trial ids deterministically (next-id counters,
    ``number == len(trials)``), the replica converges to bit-identical ids.

    Thread-safety: appends come from the primary's reactor thread, or from a
    replica's tail thread; reads (``since``) from the reactor — one lock.
    """

    __slots__ = ("_lock", "_ops")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._ops: list[tuple[int, "str | None", str, list]] = []

    @property
    def end_seq(self) -> int:
        with self._lock:
            return len(self._ops)

    def append(self, method: str, params: list, op_id: "str | None" = None) -> tuple:
        with self._lock:
            ent = (len(self._ops), op_id, method, params)
            self._ops.append(ent)
            return ent

    def append_at(self, seq: int, op_id: "str | None", method: str, params: list) -> None:
        """Replica-side append that preserves the primary's numbering.  A gap
        means the subscription missed ops — unrecoverable, so it raises."""
        with self._lock:
            if seq != len(self._ops):
                raise ValueError(
                    f"op journal gap: expected seq {len(self._ops)}, got {seq}"
                )
            self._ops.append((seq, op_id, method, params))

    def since(self, seq: int) -> list[tuple]:
        with self._lock:
            return list(self._ops[max(0, seq):])


# -- blocking frame helpers (used by the client; the server is non-blocking) --


def send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(struct.pack(">I", len(payload)) + payload)


def recv_frame(sock: socket.socket) -> bytes | None:
    """Read one length-prefixed frame; ``None`` on clean EOF.

    A ``socket.timeout`` escapes only while *idle* (no byte of the frame seen
    yet) — once a frame has started, reads are retried so a slow peer cannot
    cause a torn frame, but a peer that stalls longer than
    ``MID_FRAME_STALL_SECONDS`` without sending a single byte raises
    ``ConnectionError`` instead of hanging the caller forever.
    """
    header = _recv_exact(sock, 4, allow_idle_timeout=True)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {length} bytes exceeds cap {MAX_FRAME_BYTES}")
    body = _recv_exact(sock, length, allow_idle_timeout=False)
    if body is None:
        raise ConnectionError("connection closed mid-frame")
    return body


def _recv_exact(sock: socket.socket, n: int, allow_idle_timeout: bool) -> bytes | None:
    buf = b""
    stall_deadline: float | None = None
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if allow_idle_timeout and not buf:
                raise
            now = time.monotonic()
            if stall_deadline is None:
                stall_deadline = now + MID_FRAME_STALL_SECONDS
            elif now >= stall_deadline:
                raise ConnectionError(
                    f"peer stalled mid-frame for over {MID_FRAME_STALL_SECONDS}s"
                ) from None
            continue  # mid-frame: give the peer a bounded grace period
        stall_deadline = None  # any progress resets the stall clock
        if not chunk:
            if buf:
                raise ConnectionError("connection closed mid-frame")
            return None
        buf += chunk
    return buf


# -- auth scopes --------------------------------------------------------------


class _Scope:
    """Capabilities of one auth token: ``readonly`` blocks writes,
    ``studies`` (a frozenset of study ids, or None = all) bounds which
    studies the token may touch."""

    __slots__ = ("readonly", "studies")

    def __init__(self, readonly: bool = False, studies: "frozenset[int] | None" = None):
        self.readonly = readonly
        self.studies = studies

    @property
    def unrestricted(self) -> bool:
        return not self.readonly and self.studies is None


_FULL_SCOPE = _Scope()


def _normalize_tokens(auth_token, auth_tokens) -> list[tuple[str, _Scope]]:
    scopes: list[tuple[str, _Scope]] = []
    if auth_token is not None:
        scopes.append((auth_token, _FULL_SCOPE))
    for ent in auth_tokens or []:
        if isinstance(ent, str):
            scopes.append((ent, _FULL_SCOPE))
            continue
        studies = ent.get("studies")
        scopes.append(
            (
                ent["token"],
                _Scope(
                    readonly=bool(ent.get("readonly", False)),
                    studies=(
                        frozenset(int(s) for s in studies) if studies is not None else None
                    ),
                ),
            )
        )
    return scopes


# -- reactor ------------------------------------------------------------------


class _Drop(Exception):
    """Internal: close this connection (protocol violation or dead peer)."""


class _Conn:
    __slots__ = (
        "sock",
        "peer",
        "inbuf",
        "outbuf",
        "authed",
        "scope",
        "proto",
        "specs",
        "closing",
        "handshaking",
        "stall_deadline",
        "mask",
        "closed",
        "subscriber",
    )

    def __init__(self, sock, peer: str, authed: bool, handshaking: bool):
        self.sock = sock
        self.peer = peer
        self.inbuf = bytearray()
        self.outbuf = bytearray()
        self.authed = authed
        self.scope: "_Scope | None" = _FULL_SCOPE if authed else None
        self.proto = 1
        # per-connection interned pruner specs (client sends each spec once
        # as __spec_def__, then short __spec_ref__ frames; see client.py)
        self.specs: dict[int, dict] = {}
        self.closing = False  # reply flushed, then close (auth rejection)
        self.handshaking = handshaking  # TLS handshake in progress
        self.stall_deadline: "float | None" = (
            time.monotonic() + MID_FRAME_STALL_SECONDS if handshaking else None
        )
        self.mask = selectors.EVENT_READ
        self.closed = False
        self.subscriber = False  # receives the replication op stream


class _RPCServer:
    """The selectors-based reactor + dispatcher behind :class:`StorageServer`."""

    def __init__(
        self,
        addr: tuple[str, int],
        storage: BaseStorage,
        auth_token: "str | None" = None,
        auth_tokens: "list | None" = None,
        ssl_context: "ssl.SSLContext | None" = None,
        max_protocol: int = 2,
        journal: "OpJournal | None" = None,
        role: str = "primary",
        epoch: int = 1,
        sync_replication: bool = False,
        fault_injector: Any = None,
        reclaim_grace: "float | None" = None,
        reclaim_requeue: bool = False,
        reclaim_interval: float = 1.0,
    ):
        self.storage = storage
        self._scopes = _normalize_tokens(auth_token, auth_tokens)
        self.auth_required = bool(self._scopes)
        self.ssl_context = ssl_context
        self.max_protocol = max_protocol
        self.stopping = threading.Event()
        # -- cluster state ----------------------------------------------------
        self._journal = journal
        self.role = role  # "primary" accepts writes; "replica" refuses them
        self.epoch = int(epoch)
        self.sync_replication = sync_replication
        self.fault_injector = fault_injector
        self._tail_handle: Any = None  # set by StorageServer on replicas
        self._subscribers: set[_Conn] = set()
        self._acked_seq = 0  # highest journal seq a subscriber confirmed applied
        self._pending_acks: "deque[tuple[int, _Conn, bytes]]" = deque()
        self._dedup: "OrderedDict[str, Any]" = OrderedDict()
        self._dedup_lock = threading.Lock()
        self._delayed: list[tuple[float, int, _Conn, bytes]] = []  # fault-injected
        self._delay_counter = 0
        self._reclaim_grace = reclaim_grace
        self._reclaim_requeue = reclaim_requeue
        self._reclaim_interval = reclaim_interval
        self._last_reclaim = time.monotonic()
        self._kill = threading.Event()  # hard-stop: exit without flushing
        # always-on, server-owned registry: get_server_metrics must work
        # without globally enabling client-side telemetry in this process
        self.metrics = telemetry.MetricsRegistry(enabled=True)
        self.started_at = time.time()
        # trial_id -> study_id, maintained only when a study-scoped token
        # exists (enforcement needs it; unscoped servers skip the memory)
        self._track_trials = any(sc.studies is not None for _, sc in self._scopes)
        self._trial_study: dict[int, int] = {}

        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind(addr)
        listener.listen(128)
        listener.setblocking(False)
        self._listener = listener
        self.server_address = listener.getsockname()
        self._sel = selectors.DefaultSelector()
        self._conns: set[_Conn] = set()
        self._last_sweep = time.monotonic()
        self._closed = False

    # -- event loop -----------------------------------------------------------

    def serve_forever(self, poll_interval: float = 0.1) -> None:
        self._sel.register(self._listener, selectors.EVENT_READ, None)
        try:
            while not self.stopping.is_set():
                if self._kill.is_set():
                    break  # simulated crash: abandon everything in-flight
                for key, mask in self._sel.select(poll_interval):
                    if key.data is None:
                        self._accept()
                    else:
                        conn: _Conn = key.data
                        try:
                            self._service(conn, mask)
                        except _Drop:
                            self._close_conn(conn)
                        except Exception:
                            # one connection's failure must never kill the
                            # loop: drop it, keep serving everyone else
                            self.metrics.counter("server.protocol_errors").inc()
                            self._close_conn(conn)
                now = time.monotonic()
                if self._delayed and self._delayed[0][0] <= now:
                    self._flush_delayed(now)
                if now - self._last_sweep >= 1.0:
                    self._last_sweep = now
                    self._sweep_stalled(now)
                if (
                    self._reclaim_grace is not None
                    and self.role == "primary"
                    and now - self._last_reclaim >= self._reclaim_interval
                ):
                    self._last_reclaim = now
                    self._run_reclaim()
        finally:
            self.close(flush=not self._kill.is_set())

    def _flush_delayed(self, now: float) -> None:
        """Release fault-injector-delayed responses whose hold expired."""
        while self._delayed and self._delayed[0][0] <= now:
            _, _, conn, body = heapq.heappop(self._delayed)
            if not conn.closed:
                try:
                    self._send(conn, body)
                except _Drop:
                    self._close_conn(conn)

    def _run_reclaim(self) -> None:
        """Stale-RUNNING sweep: trials whose worker stopped heartbeating for
        ``reclaim_grace`` seconds are FAILed (or requeued as WAITING).  Runs
        on the reactor thread so the resulting state writes are journaled and
        streamed to replicas like any client write."""
        try:
            summaries = self.storage.get_all_studies()
        except Exception:
            return
        target = TrialState.WAITING if self._reclaim_requeue else TrialState.FAIL
        for s in summaries:
            try:
                tids = self.storage.reclaim_stale_trials(
                    s.study_id, self._reclaim_grace, requeue=self._reclaim_requeue
                )
            except Exception:
                continue
            if not tids:
                continue
            self.metrics.counter("server.reclaimed_trials").inc(len(tids))
            if self._journal is not None:
                ents = [
                    self._journal.append("set_trial_state_values", [tid, target, None])
                    for tid in tids
                ]
                self._stream_ops(ents)

    def close(self, flush: bool = True) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in list(self._conns):
            if flush and conn.outbuf and not conn.handshaking and not conn.closed:
                # best-effort flush of pending responses on graceful shutdown
                try:
                    conn.sock.setblocking(True)
                    conn.sock.settimeout(1.0)
                    conn.sock.sendall(bytes(conn.outbuf))
                except Exception:
                    pass
            self._close_conn(conn)
        try:
            self._sel.close()
        except Exception:
            pass
        try:
            self._listener.close()
        except OSError:
            pass

    def _accept(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            fi = self.fault_injector
            if fi is not None and fi.on_accept():
                self.metrics.counter("server.faults.dropped_connects").inc()
                try:
                    sock.close()
                except OSError:
                    pass
                continue
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            handshaking = False
            if self.ssl_context is not None:
                try:
                    sock = self.ssl_context.wrap_socket(
                        sock, server_side=True, do_handshake_on_connect=False
                    )
                except (ssl.SSLError, OSError):
                    try:
                        sock.close()
                    except OSError:
                        pass
                    continue
                handshaking = True
            conn = _Conn(
                sock, "%s:%s" % addr[:2], authed=not self.auth_required,
                handshaking=handshaking,
            )
            try:
                self._sel.register(sock, selectors.EVENT_READ, conn)
            except (ValueError, KeyError, OSError):
                try:
                    sock.close()
                except OSError:
                    pass
                continue
            self._conns.add(conn)
            self.metrics.gauge("server.active_connections").add(1)

    def _service(self, conn: _Conn, mask: int) -> None:
        if conn.closed:
            return
        if conn.handshaking:
            self._tls_handshake(conn)
            return
        if mask & selectors.EVENT_READ:
            self._read(conn)
        if not conn.closed and (mask & selectors.EVENT_WRITE):
            self._write(conn)

    def _tls_handshake(self, conn: _Conn) -> None:
        try:
            conn.sock.do_handshake()
        except ssl.SSLWantReadError:
            self._set_mask(conn, selectors.EVENT_READ)
            return
        except ssl.SSLWantWriteError:
            self._set_mask(conn, selectors.EVENT_WRITE)
            return
        except (ssl.SSLError, OSError):
            raise _Drop from None
        conn.handshaking = False
        conn.stall_deadline = None
        self._set_mask(conn, selectors.EVENT_READ)
        # app data may have arrived piggybacked on the final handshake flight
        self._read(conn)

    def _read(self, conn: _Conn) -> None:
        while True:
            try:
                chunk = conn.sock.recv(1 << 16)
            except (ssl.SSLWantReadError, ssl.SSLWantWriteError):
                break
            except (BlockingIOError, InterruptedError):
                break
            except (ConnectionError, OSError, ssl.SSLError):
                raise _Drop from None
            if not chunk:
                raise _Drop  # EOF
            conn.inbuf += chunk
            conn.stall_deadline = None  # progress resets the stall clock
            if len(conn.inbuf) > MAX_FRAME_BYTES + 4:
                break  # let frame parsing catch up before buffering more
        self._process_inbuf(conn)

    def _process_inbuf(self, conn: _Conn) -> None:
        inbuf = conn.inbuf
        while not conn.closed and not conn.closing:
            if len(inbuf) < 4:
                break
            length = int.from_bytes(inbuf[:4], "big")
            if length > MAX_FRAME_BYTES:
                # oversized length header: unrecoverable framing state
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop
            if len(inbuf) < 4 + length:
                break
            payload = bytes(memoryview(inbuf)[4 : 4 + length])
            del inbuf[: 4 + length]
            self._handle_frame(conn, payload)
        if conn.closed:
            return
        if inbuf and conn.stall_deadline is None:
            # partial frame pending: the peer gets a bounded grace period
            conn.stall_deadline = time.monotonic() + MID_FRAME_STALL_SECONDS

    def _handle_frame(self, conn: _Conn, payload: bytes) -> None:
        self.metrics.counter("server.frames_in").inc()
        self.metrics.counter("server.bytes_in").inc(len(payload))
        if not conn.authed:
            self._handle_auth(conn, payload)
            return
        proto = conn.proto
        if proto == 2:
            if not payload or payload[0] != BINARY_MAGIC:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop
            try:
                request = bloads(memoryview(payload)[1:])
            except Exception:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop from None
        else:
            try:
                request = json.loads(payload)
            except json.JSONDecodeError:
                self.metrics.counter("server.protocol_errors").inc()
                raise _Drop from None
        if isinstance(request, dict) and "__ack_ops__" in request:
            # one-way replication ack from a subscriber — no response frame
            self._on_ack(int(request["__ack_ops__"]))
            return
        # chaos faults target *client* RPC frames only: replication-internal
        # traffic (subscriber acks above, subscriber RPCs below) is exempt so
        # an armed count lands on the frame the test aimed at
        fault = None
        fi = self.fault_injector
        if fi is not None and not conn.subscriber:
            fault = fi.on_frame()
            if fault == "drop_conn":
                self.metrics.counter("server.faults.dropped_conns").inc()
                raise _Drop
        batch = isinstance(request, list)
        seq0 = self._journal.end_seq if self._journal is not None else 0
        t0 = time.perf_counter()
        # events the wrapped backend records during dispatch carry the
        # *client* identity, so a fleet-wide trace attributes work to workers
        telemetry.set_worker_context(conn.peer)
        hello_proto = None
        subscribe_since: "int | None" = None
        try:
            encoded: list[bytes] = []
            for r in request if batch else [request]:
                response, blob = self.dispatch(
                    r, conn.specs, scope=conn.scope, proto=proto
                )
                encoded.append(blob)
                if not batch and isinstance(r, dict) and response.get("ok"):
                    m = r.get("method")
                    if m == "hello":
                        hello_proto = response["result"]["protocol"]
                    elif m == "subscribe_ops":
                        p = r.get("params") or []
                        subscribe_since = int(p[0]) if p else 0
        finally:
            telemetry.set_worker_context(None)
        if batch:
            # responses were serialized one by one (for per-method byte
            # accounting); assemble the batch frame compositionally instead
            # of re-serializing the whole list
            if proto == 2:
                body = bytes([BINARY_MAGIC]) + bjoin(encoded)
            else:
                body = b"[" + b",".join(encoded) + b"]"
            # the whole-frame view of a batched flush (tell_batch, the
            # write-behind drain): per-op latencies are recorded by dispatch;
            # this row pins the envelope cost clients feel
            self._note_rpc("batch", t0, len(body))
            self.metrics.counter("server.batched_ops").inc(len(encoded))
        else:
            body = (bytes([BINARY_MAGIC]) + encoded[0]) if proto == 2 else encoded[0]
        if fault == "blackhole":
            # the request *executed*; the response evaporates — exactly the
            # double-tell scenario the op-id dedup window must absorb
            self.metrics.counter("server.faults.blackholed_frames").inc()
        elif isinstance(fault, tuple) and fault[0] == "delay":
            self.metrics.counter("server.faults.delayed_frames").inc()
            self._delay_counter += 1
            heapq.heappush(
                self._delayed,
                (time.monotonic() + float(fault[1]), self._delay_counter, conn, body),
            )
        elif self._hold_for_ack(conn, body, seq0):
            pass  # semi-sync replication: released by the replica's ack
        else:
            self._send(conn, body)
        if hello_proto == 2:
            conn.proto = 2  # every later frame on this connection is binary
        if subscribe_since is not None:
            self._add_subscriber(conn, subscribe_since)

    def _handle_auth(self, conn: _Conn, payload: bytes) -> None:
        # the auth handshake is always JSON, whatever gets negotiated later
        try:
            request = json.loads(payload)
        except json.JSONDecodeError:
            self.metrics.counter("server.protocol_errors").inc()
            raise _Drop from None
        scope = self._auth_scope(request)
        if scope is not None:
            conn.authed = True
            conn.scope = scope
            response = {"id": request.get("id"), "ok": True, "result": "ok"}
        else:
            self.metrics.counter("server.auth_failures").inc()
            self.metrics.counter("server.auth_failures.bad_token").inc()
            response = {
                "id": request.get("id") if isinstance(request, dict) else None,
                "ok": False,
                "error": {
                    "type": "PermissionError",
                    "message": "storage server requires an auth token",
                },
            }
            conn.closing = True  # reply, flush, drop
        self._send(conn, json.dumps(response).encode())

    def _auth_scope(self, request: Any) -> "_Scope | None":
        if not isinstance(request, dict) or request.get("method") != "auth":
            return None
        params = request.get("params")
        if not isinstance(params, list) or len(params) != 1 or not isinstance(params[0], str):
            return None
        for token, scope in self._scopes:
            if hmac.compare_digest(params[0], token):
                return scope
        return None

    def _send(self, conn: _Conn, body: bytes) -> None:
        self.metrics.counter("server.frames_out").inc()
        self.metrics.counter("server.bytes_out").inc(len(body))
        conn.outbuf += struct.pack(">I", len(body))
        conn.outbuf += body
        self._write(conn)

    def _write(self, conn: _Conn) -> None:
        while conn.outbuf:
            try:
                n = conn.sock.send(memoryview(conn.outbuf))
            except (ssl.SSLWantWriteError, ssl.SSLWantReadError):
                break
            except (BlockingIOError, InterruptedError):
                break
            except (ConnectionError, OSError, ssl.SSLError):
                raise _Drop from None
            if n == 0:
                break
            del conn.outbuf[:n]
        if conn.outbuf:
            self._set_mask(conn, selectors.EVENT_READ | selectors.EVENT_WRITE)
            if conn.stall_deadline is None:
                # a peer that never drains its responses is as dead as one
                # that stalls mid-frame
                conn.stall_deadline = time.monotonic() + MID_FRAME_STALL_SECONDS
        else:
            self._set_mask(conn, selectors.EVENT_READ)
            if conn.closing:
                self._close_conn(conn)

    def _set_mask(self, conn: _Conn, mask: int) -> None:
        if mask != conn.mask and not conn.closed:
            try:
                self._sel.modify(conn.sock, mask, conn)
                conn.mask = mask
            except (ValueError, KeyError, OSError):
                raise _Drop from None

    def _sweep_stalled(self, now: float) -> None:
        for conn in list(self._conns):
            if conn.stall_deadline is not None and now >= conn.stall_deadline:
                self.metrics.counter("server.stalled_connections").inc()
                self._close_conn(conn)

    def _close_conn(self, conn: _Conn) -> None:
        if conn.closed:
            return
        conn.closed = True
        try:
            self._sel.unregister(conn.sock)
        except (ValueError, KeyError, OSError):
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self._conns.discard(conn)
        self.metrics.gauge("server.active_connections").add(-1)
        if conn.subscriber:
            self._subscribers.discard(conn)
            self.metrics.gauge("server.replication.subscribers").add(-1)
            if not self._subscribers and self._pending_acks:
                # the replica is gone: degrade to async rather than wedging
                # every client behind acks that will never come
                self.metrics.counter("server.replication.degraded").inc()
                self._release_pending_acks(force=True)

    # -- replication ----------------------------------------------------------

    def _add_subscriber(self, conn: _Conn, since: int) -> None:
        """Register a replica's op-stream subscription and push the backlog."""
        conn.subscriber = True
        self._subscribers.add(conn)
        self.metrics.gauge("server.replication.subscribers").add(1)
        if self._journal is None:
            return
        backlog = self._journal.since(since)
        for i in range(0, len(backlog), _OP_BACKLOG_CHUNK):
            self._push_op_frame(conn, backlog[i : i + _OP_BACKLOG_CHUNK])

    def _stream_ops(self, ents: list[tuple]) -> None:
        """Push freshly journaled ops to every live subscriber."""
        if not self._subscribers:
            return
        for conn in list(self._subscribers):
            if conn.closed:
                continue
            try:
                self._push_op_frame(conn, ents)
            except _Drop:
                self._close_conn(conn)

    def _push_op_frame(self, conn: _Conn, ents: list[tuple]) -> None:
        if conn.proto == 2:
            body = bytes([BINARY_MAGIC]) + bdumps(
                {"__op_stream__": [list(e) for e in ents], "epoch": self.epoch}
            )
        else:
            wire = [[seq, op_id, method, pack(params)] for seq, op_id, method, params in ents]
            body = json.dumps({"__op_stream__": wire, "epoch": self.epoch}).encode()
        self.metrics.counter("server.replication.streamed_ops").inc(len(ents))
        self._send(conn, body)

    def _hold_for_ack(self, conn: _Conn, body: bytes, seq0: int) -> bool:
        """Semi-synchronous replication: when this frame journaled new ops and
        a subscriber is attached, the response is parked until the replica
        acks the journal suffix — a client-visible ack then implies the op
        survives a primary crash."""
        if not self.sync_replication or self._journal is None or conn.subscriber:
            return False
        if not self._subscribers:
            return False
        need = self._journal.end_seq
        if need <= seq0 or need <= self._acked_seq:
            return False
        self._pending_acks.append((need, conn, body))
        self.metrics.counter("server.replication.held_responses").inc()
        return True

    def _on_ack(self, seq: int) -> None:
        if seq > self._acked_seq:
            self._acked_seq = seq
            self.metrics.counter("server.replication.acks").inc()
        self._release_pending_acks()

    def _release_pending_acks(self, force: bool = False) -> None:
        while self._pending_acks and (force or self._pending_acks[0][0] <= self._acked_seq):
            _, conn, body = self._pending_acks.popleft()
            if conn.closed:
                continue
            try:
                self._send(conn, body)
            except _Drop:
                self._close_conn(conn)

    def _dedup_lookup(self, op_id: str) -> tuple[bool, Any]:
        with self._dedup_lock:
            if op_id in self._dedup:
                return True, self._dedup[op_id]
            return False, None

    def _dedup_store(self, op_id: str, result: Any) -> None:
        with self._dedup_lock:
            self._dedup[op_id] = result
            while len(self._dedup) > _DEDUP_WINDOW:
                self._dedup.popitem(last=False)

    def _journal_write(
        self, method: str, params: list, result: Any, op_id: "str | None"
    ) -> None:
        """Append a successful write dispatch to the op journal in its
        *replayable* form, and stream it to subscribers.  Fused and sweep ops
        are decomposed into the primitive writes a replica can re-execute."""
        entries: list[tuple["str | None", str, list]] = []
        if method == "report_and_prune":
            # only the value write mutates state; the prune decision is a
            # read the replica re-derives from its own peer data
            entries.append(
                (op_id, "set_trial_intermediate_value",
                 [params[1], int(params[2]), float(params[3])])
            )
        elif method in ("fail_stale_trials", "reclaim_stale_trials"):
            requeue = (
                method == "reclaim_stale_trials"
                and len(params) > 2
                and bool(params[2])
            )
            target = TrialState.WAITING if requeue else TrialState.FAIL
            for tid in result or []:
                entries.append((None, "set_trial_state_values", [tid, target, None]))
        else:
            entries.append((op_id, method, list(params)))
        ents = [self._journal.append(m, p, oid) for oid, m, p in entries]
        if ents:
            self._stream_ops(ents)

    def promote(self, epoch: "int | None" = None) -> dict[str, Any]:
        """Replica → primary under a bumped epoch.  Safe to call on a node
        that is already primary (idempotent)."""
        if self.role != "primary":
            tail = self._tail_handle
            if epoch is None:
                seen = getattr(tail, "seen_epoch", 0) if tail is not None else 0
                epoch = max(seen, self.epoch) + 1
            if tail is not None:
                tail.stop(join=False)
            self.role = "primary"
            self.epoch = int(epoch)
            self.metrics.counter("server.promotions").inc()
        return {
            "role": self.role,
            "epoch": self.epoch,
            "seq": self._journal.end_seq if self._journal is not None else 0,
        }

    def cluster_info(self) -> dict[str, Any]:
        return {
            "role": self.role,
            "epoch": self.epoch,
            "dedup": True,
            "seq": self._journal.end_seq if self._journal is not None else 0,
        }

    # -- dispatch -------------------------------------------------------------

    def dispatch(
        self,
        request: Any,
        conn_specs: "dict[int, dict] | None" = None,
        scope: "_Scope | None" = None,
        proto: int = 1,
    ) -> tuple[dict, bytes]:
        """Execute one RPC; returns ``(response, encoded_response)``.

        The response is serialized exactly once — the returned bytes are both
        the wire payload and the per-method byte-accounting sample."""
        enc = self._enc_json if proto == 1 else self._enc_bin
        if not isinstance(request, dict):
            request = {}
        req_id = request.get("id")
        method = request.get("method")
        t0 = time.perf_counter()
        try:
            if method == "ping":
                response = {"id": req_id, "ok": True, "result": "pong"}
                return response, enc(response)
            if method == "auth":
                # reaching dispatch means no token is required (or the
                # connection already authenticated); accept idempotently
                response = {"id": req_id, "ok": True, "result": "ok"}
                return response, enc(response)
            if method == "hello" and self.max_protocol >= 2:
                response = {"id": req_id, "ok": True, "result": self._hello(request)}
                return response, enc(response)
            if method == "get_server_metrics":
                response = {"id": req_id, "ok": True, "result": self.server_metrics()}
                return response, enc(response)
            if method == "get_cluster_info":
                response = {"id": req_id, "ok": True, "result": self.cluster_info()}
                return response, enc(response)
            if method == "promote":
                p = request.get("params") or []
                response = {
                    "id": req_id, "ok": True,
                    "result": self.promote(int(p[0]) if p and p[0] is not None else None),
                }
                return response, enc(response)
            if method == "subscribe_ops":
                if self._journal is None:
                    raise ValueError(
                        "replication requires a server started with journal=True"
                    )
                response = {
                    "id": req_id, "ok": True,
                    "result": {"epoch": self.epoch, "end_seq": self._journal.end_seq},
                }
                return response, enc(response)
            if method not in _METHODS:
                raise ValueError(f"unknown storage method {method!r}")
            params = request.get("params") or []
            if proto == 1:
                params = unpack(params)
            if method == "report_and_prune":
                spec = params[4] if len(params) > 4 and isinstance(params[4], dict) else None
                if spec is not None and "__spec_ref__" in spec:
                    self.metrics.counter("server.spec_cache.hits").inc()
                elif spec is not None and "__spec_def__" in spec:
                    self.metrics.counter("server.spec_cache.defs").inc()
                params = _resolve_spec(params, conn_specs)
            self._check_scope(method, params, scope)
            if method in _V2_ONLY and proto == 1:
                raise NotImplementedError(f"{method} requires wire protocol v2")
            op_id = request.get("op")
            if op_id is not None and method in _DEDUPED:
                hit, cached = self._dedup_lookup(op_id)
                if hit:
                    # retransmitted frame: answer from the dedup window — the
                    # original execution already happened (here, or on the
                    # primary this node replicated before promotion)
                    self.metrics.counter("server.dedup.hits").inc()
                    response = {
                        "id": req_id, "ok": True,
                        "result": pack(cached) if proto == 1 else cached,
                    }
                    blob = enc(response)
                    self._note_rpc(method, t0, len(blob))
                    return response, blob
            if self.role != "primary" and method in _WRITE_METHODS:
                raise StorageUnavailableError(
                    f"node is a replica (epoch {self.epoch}); writes need the primary"
                )
            result = self._invoke(method, params)
            if op_id is not None and method in _DEDUPED:
                self._dedup_store(op_id, result)
            if self._journal is not None and method in _WRITE_METHODS:
                self._journal_write(method, params, result, op_id)
            if self._track_trials:
                self._note_trial_ids(method, params, result)
            response = {
                "id": req_id,
                "ok": True,
                "result": pack(result) if proto == 1 else result,
            }
            # an unserializable result must become a typed error frame, not a
            # dropped connection (the client would silently retry + misreport)
            blob = enc(response)
            self._note_rpc(method, t0, len(blob))
            return response, blob
        except Exception as e:  # every failure maps to a typed client-side raise
            self._note_rpc(method, t0, 0, error=True)
            response = {
                "id": req_id,
                "ok": False,
                "error": {"type": type(e).__name__, "message": str(e)},
            }
            try:
                return response, enc(response)
            except Exception:  # pragma: no cover - unserializable error text
                response = {
                    "id": req_id,
                    "ok": False,
                    "error": {"type": "StorageInternalError", "message": "dispatch failed"},
                }
                return response, enc(response)

    @staticmethod
    def _enc_json(response: dict) -> bytes:
        return json.dumps(response).encode()

    @staticmethod
    def _enc_bin(response: dict) -> bytes:
        return bdumps(response)

    def _hello(self, request: dict) -> dict:
        params = request.get("params") or []
        want = 2
        if params and isinstance(params[0], dict):
            want = int(params[0].get("protocol", 2))
        # cluster extras piggyback on the negotiation so a failover-aware
        # client validates role/epoch without an extra round trip
        return {
            "protocol": max(1, min(want, self.max_protocol, 2)),
            "role": self.role,
            "epoch": self.epoch,
            "dedup": True,
        }

    def _check_scope(self, method: str, params: list, scope: "_Scope | None") -> None:
        if scope is None or scope.unrestricted:
            return
        if scope.readonly and method in _WRITE_METHODS:
            self._auth_failure("readonly")
            raise PermissionError(f"token is read-only; {method!r} is a write")
        studies = scope.studies
        if studies is None:
            return
        if method in _GLOBAL_SCOPED:
            self._auth_failure("study_scope")
            raise PermissionError(
                f"token is study-scoped; {method!r} is not study-addressable"
            )
        if method == "get_study_id_from_name":
            # resolve first: the id mapping itself is what the scope protects
            sid = self.storage.get_study_id_from_name(params[0])
        elif method in _TRIAL_SCOPED:
            sid = self._study_of_trial(int(params[0]), studies)
        else:
            sid = int(params[0])
        if sid not in studies:
            self._auth_failure("study_scope")
            raise PermissionError(f"token is not scoped to study {sid}")

    def _auth_failure(self, cause: str) -> None:
        self.metrics.counter("server.auth_failures").inc()
        self.metrics.counter(f"server.auth_failures.{cause}").inc()

    def _study_of_trial(self, trial_id: int, studies: "frozenset[int]") -> int:
        """Resolve a trial-addressed call to its study for scope checks: the
        map fills from create dispatches; unknown ids (trials created by
        another connection) fall back to one scan of the allowed studies."""
        sid = self._trial_study.get(trial_id)
        if sid is None:
            for s in sorted(studies):
                try:
                    for t in self.storage.get_all_trials(s, deepcopy=False):
                        self._trial_study.setdefault(t.trial_id, s)
                except Exception:
                    continue
            sid = self._trial_study.get(trial_id)
        if sid is None:
            self._auth_failure("study_scope")
            raise PermissionError(
                f"trial {trial_id} is outside this token's study scope"
            )
        return sid

    def _note_trial_ids(self, method: str, params: list, result: Any) -> None:
        if method == "create_new_trial" and isinstance(result, int):
            self._trial_study[result] = int(params[0])
        elif method == "create_new_trials" and isinstance(result, list):
            sid = int(params[0])
            for tid in result:
                self._trial_study[tid] = sid
        elif method == "get_trial_id_from_study_and_number" and isinstance(result, int):
            self._trial_study[result] = int(params[0])

    def _note_rpc(self, method: Any, t0: float, nbytes: int, error: bool = False) -> None:
        name = method if isinstance(method, str) else "invalid"
        self.metrics.counter(f"server.rpc.{name}.calls").inc()
        self.metrics.histogram(f"server.rpc.{name}").observe(time.perf_counter() - t0)
        if nbytes:
            self.metrics.counter(f"server.rpc.{name}.bytes_out").inc(nbytes)
        if error:
            self.metrics.counter(f"server.rpc.{name}.errors").inc()

    def server_metrics(self) -> dict[str, Any]:
        """JSON-safe metrics surface: per-method call counts / latency
        percentiles / bytes plus connection- and cache-level counters."""
        snap = self.metrics.snapshot()
        counters = snap["counters"]
        methods: dict[str, Any] = {}
        for name, h in snap["histograms"].items():
            if not name.startswith("server.rpc."):
                continue
            m = name[len("server.rpc."):]
            methods[m] = {
                "calls": counters.get(f"server.rpc.{m}.calls", 0),
                "errors": counters.get(f"server.rpc.{m}.errors", 0),
                "bytes_out": counters.get(f"server.rpc.{m}.bytes_out", 0),
                **{k: h[k] for k in ("count", "mean", "p50", "p95", "p99", "max")},
            }
        return {
            "uptime_s": time.time() - self.started_at,
            "active_connections": snap["gauges"].get("server.active_connections", 0),
            "auth_failures": counters.get("server.auth_failures", 0),
            "auth_failures_by_cause": {
                "bad_token": counters.get("server.auth_failures.bad_token", 0),
                "readonly": counters.get("server.auth_failures.readonly", 0),
                "study_scope": counters.get("server.auth_failures.study_scope", 0),
            },
            "protocol_errors": counters.get("server.protocol_errors", 0),
            "stalled_connections": counters.get("server.stalled_connections", 0),
            "frames_in": counters.get("server.frames_in", 0),
            "frames_out": counters.get("server.frames_out", 0),
            "bytes_in": counters.get("server.bytes_in", 0),
            "bytes_out": counters.get("server.bytes_out", 0),
            "spec_cache_hits": counters.get("server.spec_cache.hits", 0),
            "spec_cache_defs": counters.get("server.spec_cache.defs", 0),
            "batched_ops": counters.get("server.batched_ops", 0),
            "reclaimed_trials": counters.get("server.reclaimed_trials", 0),
            "dedup_hits": counters.get("server.dedup.hits", 0),
            "faults": {
                "dropped_connects": counters.get("server.faults.dropped_connects", 0),
                "dropped_conns": counters.get("server.faults.dropped_conns", 0),
                "blackholed_frames": counters.get("server.faults.blackholed_frames", 0),
                "delayed_frames": counters.get("server.faults.delayed_frames", 0),
            },
            "replication": {
                "role": self.role,
                "epoch": self.epoch,
                "seq": self._journal.end_seq if self._journal is not None else 0,
                "acked_seq": self._acked_seq,
                "subscribers": snap["gauges"].get("server.replication.subscribers", 0),
                "streamed_ops": counters.get("server.replication.streamed_ops", 0),
                "applied_ops": counters.get("server.replication.applied_ops", 0),
                "held_responses": counters.get("server.replication.held_responses", 0),
                "degraded": counters.get("server.replication.degraded", 0),
                "promotions": counters.get("server.promotions", 0),
                "reconnects": counters.get("server.replication.reconnects", 0),
            },
            "methods": methods,
        }

    def _invoke(self, method: str, params: list[Any]) -> Any:
        if method in ("get_all_trials", "get_n_trials"):
            # states arrives as a wire list; the API takes a tuple
            if method == "get_all_trials":
                study_id, deepcopy, states, since = params
                states = tuple(states) if states is not None else None
                if since is not None:
                    return get_trials_since(
                        self.storage, study_id, since, deepcopy=deepcopy, states=states
                    )
                return self.storage.get_all_trials(study_id, deepcopy=deepcopy, states=states)
            if method == "get_n_trials":
                study_id, states = params
                states = tuple(states) if states is not None else None
                return self.storage.get_n_trials(study_id, states=states)
        return getattr(self.storage, method)(*params)


def _resolve_spec(params: list, conn_specs: "dict[int, dict] | None") -> list:
    """Resolve the pruner-spec param of a fused report: a ``__spec_def__``
    envelope registers the full spec in this connection's cache, a
    ``__spec_ref__`` looks one up, and a raw spec dict (older clients, or
    in-process dispatch without connection state) passes through untouched."""
    if len(params) < 5 or not isinstance(params[4], dict):
        return params
    spec = params[4]
    if "__spec_def__" in spec:
        ent = spec["__spec_def__"]
        params = list(params)
        params[4] = ent["spec"]
        if conn_specs is not None:
            conn_specs[int(ent["id"])] = ent["spec"]
        return params
    if "__spec_ref__" in spec:
        ref = int(spec["__spec_ref__"])
        if conn_specs is None or ref not in conn_specs:
            raise ValueError(
                f"unknown pruner spec ref {ref} (connection lost its spec cache)"
            )
        params = list(params)
        params[4] = conn_specs[ref]
        return params
    return params


class _ReplicaTail:
    """Background thread on a replica: subscribes to the primary's op stream,
    replays every op into the local backend (preserving the primary's journal
    numbering and dedup window), and acks the applied sequence so a semi-sync
    primary can release held client responses.  Reconnects with jittered
    exponential backoff; ``stop()`` unblocks the socket and ends the loop."""

    def __init__(
        self,
        server: _RPCServer,
        host: str,
        port: int,
        auth_token: "str | None" = None,
        protocol: int = 2,
    ):
        self._server = server
        self._host = host
        self._port = port
        self._auth_token = auth_token
        self._protocol = protocol
        self.applied = server._journal.end_seq  # next seq we expect
        self.seen_epoch = 0  # highest primary epoch observed on the stream
        self._stop = threading.Event()
        self._sock: "socket.socket | None" = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._next_id = 0

    def start(self) -> "_ReplicaTail":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        sock = self._sock
        if sock is not None:
            try:
                sock.close()  # unblock a recv in progress
            except OSError:
                pass
        if join and self._thread.is_alive() and threading.current_thread() is not self._thread:
            self._thread.join(timeout=5.0)

    def _req_id(self) -> int:
        self._next_id += 1
        return self._next_id

    def _run(self) -> None:
        import random

        rng = random.Random(id(self) & 0xFFFF)
        attempt = 0
        while not self._stop.is_set():
            try:
                self._connect_and_tail()
                attempt = 0
            except Exception:
                if self._stop.is_set():
                    break
                self._server.metrics.counter("server.replication.reconnects").inc()
                attempt += 1
                delay = min(1.0, 0.05 * (2 ** min(attempt, 5))) * (0.5 + rng.random())
                self._stop.wait(delay)

    def _rpc(self, sock: socket.socket, proto: int, method: str, params: list) -> Any:
        request = {"id": self._req_id(), "method": method, "params": params}
        if proto == 2:
            send_frame(sock, bytes([BINARY_MAGIC]) + bdumps(request))
        else:
            send_frame(sock, json.dumps({**request, "params": pack(params)}).encode())
        body = self._recv(sock)
        if body is None:
            raise ConnectionError("primary closed during rpc")
        response = bloads(memoryview(body)[1:]) if proto == 2 else json.loads(body)
        if not response.get("ok"):
            err = response.get("error") or {}
            raise ConnectionError(f"primary rejected {method}: {err.get('message')}")
        result = response.get("result")
        return result if proto == 2 else unpack(result)

    def _recv(self, sock: socket.socket) -> "bytes | None":
        """recv_frame that treats idle timeouts as 'check the stop flag'."""
        while True:
            try:
                return recv_frame(sock)
            except socket.timeout:
                if self._stop.is_set():
                    raise ConnectionError("tail stopped") from None

    def _connect_and_tail(self) -> None:
        sock = socket.create_connection((self._host, self._port), timeout=5.0)
        self._sock = sock
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.settimeout(1.0)  # poll the stop flag while idle
            if self._auth_token is not None:
                request = {"id": self._req_id(), "method": "auth", "params": [self._auth_token]}
                send_frame(sock, json.dumps(request).encode())
                body = self._recv(sock)
                if body is None or not json.loads(body).get("ok"):
                    raise ConnectionError("replication auth rejected")
            proto = 1
            if self._protocol >= 2:
                request = {"id": self._req_id(), "method": "hello", "params": [{"protocol": 2}]}
                send_frame(sock, json.dumps(request).encode())
                body = self._recv(sock)
                if body is None:
                    raise ConnectionError("primary closed during hello")
                response = json.loads(body)
                if response.get("ok") and int(response["result"].get("protocol", 1)) >= 2:
                    proto = 2
            sub = self._rpc(sock, proto, "subscribe_ops", [self.applied])
            self.seen_epoch = max(self.seen_epoch, int(sub.get("epoch", 0)))
            while not self._stop.is_set():
                body = self._recv(sock)
                if body is None:
                    raise ConnectionError("primary closed the op stream")
                if proto == 2:
                    msg = bloads(memoryview(body)[1:])
                else:
                    msg = json.loads(body)
                ops = msg.get("__op_stream__") if isinstance(msg, dict) else None
                if ops is None:
                    continue  # not an op frame; ignore
                self.seen_epoch = max(self.seen_epoch, int(msg.get("epoch", 0)))
                for seq, op_id, method, params in ops:
                    if proto == 1:
                        params = unpack(params)
                    self._apply(int(seq), op_id, method, params)
                # ack the whole frame at once: one frame back per frame in
                ack = {"__ack_ops__": self.applied}
                if proto == 2:
                    send_frame(sock, bytes([BINARY_MAGIC]) + bdumps(ack))
                else:
                    send_frame(sock, json.dumps(ack).encode())
        finally:
            self._sock = None
            try:
                sock.close()
            except OSError:
                pass

    def _apply(self, seq: int, op_id: "str | None", method: str, params: list) -> None:
        if seq < self.applied:
            return  # overlap with an already-replayed backlog
        srv = self._server
        try:
            result = getattr(srv.storage, method)(*params)
        except Exception:
            # a replayed op must never kill the tail; record and move on
            result = None
            srv.metrics.counter("server.replication.apply_errors").inc()
        srv._journal.append_at(seq, op_id, method, params)
        if op_id is not None:
            # a retransmit that lands here after promotion answers from this
            # window; None (e.g. a decomposed fused report) degrades to a
            # conservative falsy result
            srv._dedup_store(op_id, result if result is not None else False)
        self.applied = seq + 1
        srv.metrics.counter("server.replication.applied_ops").inc()


class StorageServer:
    """Serve a storage backend over TCP.

    >>> server = StorageServer(SQLiteStorage("study.db")).start()
    >>> server.url          # hand this to workers on other machines
    'remote://10.0.0.5:38211'
    >>> server.stop()

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    Usable as a context manager.

    ``auth_token`` arms a shared-secret handshake: every connection must
    present the token in its first frame (``RemoteStorage`` does this
    automatically for ``remote://token@host:port`` URLs or an explicit
    ``auth_token=``) or it is rejected with ``PermissionError`` and dropped.
    ``auth_tokens`` adds *scoped* tokens — dicts of ``{"token": str,
    "readonly": bool, "studies": [ids] | None}`` — whose violations raise
    ``PermissionError`` on the offending call (the connection survives).

    ``tls_cert``/``tls_key`` (PEM paths) wrap every connection in TLS;
    clients then connect via ``remote+tls://host:port`` (authentication
    still runs inside the encrypted channel).  Without TLS the wire is
    plaintext — run inside a trusted network or tunnel for confidentiality.

    ``max_protocol=1`` pins the server to JSON frames (the ``hello``
    negotiation is answered as an unknown method, exactly like a pre-v2
    server), which v2 clients transparently fall back from.

    Cluster / fault-tolerance knobs (DESIGN.md "Cluster"):

    * ``journal=True`` — keep a replayable op journal so replicas can
      subscribe (implied by ``replicate_from`` / ``sync_replication``).
    * ``replicate_from="remote://host:port"`` — start as a *replica* of that
      primary: refuse writes, tail its op stream, replay into the local
      backend.  :meth:`promote` flips it to primary under a bumped epoch.
    * ``sync_replication=True`` — hold each write's client response until a
      subscribed replica acks the op (degrades to async with no subscriber).
    * ``fault_injector`` — a :class:`~.chaos.FaultInjector` for chaos tests.
    * ``reclaim_grace`` — sweep interval-driven stale-RUNNING reclamation:
      trials with no heartbeat for that many seconds are FAILed, or requeued
      as WAITING with ``reclaim_requeue=True``.
    * :meth:`kill` — simulated crash (no response flush); :meth:`restart`
      re-binds the same port over the same backend object.
    """

    def __init__(
        self, storage: BaseStorage, host: str = "127.0.0.1", port: int = 0,
        auth_token: "str | None" = None, auth_tokens: "list | None" = None,
        tls_cert: "str | None" = None, tls_key: "str | None" = None,
        max_protocol: int = 2,
        journal: bool = False,
        replicate_from: "str | None" = None,
        sync_replication: bool = False,
        epoch: int = 1,
        fault_injector: Any = None,
        reclaim_grace: "float | None" = None,
        reclaim_requeue: bool = False,
        reclaim_interval: float = 1.0,
    ):
        if (tls_cert is None) != (tls_key is None):
            raise ValueError("tls_cert and tls_key must be given together")
        self._storage = storage
        self._host = host
        self._requested_port = port
        self._auth_token = auth_token
        self._auth_tokens = auth_tokens
        self._tls_cert = tls_cert
        self._tls_key = tls_key
        self._max_protocol = max_protocol
        self._replicate_from = replicate_from
        self._journal_enabled = bool(journal or replicate_from or sync_replication)
        self._journal = OpJournal() if self._journal_enabled else None
        self._sync_replication = sync_replication
        self._epoch = int(epoch)
        self._role = "replica" if replicate_from else "primary"
        self._fault_injector = fault_injector
        self._reclaim_grace = reclaim_grace
        self._reclaim_requeue = reclaim_requeue
        self._reclaim_interval = reclaim_interval
        self._server: _RPCServer | None = None
        self._thread: threading.Thread | None = None
        self._tail: "_ReplicaTail | None" = None

    def start(self) -> "StorageServer":
        if self._server is not None:
            return self
        ssl_context = None
        if self._tls_cert is not None:
            ssl_context = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
            ssl_context.load_cert_chain(self._tls_cert, self._tls_key)
        self._server = _RPCServer(
            (self._host, self._requested_port), self._storage,
            auth_token=self._auth_token, auth_tokens=self._auth_tokens,
            ssl_context=ssl_context, max_protocol=self._max_protocol,
            journal=self._journal, role=self._role, epoch=self._epoch,
            sync_replication=self._sync_replication,
            fault_injector=self._fault_injector,
            reclaim_grace=self._reclaim_grace,
            reclaim_requeue=self._reclaim_requeue,
            reclaim_interval=self._reclaim_interval,
        )
        # remember the bound port so kill()/restart() resurrects the same URL
        self._requested_port = self._server.server_address[1]
        if self._replicate_from is not None and self._tail is None:
            from .client import parse_remote_candidates

            candidates, token, _tls = parse_remote_candidates(self._replicate_from)
            self._tail = _ReplicaTail(
                self._server, candidates[0][0], candidates[0][1],
                auth_token=token or self._auth_token,  # shared-secret cluster
                protocol=self._max_protocol,
            ).start()
            self._server._tail_handle = self._tail
        elif self._tail is not None:
            self._server._tail_handle = self._tail
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.1}, daemon=True
        )
        self._thread.start()
        return self

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_address[1]

    @property
    def tls(self) -> bool:
        return self._tls_cert is not None

    @property
    def url(self) -> str:
        scheme = "remote+tls" if self.tls else "remote"
        return f"{scheme}://{self.host}:{self.port}"

    def get_server_metrics(self) -> dict[str, Any]:
        """The live metrics surface (same payload the ``get_server_metrics``
        RPC returns to :class:`RemoteStorage` clients)."""
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_metrics()

    def stop(self) -> None:
        if self._server is None:
            return
        if self._tail is not None:
            self._tail.stop()
            self._tail = None
        self._server.stopping.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._server.close()  # idempotent; covers a loop that died early
        self._server = None
        self._thread = None

    def kill(self) -> None:
        """Simulated crash: sockets close without flushing responses, held
        (semi-sync) responses are abandoned, the replica tail dies.  The
        backend object survives — :meth:`restart` brings the node back on the
        same port, like a process restart over durable storage."""
        if self._server is None:
            return
        if self._tail is not None:
            self._tail.stop()
            self._tail = None
        self._server._kill.set()
        self._server.stopping.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._server.close(flush=False)
        self._server = None
        self._thread = None

    def restart(self) -> "StorageServer":
        """Bring a stopped/killed node back on the same host:port."""
        return self.start()

    def promote(self, epoch: "int | None" = None) -> dict[str, Any]:
        """Replica → primary: stop tailing the (dead) upstream, accept writes
        under a bumped epoch.  Returns ``{"role", "epoch", "seq"}``."""
        if self._server is None:
            raise RuntimeError("server not started")
        tail, self._tail = self._tail, None
        if tail is not None:
            tail.stop()  # join: every received op is applied before we flip
        info = self._server.promote(epoch)
        # keep wrapper state in sync so a later kill()/restart() stays primary
        self._role = info["role"]
        self._epoch = info["epoch"]
        self._replicate_from = None
        return info

    @property
    def role(self) -> str:
        return self._server.role if self._server is not None else self._role

    @property
    def epoch(self) -> int:
        return self._server.epoch if self._server is not None else self._epoch

    @property
    def fault_injector(self) -> Any:
        return self._fault_injector

    @property
    def storage(self) -> BaseStorage:
        return self._storage

    @property
    def journal(self) -> "OpJournal | None":
        return self._journal

    def replication_state(self) -> dict[str, Any]:
        """Live replication view: journal seq, acked seq, applied seq (on a
        replica), role and epoch — what the chaos harness polls."""
        srv = self._server
        tail = self._tail
        return {
            "role": self.role,
            "epoch": self.epoch,
            "seq": self._journal.end_seq if self._journal is not None else 0,
            "acked_seq": srv._acked_seq if srv is not None else 0,
            "applied_seq": tail.applied if tail is not None else None,
        }

    def __enter__(self) -> "StorageServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def main(argv: list[str] | None = None) -> None:
    """``python -m repro.core.storage.server sqlite:///study.db --port 9000``"""
    import argparse

    from . import get_storage

    ap = argparse.ArgumentParser(description="serve a storage backend over remote://")
    ap.add_argument("storage", help="backend URL to wrap (sqlite:/// or journal://)")
    ap.add_argument("--host", default="0.0.0.0")
    ap.add_argument("--port", type=int, default=9000)
    ap.add_argument(
        "--auth-token",
        default=os.environ.get("REPRO_STORAGE_TOKEN"),
        help="shared secret; clients connect with remote://TOKEN@host:port "
        "(default: $REPRO_STORAGE_TOKEN)",
    )
    ap.add_argument(
        "--readonly-token",
        default=None,
        help="additional shared secret granting read-only access",
    )
    ap.add_argument("--tls-cert", default=None, help="PEM certificate; enables TLS")
    ap.add_argument("--tls-key", default=None, help="PEM private key; enables TLS")
    ap.add_argument(
        "--max-protocol", type=int, default=2, choices=(1, 2),
        help="1 pins the wire to legacy JSON frames",
    )
    ap.add_argument(
        "--journal", action="store_true",
        help="record executed writes in a replayable op journal (required "
        "to serve replicas)",
    )
    ap.add_argument(
        "--sync-replication", action="store_true",
        help="hold client write responses until a connected replica acks "
        "(implies --journal; acked writes survive primary loss)",
    )
    ap.add_argument(
        "--replicate-from", default=None, metavar="URL",
        help="start as a replica tailing this primary's op journal; promote "
        "later with the 'promote' RPC",
    )
    ap.add_argument(
        "--reclaim-grace", type=float, default=None, metavar="SECONDS",
        help="FAIL RUNNING trials whose worker stopped heartbeating for "
        "this many seconds (server-side sweep)",
    )
    ap.add_argument(
        "--reclaim-requeue", action="store_true",
        help="re-enqueue reclaimed trials as WAITING instead of FAILing them",
    )
    args = ap.parse_args(argv)

    auth_tokens = None
    if args.readonly_token:
        auth_tokens = [{"token": args.readonly_token, "readonly": True}]
    server = StorageServer(
        get_storage(args.storage), host=args.host, port=args.port,
        auth_token=args.auth_token, auth_tokens=auth_tokens,
        tls_cert=args.tls_cert, tls_key=args.tls_key,
        max_protocol=args.max_protocol,
        journal=args.journal or args.sync_replication,
        sync_replication=args.sync_replication,
        replicate_from=args.replicate_from,
        reclaim_grace=args.reclaim_grace,
        reclaim_requeue=args.reclaim_requeue,
    ).start()
    print(f"serving {args.storage} at {server.url} (ctrl-c to stop)", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        server.stop()


if __name__ == "__main__":
    main()
