"""Networked storage server — the piece that turns "N processes on one box"
into "N workers on a fleet" (paper §4's scalable deployment criterion).

A :class:`StorageServer` wraps *any* :class:`BaseStorage` backend and exposes
it over TCP to :class:`~repro.core.storage.client.RemoteStorage` clients.

Protocol
--------
Length-prefixed JSON-RPC: each frame is a 4-byte big-endian payload length
followed by UTF-8 JSON.  A request is ``{"id", "method", "params"}`` (params
encoded with :mod:`.serde`); the response is ``{"id", "ok", "result"}`` or
``{"id", "ok": false, "error": {"type", "message"}}``.  A frame may carry a
*list* of requests (a batch); the server executes them in order and answers
with a list of responses in the same frame — one round trip for a whole
write-behind flush.

Concurrency: one daemon thread per connection; atomicity of each call (e.g.
the WAITING->RUNNING compare-and-set in ``set_trial_state_values``) is
delegated to the wrapped backend, which already guarantees it per the
BaseStorage contract.  Graceful shutdown via :meth:`StorageServer.stop` —
in-flight requests finish, then sockets close.
"""

from __future__ import annotations

import hmac
import json
import os
import socket
import socketserver
import struct
import threading
import time
from typing import Any

from .. import telemetry
from .base import BaseStorage, get_trials_since
from .serde import pack, unpack

__all__ = ["StorageServer", "send_frame", "recv_frame", "MAX_FRAME_BYTES"]

MAX_FRAME_BYTES = 64 * 1024 * 1024  # sanity cap on one frame
MID_FRAME_STALL_SECONDS = 30.0  # max time a peer may stall between bytes of one frame

# The RPC surface: exactly the BaseStorage API (plus ping for liveness).
_METHODS = frozenset(
    {
        "create_new_study",
        "delete_study",
        "get_study_id_from_name",
        "get_study_name_from_id",
        "get_study_directions",
        "get_all_studies",
        "set_study_user_attr",
        "set_study_system_attr",
        "get_study_user_attrs",
        "get_study_system_attrs",
        "create_new_trial",
        "set_trial_param",
        "set_trial_state_values",
        "set_trial_intermediate_value",
        "report_and_prune",
        "set_trial_user_attr",
        "set_trial_system_attr",
        "get_trial",
        "get_all_trials",
        "get_n_trials",
        "get_trial_id_from_study_and_number",
        "record_heartbeat",
        "get_stale_trial_ids",
        "fail_stale_trials",
        "get_trials_revision",
        "get_trial_events",
    }
)


def send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(struct.pack(">I", len(payload)) + payload)


def recv_frame(sock: socket.socket) -> bytes | None:
    """Read one length-prefixed frame; ``None`` on clean EOF.

    A ``socket.timeout`` escapes only while *idle* (no byte of the frame seen
    yet) — once a frame has started, reads are retried so a slow peer cannot
    cause a torn frame, but a peer that stalls longer than
    ``MID_FRAME_STALL_SECONDS`` without sending a single byte raises
    ``ConnectionError`` instead of hanging the caller forever.
    """
    header = _recv_exact(sock, 4, allow_idle_timeout=True)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {length} bytes exceeds cap {MAX_FRAME_BYTES}")
    body = _recv_exact(sock, length, allow_idle_timeout=False)
    if body is None:
        raise ConnectionError("connection closed mid-frame")
    return body


def _recv_exact(sock: socket.socket, n: int, allow_idle_timeout: bool) -> bytes | None:
    buf = b""
    stall_deadline: float | None = None
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if allow_idle_timeout and not buf:
                raise
            now = time.monotonic()
            if stall_deadline is None:
                stall_deadline = now + MID_FRAME_STALL_SECONDS
            elif now >= stall_deadline:
                raise ConnectionError(
                    f"peer stalled mid-frame for over {MID_FRAME_STALL_SECONDS}s"
                ) from None
            continue  # mid-frame: give the peer a bounded grace period
        stall_deadline = None  # any progress resets the stall clock
        if not chunk:
            if buf:
                raise ConnectionError("connection closed mid-frame")
            return None
        buf += chunk
    return buf


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:
        server: "_RPCServer" = self.server  # type: ignore[assignment]
        metrics = server.metrics
        metrics.gauge("server.active_connections").add(1)
        # events the wrapped backend records on this thread carry the *client*
        # identity, so a fleet-wide trace attributes work to its worker
        telemetry.set_worker_context("%s:%s" % self.client_address[:2])
        try:
            self._serve(server, metrics)
        finally:
            telemetry.set_worker_context(None)
            metrics.gauge("server.active_connections").add(-1)

    def _serve(self, server: "_RPCServer", metrics: telemetry.MetricsRegistry) -> None:
        sock: socket.socket = self.request
        sock.settimeout(0.5)  # so the loop notices server shutdown promptly
        authed = server.auth_token is None
        # per-connection interned pruner specs (client sends each spec once
        # as __spec_def__, then short __spec_ref__ frames; see client.py)
        conn_specs: dict[int, dict] = {}
        while not server.stopping.is_set():
            try:
                payload = recv_frame(sock)
            except socket.timeout:
                continue
            except (ConnectionError, OSError):
                return
            if payload is None:
                return
            metrics.counter("server.frames_in").inc()
            metrics.counter("server.bytes_in").inc(len(payload))
            try:
                request = json.loads(payload)
            except json.JSONDecodeError:
                return  # protocol violation; drop the connection
            drop_after_reply = False
            if not authed:
                # token-protected server: the first frame must be a valid auth
                # handshake; anything else is answered with a typed error and
                # the connection is dropped
                if _auth_ok(request, server.auth_token):
                    authed = True
                    responses = [{"id": request.get("id"), "ok": True, "result": "ok"}]
                    batch = False
                else:
                    metrics.counter("server.auth_failures").inc()
                    responses = [
                        {
                            "id": request.get("id") if isinstance(request, dict) else None,
                            "ok": False,
                            "error": {
                                "type": "PermissionError",
                                "message": "storage server requires an auth token",
                            },
                        }
                    ]
                    batch = False
                    drop_after_reply = True
            else:
                batch = isinstance(request, list)
                t0 = time.perf_counter()
                responses = [
                    server.dispatch(r, conn_specs)
                    for r in (request if batch else [request])
                ]
            out = json.dumps(responses if batch else responses[0]).encode()
            if batch:
                # the whole-frame view of a batched flush (tell_batch, the
                # write-behind drain): per-op latencies are recorded by
                # dispatch; this row pins the envelope cost clients feel
                server._note_rpc("batch", t0, len(out))
                metrics.counter("server.batched_ops").inc(len(responses))
            metrics.counter("server.frames_out").inc()
            metrics.counter("server.bytes_out").inc(len(out))
            try:
                sock.settimeout(30.0)
                send_frame(sock, out)
                sock.settimeout(0.5)
            except (ConnectionError, OSError):
                return
            if drop_after_reply:
                return


def _resolve_spec(params: list, conn_specs: "dict[int, dict] | None") -> list:
    """Resolve the pruner-spec param of a fused report: a ``__spec_def__``
    envelope registers the full spec in this connection's cache, a
    ``__spec_ref__`` looks one up, and a raw spec dict (older clients, or
    in-process dispatch without connection state) passes through untouched."""
    if len(params) < 5 or not isinstance(params[4], dict):
        return params
    spec = params[4]
    if "__spec_def__" in spec:
        ent = spec["__spec_def__"]
        params = list(params)
        params[4] = ent["spec"]
        if conn_specs is not None:
            conn_specs[int(ent["id"])] = ent["spec"]
        return params
    if "__spec_ref__" in spec:
        ref = int(spec["__spec_ref__"])
        if conn_specs is None or ref not in conn_specs:
            raise ValueError(
                f"unknown pruner spec ref {ref} (connection lost its spec cache)"
            )
        params = list(params)
        params[4] = conn_specs[ref]
        return params
    return params


def _auth_ok(request: Any, token: str) -> bool:
    if not isinstance(request, dict) or request.get("method") != "auth":
        return False
    params = request.get("params")
    if not isinstance(params, list) or len(params) != 1 or not isinstance(params[0], str):
        return False
    return hmac.compare_digest(params[0], token)


class _RPCServer(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr: tuple[str, int], storage: BaseStorage, auth_token: "str | None" = None):
        super().__init__(addr, _Handler)
        self.storage = storage
        self.auth_token = auth_token
        self.stopping = threading.Event()
        # always-on, server-owned registry: get_server_metrics must work
        # without globally enabling client-side telemetry in this process
        self.metrics = telemetry.MetricsRegistry(enabled=True)
        self.started_at = time.time()

    def dispatch(self, request: dict, conn_specs: "dict[int, dict] | None" = None) -> dict:
        req_id = request.get("id")
        method = request.get("method")
        t0 = time.perf_counter()
        try:
            if method == "ping":
                return {"id": req_id, "ok": True, "result": "pong"}
            if method == "auth":
                # reaching dispatch means no token is required (or the
                # connection already authenticated); accept idempotently
                return {"id": req_id, "ok": True, "result": "ok"}
            if method == "get_server_metrics":
                return {"id": req_id, "ok": True, "result": self.server_metrics()}
            if method not in _METHODS:
                raise ValueError(f"unknown storage method {method!r}")
            params = unpack(request.get("params") or [])
            if method == "report_and_prune":
                spec = params[4] if len(params) > 4 and isinstance(params[4], dict) else None
                if spec is not None and "__spec_ref__" in spec:
                    self.metrics.counter("server.spec_cache.hits").inc()
                elif spec is not None and "__spec_def__" in spec:
                    self.metrics.counter("server.spec_cache.defs").inc()
                params = _resolve_spec(params, conn_specs)
            result = self._invoke(method, params)
            response = {"id": req_id, "ok": True, "result": pack(result)}
            # an unserializable result must become a typed error frame, not a
            # dropped connection (the client would silently retry + misreport)
            # — the dump doubles as the per-method response-size sample
            blob = json.dumps(response)
            self._note_rpc(method, t0, len(blob))
            return response
        except Exception as e:  # every failure maps to a typed client-side raise
            self._note_rpc(method, t0, 0, error=True)
            return {
                "id": req_id,
                "ok": False,
                "error": {"type": type(e).__name__, "message": str(e)},
            }

    def _note_rpc(self, method: Any, t0: float, nbytes: int, error: bool = False) -> None:
        name = method if isinstance(method, str) else "invalid"
        self.metrics.counter(f"server.rpc.{name}.calls").inc()
        self.metrics.histogram(f"server.rpc.{name}").observe(time.perf_counter() - t0)
        if nbytes:
            self.metrics.counter(f"server.rpc.{name}.bytes_out").inc(nbytes)
        if error:
            self.metrics.counter(f"server.rpc.{name}.errors").inc()

    def server_metrics(self) -> dict[str, Any]:
        """JSON-safe metrics surface: per-method call counts / latency
        percentiles / bytes plus connection- and cache-level counters."""
        snap = self.metrics.snapshot()
        counters = snap["counters"]
        methods: dict[str, Any] = {}
        for name, h in snap["histograms"].items():
            if not name.startswith("server.rpc."):
                continue
            m = name[len("server.rpc."):]
            methods[m] = {
                "calls": counters.get(f"server.rpc.{m}.calls", 0),
                "errors": counters.get(f"server.rpc.{m}.errors", 0),
                "bytes_out": counters.get(f"server.rpc.{m}.bytes_out", 0),
                **{k: h[k] for k in ("count", "mean", "p50", "p95", "p99", "max")},
            }
        return {
            "uptime_s": time.time() - self.started_at,
            "active_connections": snap["gauges"].get("server.active_connections", 0),
            "auth_failures": counters.get("server.auth_failures", 0),
            "frames_in": counters.get("server.frames_in", 0),
            "frames_out": counters.get("server.frames_out", 0),
            "bytes_in": counters.get("server.bytes_in", 0),
            "bytes_out": counters.get("server.bytes_out", 0),
            "spec_cache_hits": counters.get("server.spec_cache.hits", 0),
            "spec_cache_defs": counters.get("server.spec_cache.defs", 0),
            "batched_ops": counters.get("server.batched_ops", 0),
            "methods": methods,
        }

    def _invoke(self, method: str, params: list[Any]) -> Any:
        if method in ("get_all_trials", "get_n_trials"):
            # states arrives as a JSON list; the API takes a tuple
            if method == "get_all_trials":
                study_id, deepcopy, states, since = params
                states = tuple(states) if states is not None else None
                if since is not None:
                    return get_trials_since(
                        self.storage, study_id, since, deepcopy=deepcopy, states=states
                    )
                return self.storage.get_all_trials(study_id, deepcopy=deepcopy, states=states)
            if method == "get_n_trials":
                study_id, states = params
                states = tuple(states) if states is not None else None
                return self.storage.get_n_trials(study_id, states=states)
        return getattr(self.storage, method)(*params)


class StorageServer:
    """Serve a storage backend over TCP.

    >>> server = StorageServer(SQLiteStorage("study.db")).start()
    >>> server.url          # hand this to workers on other machines
    'remote://10.0.0.5:38211'
    >>> server.stop()

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    Usable as a context manager.

    ``auth_token`` arms a shared-secret handshake: every connection must
    present the token in its first frame (``RemoteStorage`` does this
    automatically for ``remote://token@host:port`` URLs or an explicit
    ``auth_token=``) or it is rejected with ``PermissionError`` and dropped.
    This is authentication only — the wire stays plaintext; run inside a
    trusted network or tunnel for confidentiality.
    """

    def __init__(
        self, storage: BaseStorage, host: str = "127.0.0.1", port: int = 0,
        auth_token: "str | None" = None,
    ):
        self._storage = storage
        self._host = host
        self._requested_port = port
        self._auth_token = auth_token
        self._server: _RPCServer | None = None
        self._thread: threading.Thread | None = None

    def start(self) -> "StorageServer":
        if self._server is not None:
            return self
        self._server = _RPCServer(
            (self._host, self._requested_port), self._storage, auth_token=self._auth_token
        )
        self._thread = threading.Thread(
            target=self._server.serve_forever, kwargs={"poll_interval": 0.1}, daemon=True
        )
        self._thread.start()
        return self

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"remote://{self.host}:{self.port}"

    def get_server_metrics(self) -> dict[str, Any]:
        """The live metrics surface (same payload the ``get_server_metrics``
        RPC returns to :class:`RemoteStorage` clients)."""
        if self._server is None:
            raise RuntimeError("server not started")
        return self._server.server_metrics()

    def stop(self) -> None:
        if self._server is None:
            return
        self._server.stopping.set()
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._server = None
        self._thread = None

    def __enter__(self) -> "StorageServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False


def main(argv: list[str] | None = None) -> None:
    """``python -m repro.core.storage.server sqlite:///study.db --port 9000``"""
    import argparse

    from . import get_storage

    ap = argparse.ArgumentParser(description="serve a storage backend over remote://")
    ap.add_argument("storage", help="backend URL to wrap (sqlite:/// or journal://)")
    ap.add_argument("--host", default="0.0.0.0")
    ap.add_argument("--port", type=int, default=9000)
    ap.add_argument(
        "--auth-token",
        default=os.environ.get("REPRO_STORAGE_TOKEN"),
        help="shared secret; clients connect with remote://TOKEN@host:port "
        "(default: $REPRO_STORAGE_TOKEN)",
    )
    args = ap.parse_args(argv)

    server = StorageServer(
        get_storage(args.storage), host=args.host, port=args.port,
        auth_token=args.auth_token,
    ).start()
    print(f"serving {args.storage} at {server.url} (ctrl-c to stop)", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        server.stop()


if __name__ == "__main__":
    main()
