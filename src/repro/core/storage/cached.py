"""``CachedStorage`` — client-side write-behind cache proxy.

``get_all_trials`` is the per-``ask`` bottleneck: every sampler reads the
whole study before suggesting, so a naive remote backend re-ships N trials
over the wire N times (O(N^2) total).  This proxy (modeled on Optuna's
``_CachedStorage``) makes the read incremental and the hot writes local:

* **Finished trials are immutable** (BaseStorage contract) — once seen, they
  are cached forever and never re-fetched.  A per-study *watermark* tracks
  the smallest trial number not yet known-finished; each ``get_all_trials``
  fetches only ``number >= watermark`` from the backend (the ``since=`` hook,
  with a full-read fallback for backends that lack it).
* **Own running trials are tracked locally** — trials this process created or
  claimed keep an up-to-date local copy, so suggest-time reads never touch
  the backend.  Param/attr writes are buffered (write-behind) and flushed in
  one batched RPC before any write that must be globally visible
  (``report`` values for cross-worker pruning, state transitions).
* **Everything else forwards** — claims (``set_trial_state_values``) always
  execute on the backend, so the WAITING->RUNNING compare-and-set stays
  atomic study-wide.

Invalidation rules are documented in DESIGN.md.
"""

from __future__ import annotations

import copy
import threading
from typing import Any, Iterable

from .. import telemetry
from ..distributions import BaseDistribution, check_distribution_compatibility
from ..exceptions import RetryableStorageError
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseStorage, StudySummary, get_trials_since

__all__ = ["CachedStorage"]

# failures that mean "the backend is unreachable right now", after which the
# write-behind buffer must survive intact for a later re-flush
_TRANSIENT = (RetryableStorageError, ConnectionError, TimeoutError, OSError)


class _StudyCache:
    def __init__(self) -> None:
        self.trials: dict[int, FrozenTrial] = {}  # by number
        self.watermark = 0  # every number < watermark is finished and cached
        self.revision: int | None = None  # backend revision at last fetch


class CachedStorage(BaseStorage):
    """Wrap any :class:`BaseStorage` backend with an incremental read cache
    and write-behind buffering for trials owned by this process."""

    def __init__(self, backend: BaseStorage):
        if isinstance(backend, CachedStorage):
            raise ValueError("do not nest CachedStorage proxies")
        self._backend = backend
        self._lock = threading.RLock()
        self._studies: dict[int, _StudyCache] = {}
        self._index: dict[int, tuple[int, int]] = {}  # trial_id -> (study_id, number)
        self._own: dict[int, FrozenTrial] = {}  # trial_id -> local copy (RUNNING, ours)
        self._pending: dict[int, list[tuple[str, tuple]]] = {}  # trial_id -> buffered ops
        self._revision_supported = True  # until the backend says otherwise

    @property
    def backend(self) -> BaseStorage:
        return self._backend

    # -- study (forwarded; studies are cheap metadata) --------------------------

    def create_new_study(self, directions: list[StudyDirection], study_name: str) -> int:
        sid = self._backend.create_new_study(directions, study_name)
        with self._lock:
            self._studies[sid] = _StudyCache()
        return sid

    def delete_study(self, study_id: int) -> None:
        self._backend.delete_study(study_id)
        with self._lock:
            self._studies.pop(study_id, None)
            dead = [tid for tid, (sid, _) in self._index.items() if sid == study_id]
            for tid in dead:
                del self._index[tid]
                self._own.pop(tid, None)
                self._pending.pop(tid, None)

    def get_study_id_from_name(self, study_name: str) -> int:
        return self._backend.get_study_id_from_name(study_name)

    def get_study_name_from_id(self, study_id: int) -> str:
        return self._backend.get_study_name_from_id(study_id)

    def get_study_directions(self, study_id: int) -> list[StudyDirection]:
        return self._backend.get_study_directions(study_id)

    def get_all_studies(self) -> list[StudySummary]:
        return self._backend.get_all_studies()

    def set_study_user_attr(self, study_id: int, key: str, value: Any) -> None:
        self._backend.set_study_user_attr(study_id, key, value)

    def set_study_system_attr(self, study_id: int, key: str, value: Any) -> None:
        self._backend.set_study_system_attr(study_id, key, value)

    def get_study_user_attrs(self, study_id: int) -> dict[str, Any]:
        return self._backend.get_study_user_attrs(study_id)

    def get_study_system_attrs(self, study_id: int) -> dict[str, Any]:
        return self._backend.get_study_system_attrs(study_id)

    # -- trial ------------------------------------------------------------------

    def create_new_trial(self, study_id: int, template_trial: FrozenTrial | None = None) -> int:
        tid = self._backend.create_new_trial(study_id, template_trial)
        t = self._backend.get_trial(tid)
        with self._lock:
            self._adopt_created_locked(study_id, t)
        return tid

    def create_new_trials(
        self, study_id: int, n: int, template_trial: FrozenTrial | None = None
    ) -> list[int]:
        """Batched creation: ids in one round trip, trial rows in a second
        (when the backend supports request batching)."""
        if n <= 0:
            return []
        tids = self._backend.create_new_trials(study_id, n, template_trial)
        call_batch = getattr(self._backend, "call_batch", None)
        if call_batch is not None and len(tids) > 1:
            trials = call_batch([("get_trial", (tid,)) for tid in tids])
        else:
            trials = [self._backend.get_trial(tid) for tid in tids]
        with self._lock:
            for t in trials:
                self._adopt_created_locked(study_id, t)
        return tids

    def _adopt_created_locked(self, study_id: int, t: FrozenTrial) -> None:
        cache = self._studies.setdefault(study_id, _StudyCache())
        self._index[t.trial_id] = (study_id, t.number)
        cache.trials[t.number] = t
        # WAITING (enqueued) trials belong to whoever claims them, not us
        if t.state == TrialState.RUNNING:
            self._own[t.trial_id] = t

    def set_trial_param(
        self, trial_id: int, param_name: str, param_value_internal: float,
        distribution: BaseDistribution,
    ) -> None:
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                if param_name in t.distributions:
                    check_distribution_compatibility(t.distributions[param_name], distribution)
                t.params[param_name] = distribution.to_external_repr(param_value_internal)
                t.distributions[param_name] = distribution
                self._pending.setdefault(trial_id, []).append(
                    ("set_trial_param",
                     (trial_id, param_name, float(param_value_internal), distribution))
                )
                return
        self._backend.set_trial_param(trial_id, param_name, param_value_internal, distribution)

    def set_trial_state_values(
        self, trial_id: int, state: TrialState, values: Iterable[float] | None = None
    ) -> bool:
        values = [float(v) for v in values] if values is not None else None
        with self._lock:
            own = trial_id in self._own
            if own:
                self._flush_trial_locked(trial_id)
            ok = self._backend.set_trial_state_values(trial_id, state, values)
            if not ok:
                return False
            if own and (state.is_finished() or state == TrialState.WAITING):
                # hand the record back to the backend as the source of truth:
                # finished rows are refetched authoritative (incl.
                # datetime_complete); WAITING means we released a batch-asked
                # trial for anyone to claim, so it is no longer ours either
                self._own.pop(trial_id)
                sid, number = self._index[trial_id]
                self._studies.setdefault(sid, _StudyCache()).trials.pop(number, None)
            elif own:
                t = self._own[trial_id]
                t.state = state
                if values is not None:
                    t.values = values
            elif state == TrialState.RUNNING and trial_id in self._index:
                # we just won the claim on an enqueued trial -> adopt it
                sid, number = self._index[trial_id]
                t = self._backend.get_trial(trial_id)
                self._own[trial_id] = t
                self._studies.setdefault(sid, _StudyCache()).trials[number] = t
            return True

    def set_trial_intermediate_value(
        self, trial_id: int, step: int, intermediate_value: float
    ) -> None:
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                if t.state.is_finished():
                    raise RuntimeError(f"trial {trial_id} is already finished")
                t.intermediate_values[int(step)] = float(intermediate_value)
                # reported values feed cross-worker pruners -> must be visible
                self._pending.setdefault(trial_id, []).append(
                    ("set_trial_intermediate_value",
                     (trial_id, int(step), float(intermediate_value)))
                )
                self._flush_trial_locked(trial_id)
                return
        self._backend.set_trial_intermediate_value(trial_id, step, intermediate_value)

    def report_and_prune(
        self, study_id: int, trial_id: int, step: int, value: float,
        pruner_spec: dict, direction,
    ) -> bool:
        """Fused report→prune through the cache: the local copy of an owned
        trial is updated write-through, then any buffered write-behind ops
        ride the *same* batched frame as the fused op — the whole
        report+should_prune round still costs one backend round trip."""
        step, value = int(step), float(value)
        fused = ("report_and_prune", (study_id, trial_id, step, value, pruner_spec, direction))
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                if t.state.is_finished():
                    raise RuntimeError(f"trial {trial_id} is already finished")
                t.intermediate_values[step] = value
                # buffered ops stay queued until the backend confirms (an
                # outage mid-report must not drop the write-behind buffer)
                ops = self._pending.get(trial_id) or []
                call_batch = getattr(self._backend, "call_batch", None)
                try:
                    if call_batch is not None and ops:
                        pruned = bool(call_batch(ops + [fused])[-1])
                    else:
                        for method, params in ops:
                            getattr(self._backend, method)(*params)
                        pruned = bool(self._backend.report_and_prune(*fused[1]))
                except _TRANSIENT:
                    telemetry.inc("cached.flush.failures")
                    raise
                self._pending.pop(trial_id, None)
                return pruned
        return bool(self._backend.report_and_prune(*fused[1]))

    def set_trial_user_attr(self, trial_id: int, key: str, value: Any) -> None:
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                t.user_attrs[key] = value
                self._pending.setdefault(trial_id, []).append(
                    ("set_trial_user_attr", (trial_id, key, value))
                )
                return
        self._backend.set_trial_user_attr(trial_id, key, value)

    def set_trial_system_attr(self, trial_id: int, key: str, value: Any) -> None:
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                t.system_attrs[key] = value
                self._pending.setdefault(trial_id, []).append(
                    ("set_trial_system_attr", (trial_id, key, value))
                )
                return
        self._backend.set_trial_system_attr(trial_id, key, value)

    def get_trial(self, trial_id: int) -> FrozenTrial:
        with self._lock:
            t = self._own.get(trial_id)
            if t is not None:
                telemetry.inc("cached.get_trial.hit_own")
                return copy.deepcopy(t)
            loc = self._index.get(trial_id)
            if loc is not None:
                sid, number = loc
                cache = self._studies.get(sid)
                if cache is not None and number < cache.watermark:
                    telemetry.inc("cached.get_trial.hit_finished")
                    return copy.deepcopy(cache.trials[number])  # finished, immutable
        telemetry.inc("cached.get_trial.miss")
        return self._backend.get_trial(trial_id)

    def get_all_trials(
        self, study_id: int, deepcopy: bool = True,
        states: tuple[TrialState, ...] | None = None,
        since: int | None = None,
    ) -> list[FrozenTrial]:
        with self._lock:
            cache = self._refresh_locked(study_id)
            trials = [cache.trials[n] for n in sorted(cache.trials)]
            if since is not None:
                trials = [t for t in trials if t.number >= since]
            if states is not None:
                trials = [t for t in trials if t.state in states]
            return [copy.deepcopy(t) for t in trials] if deepcopy else trials

    def _refresh_locked(self, study_id: int) -> _StudyCache:
        """Fetch the unfinished suffix from the backend and advance the
        watermark past newly finished trials.

        The fetch is skipped entirely when the backend's monotonic trial
        revision is unchanged since the last refresh — one cheap counter read
        (a single RPC over ``remote://``) instead of re-shipping every
        RUNNING trial on every ``ask``.  Any trial mutation bumps the
        revision, so in-place updates to RUNNING trials are still seen."""
        cache = self._studies.setdefault(study_id, _StudyCache())
        rev: int | None = None
        if self._revision_supported:
            try:
                rev = self._backend.get_trials_revision(study_id)
            except NotImplementedError:
                self._revision_supported = False
        if rev is not None and rev == cache.revision:
            telemetry.inc("cached.refresh.noop")  # revision-gated skip
            return cache
        # read the revision before the data: writes landing between the two
        # reads show up as a fresh revision on the next refresh
        telemetry.inc("cached.refresh.fetch")
        fresh = get_trials_since(self._backend, study_id, cache.watermark, deepcopy=False)
        for t in fresh:
            if t.trial_id in self._own:
                continue  # never clobber our local (possibly unflushed) copy
            cache.trials[t.number] = t
            self._index[t.trial_id] = (study_id, t.number)
        for tid, t in self._own.items():
            sid, number = self._index[tid]
            if sid == study_id:
                cache.trials[number] = t
        while cache.watermark in cache.trials and cache.trials[cache.watermark].state.is_finished():
            cache.watermark += 1
        cache.revision = rev
        return cache

    # -- write-behind flushing ----------------------------------------------------

    def _flush_trial_locked(self, trial_id: int) -> None:
        """Drain one trial's write-behind buffer.  The buffer is popped only
        AFTER the backend confirms — a flush into a dead server keeps every
        op queued for the next attempt (every buffered op is an overwrite,
        so a partially-applied batch replays harmlessly)."""
        ops = self._pending.get(trial_id)
        if not ops:
            return
        call_batch = getattr(self._backend, "call_batch", None)
        try:
            if call_batch is not None and len(ops) > 1:
                call_batch(list(ops))  # one round trip for the whole buffer
            else:
                for method, params in ops:
                    getattr(self._backend, method)(*params)
        except _TRANSIENT:
            telemetry.inc("cached.flush.failures")
            raise
        self._pending.pop(trial_id, None)

    def flush(self) -> None:
        """Push all buffered writes to the backend.  On a transient backend
        failure the unflushed buffers stay queued (and the error propagates);
        calling ``flush()`` again once the backend is back re-sends them."""
        with self._lock:
            for tid in list(self._pending):
                self._flush_trial_locked(tid)

    @property
    def pending_ops(self) -> int:
        """Number of buffered write-behind ops not yet confirmed flushed."""
        with self._lock:
            return sum(len(v) for v in self._pending.values())

    # -- heartbeat / misc ---------------------------------------------------------

    def get_trials_revision(self, study_id: int) -> int:
        return self._backend.get_trials_revision(study_id)

    def record_heartbeat(self, trial_id: int) -> None:
        self._backend.record_heartbeat(trial_id)

    def get_stale_trial_ids(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._backend.get_stale_trial_ids(study_id, grace_seconds)

    def fail_stale_trials(self, study_id: int, grace_seconds: float) -> list[int]:
        return self._backend.fail_stale_trials(study_id, grace_seconds)

    def reclaim_stale_trials(
        self, study_id: int, grace_seconds: float, requeue: bool = False
    ) -> list[int]:
        return self._backend.reclaim_stale_trials(study_id, grace_seconds, requeue)

    def get_trial_events(self, study_id: int, since: int = 0) -> dict[str, Any]:
        """Lifecycle events live where the mutations execute — the backend."""
        return self._backend.get_trial_events(study_id, since)

    @property
    def supports_block_fetch(self) -> bool:
        return getattr(self._backend, "supports_block_fetch", False)

    def get_observation_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        # drain write-behind buffers first so the backend snapshot is at
        # least as fresh as what this process has already observed locally
        self.flush()
        return self._backend.get_observation_block(study_id, since)

    def get_iv_block(self, study_id: int, since: int = 0) -> dict[str, Any]:
        self.flush()
        return self._backend.get_iv_block(study_id, since)

    def get_server_metrics(self) -> dict[str, Any]:
        fn = getattr(self._backend, "get_server_metrics", None)
        if fn is None:
            raise NotImplementedError("backend has no server metrics surface")
        return fn()

    def close(self) -> None:
        try:
            self.flush()
        except _TRANSIENT:
            pass  # shutting down against a dead backend: nothing left to try
        self._backend.close()
