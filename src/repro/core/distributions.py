"""Parameter distributions for the define-by-run search space.

A distribution describes the domain a single ``trial.suggest_*`` call samples
from.  Because the search space is constructed *dynamically* (define-by-run),
distributions are recorded per-(trial, parameter) in storage, and the
intersection over completed trials recovers the concurrence relations the
relational samplers (CMA-ES, GP) need (paper §3.1).

Internal representation
-----------------------
Every parameter value is stored as a float ("internal repr"):

* Float  -> the value itself
* Int    -> float(value)
* Categorical -> float(index into ``choices``)

``to_external_repr``/``to_internal_repr`` convert between the two.  This is
the same trick Optuna uses so that storage backends only ever persist floats.
"""

from __future__ import annotations

import json
import math
from typing import Any, Sequence

__all__ = [
    "BaseDistribution",
    "FloatDistribution",
    "IntDistribution",
    "CategoricalDistribution",
    "distribution_to_json",
    "json_to_distribution",
    "check_distribution_compatibility",
]


class BaseDistribution:
    """Base class of parameter distributions."""

    def to_external_repr(self, internal: float) -> Any:
        return internal

    def to_internal_repr(self, external: Any) -> float:
        return float(external)

    def single(self) -> bool:
        """True if the domain contains exactly one value."""
        raise NotImplementedError

    def _contains(self, internal: float) -> bool:
        raise NotImplementedError

    def _asdict(self) -> dict:
        raise NotImplementedError

    def __eq__(self, other: object) -> bool:
        return type(self) is type(other) and self._asdict() == other._asdict()  # type: ignore[attr-defined]

    def __hash__(self) -> int:
        return hash((type(self).__name__, json.dumps(self._asdict(), sort_keys=True, default=str)))

    def __repr__(self) -> str:
        kwargs = ", ".join(f"{k}={v!r}" for k, v in self._asdict().items())
        return f"{type(self).__name__}({kwargs})"


class FloatDistribution(BaseDistribution):
    """A continuous domain ``[low, high]``.

    Args:
        low/high: inclusive bounds.
        log: sample in log space (requires ``low > 0``).
        step: discretization step (mutually exclusive with ``log``).
    """

    def __init__(self, low: float, high: float, log: bool = False, step: float | None = None):
        if math.isnan(low) or math.isnan(high):
            raise ValueError("low/high must not be NaN")
        if low > high:
            raise ValueError(f"low={low} must be <= high={high}")
        if log and step is not None:
            raise ValueError("log and step are mutually exclusive")
        if log and low <= 0.0:
            raise ValueError(f"low={low} must be > 0 with log=True")
        if step is not None and step <= 0:
            raise ValueError(f"step={step} must be > 0")
        self.low = float(low)
        self.high = float(high)
        self.log = bool(log)
        self.step = float(step) if step is not None else None

    def single(self) -> bool:
        if self.step is not None:
            return self.high - self.low < self.step
        return self.low == self.high

    def _contains(self, internal: float) -> bool:
        return self.low <= internal <= self.high

    def to_external_repr(self, internal: float) -> float:
        return float(internal)

    def _asdict(self) -> dict:
        return {"low": self.low, "high": self.high, "log": self.log, "step": self.step}


class IntDistribution(BaseDistribution):
    """An integer domain ``{low, low+step, ..., high}`` (or log-uniform ints)."""

    def __init__(self, low: int, high: int, log: bool = False, step: int = 1):
        if low > high:
            raise ValueError(f"low={low} must be <= high={high}")
        if log and low <= 0:
            raise ValueError(f"low={low} must be > 0 with log=True")
        if step <= 0:
            raise ValueError(f"step={step} must be > 0")
        if log and step != 1:
            raise ValueError("log and step!=1 are mutually exclusive")
        self.low = int(low)
        self.high = int(high)
        self.log = bool(log)
        self.step = int(step)

    def single(self) -> bool:
        return self.high - self.low < self.step

    def _contains(self, internal: float) -> bool:
        v = int(round(internal))
        return self.low <= v <= self.high

    def to_external_repr(self, internal: float) -> int:
        return int(round(internal))

    def _asdict(self) -> dict:
        return {"low": self.low, "high": self.high, "log": self.log, "step": self.step}


class CategoricalDistribution(BaseDistribution):
    """A finite unordered set of choices.

    Choices must be json-serializable (None, bool, int, float, str); this is
    what lets every storage backend persist them.
    """

    def __init__(self, choices: Sequence[Any]):
        if len(choices) == 0:
            raise ValueError("choices must not be empty")
        for c in choices:
            if c is not None and not isinstance(c, (bool, int, float, str)):
                raise ValueError(
                    f"categorical choice {c!r} of type {type(c).__name__} is not "
                    "json-serializable; use None/bool/int/float/str"
                )
        self.choices = tuple(choices)

    def single(self) -> bool:
        return len(self.choices) == 1

    def _contains(self, internal: float) -> bool:
        idx = int(round(internal))
        return 0 <= idx < len(self.choices)

    def to_external_repr(self, internal: float) -> Any:
        return self.choices[int(round(internal))]

    def to_internal_repr(self, external: Any) -> float:
        # type-aware match: in Python 0 == False, so .index() would conflate
        # int and bool choices (hypothesis-found edge case)
        for i, c in enumerate(self.choices):
            if type(c) is type(external) and c == external:
                return float(i)
        for i, c in enumerate(self.choices):  # fall back to plain equality
            if c == external:
                return float(i)
        raise ValueError(f"{external!r} is not one of the choices {self.choices!r}")

    def _asdict(self) -> dict:
        return {"choices": list(self.choices)}


_CLASSES = {
    "FloatDistribution": FloatDistribution,
    "IntDistribution": IntDistribution,
    "CategoricalDistribution": CategoricalDistribution,
}


def distribution_to_json(dist: BaseDistribution) -> str:
    return json.dumps({"name": type(dist).__name__, "attributes": dist._asdict()})


def json_to_distribution(s: str) -> BaseDistribution:
    obj = json.loads(s)
    cls = _CLASSES[obj["name"]]
    return cls(**obj["attributes"])


def check_distribution_compatibility(old: BaseDistribution, new: BaseDistribution) -> None:
    """Raise if a parameter is re-suggested with an incompatible domain.

    Define-by-run allows the *structure* of the space to change across trials,
    but a given parameter name must keep the same distribution *type* (and the
    same choices for categoricals) so sampler history stays meaningful.
    Bounds of numeric domains may move (Optuna semantics).
    """
    if type(old) is not type(new):
        raise ValueError(
            f"inconsistent distribution types for one parameter: {old!r} vs {new!r}"
        )
    if isinstance(old, CategoricalDistribution) and old != new:
        raise ValueError(f"inconsistent categorical choices: {old!r} vs {new!r}")
