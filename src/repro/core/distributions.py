"""Parameter distributions for the define-by-run search space.

A distribution describes the domain a single ``trial.suggest_*`` call samples
from.  Because the search space is constructed *dynamically* (define-by-run),
distributions are recorded per-(trial, parameter) in storage, and the
intersection over completed trials recovers the concurrence relations the
relational samplers (CMA-ES, GP) need (paper §3.1).

Internal representation
-----------------------
Every parameter value is stored as a float ("internal repr"):

* Float  -> the value itself
* Int    -> float(value)
* Categorical -> float(index into ``choices``)

``to_external_repr``/``to_internal_repr`` convert between the two.  This is
the same trick Optuna uses so that storage backends only ever persist floats.

Model space (array codecs)
--------------------------
Samplers model parameters in a second, *model-space* encoding where numeric
domains are additionally log-transformed when ``log=True`` (categoricals stay
choice indices).  The vectorized codecs convert whole arrays at once — this
is the encoding the columnar observation store (``core/records.py``) keeps
its ``(n_trials, n_params)`` matrix in:

* ``to_internal(xs)``     external values -> model-space float array
* ``from_internal(xs)``   model-space array -> internal-repr float array
  (exp of log space, step rounding, clipping to the domain)
* ``internal_bounds()``   the model-space domain, with the TPE-style ±0.5
  integer expansion available via ``expand_int=True``
* ``internal_to_unit()``  model space -> [0, 1] (the CMA-ES/GP coordinate)
* ``sample_uniform(rng, size)``  vectorized uniform draws in internal repr
"""

from __future__ import annotations

import json
import math
from typing import Any, Sequence

import numpy as np

__all__ = [
    "BaseDistribution",
    "FloatDistribution",
    "IntDistribution",
    "CategoricalDistribution",
    "distribution_to_json",
    "json_to_distribution",
    "check_distribution_compatibility",
    "round_to_step",
]

_EPS = 1e-12


def round_to_step(x, low: float, high: float, step: "float | int"):
    """Snap ``x`` (scalar or array) onto the grid ``low + k*step``."""
    if isinstance(x, np.ndarray):
        return low + np.round((x - low) / step) * step
    return low + round((x - low) / step) * step


class BaseDistribution:
    """Base class of parameter distributions."""

    def to_external_repr(self, internal: float) -> Any:
        return internal

    def to_internal_repr(self, external: Any) -> float:
        return float(external)

    # -- vectorized model-space codecs ----------------------------------------

    def to_internal(self, external: Sequence[Any]) -> np.ndarray:
        """Vectorized: external values -> model-space float array."""
        raise NotImplementedError

    def from_internal(self, internal: np.ndarray) -> np.ndarray:
        """Vectorized: model-space array -> internal-repr float array
        (rounded onto the domain; convert each element with
        ``to_external_repr`` to recover external values)."""
        raise NotImplementedError

    def internal_bounds(self, expand_int: bool = False) -> tuple[float, float]:
        """The model-space domain ``[low, high]``.  ``expand_int=True`` widens
        integer domains by ±0.5 (the continuous relaxation TPE models)."""
        raise NotImplementedError

    def internal_to_unit(self, internal: np.ndarray) -> np.ndarray:
        """Model space -> [0, 1] coordinates (CMA-ES/GP design matrices)."""
        low, high = self.internal_bounds()
        xs = np.asarray(internal, dtype=float)
        if high > low:
            return (xs - low) / (high - low)
        return np.full_like(xs, 0.5)

    def sample_uniform(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        """Vectorized uniform draws in *internal repr* (honoring log/step).
        Stream-compatible with the historical scalar draws: ``size=1``
        consumes the RNG exactly as one scalar call did."""
        raise NotImplementedError

    def single(self) -> bool:
        """True if the domain contains exactly one value."""
        raise NotImplementedError

    def _contains(self, internal: float) -> bool:
        raise NotImplementedError

    def _asdict(self) -> dict:
        raise NotImplementedError

    def __eq__(self, other: object) -> bool:
        return type(self) is type(other) and self._asdict() == other._asdict()  # type: ignore[attr-defined]

    def __hash__(self) -> int:
        return hash((type(self).__name__, json.dumps(self._asdict(), sort_keys=True, default=str)))

    def __repr__(self) -> str:
        kwargs = ", ".join(f"{k}={v!r}" for k, v in self._asdict().items())
        return f"{type(self).__name__}({kwargs})"


class FloatDistribution(BaseDistribution):
    """A continuous domain ``[low, high]``.

    Args:
        low/high: inclusive bounds.
        log: sample in log space (requires ``low > 0``).
        step: discretization step (mutually exclusive with ``log``).
    """

    def __init__(self, low: float, high: float, log: bool = False, step: float | None = None):
        if math.isnan(low) or math.isnan(high):
            raise ValueError("low/high must not be NaN")
        if low > high:
            raise ValueError(f"low={low} must be <= high={high}")
        if log and step is not None:
            raise ValueError("log and step are mutually exclusive")
        if log and low <= 0.0:
            raise ValueError(f"low={low} must be > 0 with log=True")
        if step is not None and step <= 0:
            raise ValueError(f"step={step} must be > 0")
        self.low = float(low)
        self.high = float(high)
        self.log = bool(log)
        self.step = float(step) if step is not None else None

    def single(self) -> bool:
        if self.step is not None:
            return self.high - self.low < self.step
        return self.low == self.high

    def _contains(self, internal: float) -> bool:
        return self.low <= internal <= self.high

    def to_external_repr(self, internal: float) -> float:
        return float(internal)

    def to_internal(self, external: Sequence[Any]) -> np.ndarray:
        xs = np.asarray(external, dtype=float)
        if self.log:
            return np.log(np.maximum(xs, _EPS))
        return xs

    def from_internal(self, internal: np.ndarray) -> np.ndarray:
        xs = np.asarray(internal, dtype=float)
        if self.log:
            xs = np.exp(xs)
        if self.step is not None:
            xs = round_to_step(xs, self.low, self.high, self.step)
        return np.clip(xs, self.low, self.high)

    def internal_bounds(self, expand_int: bool = False) -> tuple[float, float]:
        if self.log:
            return math.log(self.low), math.log(self.high)
        return self.low, self.high

    def sample_uniform(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        if self.log:
            return np.exp(rng.uniform(np.log(self.low), np.log(self.high), size=size))
        if self.step is not None:
            n = int(np.floor((self.high - self.low) / self.step + 1e-12)) + 1
            return self.low + rng.randint(n, size=size) * self.step
        return rng.uniform(self.low, self.high, size=size)

    def _asdict(self) -> dict:
        return {"low": self.low, "high": self.high, "log": self.log, "step": self.step}


class IntDistribution(BaseDistribution):
    """An integer domain ``{low, low+step, ..., high}`` (or log-uniform ints)."""

    def __init__(self, low: int, high: int, log: bool = False, step: int = 1):
        if low > high:
            raise ValueError(f"low={low} must be <= high={high}")
        if log and low <= 0:
            raise ValueError(f"low={low} must be > 0 with log=True")
        if step <= 0:
            raise ValueError(f"step={step} must be > 0")
        if log and step != 1:
            raise ValueError("log and step!=1 are mutually exclusive")
        self.low = int(low)
        self.high = int(high)
        self.log = bool(log)
        self.step = int(step)

    def single(self) -> bool:
        return self.high - self.low < self.step

    def _contains(self, internal: float) -> bool:
        v = int(round(internal))
        return self.low <= v <= self.high

    def to_external_repr(self, internal: float) -> int:
        return int(round(internal))

    def to_internal(self, external: Sequence[Any]) -> np.ndarray:
        xs = np.asarray(external, dtype=float)
        if self.log:
            return np.log(np.maximum(xs, _EPS))
        return xs

    def from_internal(self, internal: np.ndarray) -> np.ndarray:
        xs = np.asarray(internal, dtype=float)
        if self.log:
            xs = np.exp(xs)
        xs = round_to_step(xs, self.low, self.high, self.step)
        return np.clip(xs, self.low, self.high)

    def internal_bounds(self, expand_int: bool = False) -> tuple[float, float]:
        low, high = float(self.low), float(self.high)
        if expand_int:
            low, high = low - 0.5, high + 0.5
            if self.log:
                low = max(low, 0.5)
        if self.log:
            return math.log(low), math.log(high)
        return low, high

    def sample_uniform(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        if self.log:
            lo, hi = np.log(self.low - 0.5), np.log(self.high + 0.5)
            v = np.clip(np.round(np.exp(rng.uniform(lo, hi, size=size))), self.low, self.high)
            return v.astype(float)
        n = (self.high - self.low) // self.step + 1
        return (self.low + rng.randint(n, size=size) * self.step).astype(float)

    def _asdict(self) -> dict:
        return {"low": self.low, "high": self.high, "log": self.log, "step": self.step}


class CategoricalDistribution(BaseDistribution):
    """A finite unordered set of choices.

    Choices must be json-serializable (None, bool, int, float, str); this is
    what lets every storage backend persist them.
    """

    def __init__(self, choices: Sequence[Any]):
        if len(choices) == 0:
            raise ValueError("choices must not be empty")
        for c in choices:
            if c is not None and not isinstance(c, (bool, int, float, str)):
                raise ValueError(
                    f"categorical choice {c!r} of type {type(c).__name__} is not "
                    "json-serializable; use None/bool/int/float/str"
                )
        self.choices = tuple(choices)

    def single(self) -> bool:
        return len(self.choices) == 1

    def _contains(self, internal: float) -> bool:
        idx = int(round(internal))
        return 0 <= idx < len(self.choices)

    def to_external_repr(self, internal: float) -> Any:
        return self.choices[int(round(internal))]

    def to_internal_repr(self, external: Any) -> float:
        # type-aware match: in Python 0 == False, so .index() would conflate
        # int and bool choices (hypothesis-found edge case)
        for i, c in enumerate(self.choices):
            if type(c) is type(external) and c == external:
                return float(i)
        for i, c in enumerate(self.choices):  # fall back to plain equality
            if c == external:
                return float(i)
        raise ValueError(f"{external!r} is not one of the choices {self.choices!r}")

    def to_internal(self, external: Sequence[Any]) -> np.ndarray:
        # choice matching is type-aware (see to_internal_repr) so this stays a
        # per-element loop; it only runs on the few rows of an incremental
        # ingest, never on the ask hot path
        return np.asarray([self.to_internal_repr(v) for v in external], dtype=float)

    def from_internal(self, internal: np.ndarray) -> np.ndarray:
        xs = np.round(np.asarray(internal, dtype=float))
        return np.clip(xs, 0.0, float(len(self.choices) - 1))

    def internal_bounds(self, expand_int: bool = False) -> tuple[float, float]:
        return 0.0, float(len(self.choices) - 1)

    def internal_to_unit(self, internal: np.ndarray) -> np.ndarray:
        # CMA-ES/GP exclude categoricals; the unit coordinate is the index
        return np.asarray(internal, dtype=float)

    def sample_uniform(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        return rng.randint(len(self.choices), size=size).astype(float)

    def _asdict(self) -> dict:
        return {"choices": list(self.choices)}


_CLASSES = {
    "FloatDistribution": FloatDistribution,
    "IntDistribution": IntDistribution,
    "CategoricalDistribution": CategoricalDistribution,
}


def distribution_to_json(dist: BaseDistribution) -> str:
    return json.dumps({"name": type(dist).__name__, "attributes": dist._asdict()})


def json_to_distribution(s: str) -> BaseDistribution:
    obj = json.loads(s)
    cls = _CLASSES[obj["name"]]
    return cls(**obj["attributes"])


def check_distribution_compatibility(old: BaseDistribution, new: BaseDistribution) -> None:
    """Raise if a parameter is re-suggested with an incompatible domain.

    Define-by-run allows the *structure* of the space to change across trials,
    but a given parameter name must keep the same distribution *type* (and the
    same choices for categoricals) so sampler history stays meaningful.
    Bounds of numeric domains may move (Optuna semantics).
    """
    if type(old) is not type(new):
        raise ValueError(
            f"inconsistent distribution types for one parameter: {old!r} vs {new!r}"
        )
    if isinstance(old, CategoricalDistribution) and old != new:
        raise ValueError(f"inconsistent categorical choices: {old!r} vs {new!r}")
