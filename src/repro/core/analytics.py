"""Columnar plot reductions for the live analytics service (paper §4's
web-dashboard criterion).

Every dashboard view is computed here as an array reduction over the
columnar stores (``core/records.py``) — no ``FrozenTrial`` walks:

* optimization history — running-best prefix scan over the COMPLETE mask
  (:func:`running_best`),
* contour — 2-D grid binning of the objective over two model-space
  parameter columns, best value per cell (:func:`contour_reduction`),
* slice — per-parameter scatter plus binned quantile band
  (:func:`slice_reduction`),
* Pareto front — front mask from the multi-objective engine
  (``core/moo.pareto_front_mask``),
* learning curves — rows of the intermediate-value matrix, per objective on
  vector-reporting studies.

Randomized parity tests against brute-force per-trial reference loops live
in ``tests/test_analytics.py``.

:class:`StudyAnalytics` wraps one study with payload caches keyed on the
stores' version counters, so an idle study renders for free; the
:class:`RevisionPoller` is the one revision-gated poll loop shared by
``dashboard --live`` and the HTTP service (``serve/dashboard_service.py``).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any

import numpy as np

from . import moo, telemetry
from .frozen import TrialState
from .importance import fanova_importances, spearman_importances

if TYPE_CHECKING:
    from .records import IntermediateValueStore
    from .study import Study

__all__ = [
    "RevisionPoller",
    "StudyAnalytics",
    "running_best",
    "contour_reduction",
    "slice_reduction",
    "learning_curves",
    "jsonable",
]

_COMPLETE = int(TrialState.COMPLETE)


def jsonable(obj: Any) -> Any:
    """Strict-JSON-safe conversion: numpy scalars/arrays to native Python,
    non-finite floats to ``None`` (browser ``JSON.parse`` rejects NaN)."""
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if math.isfinite(f) else None
    if isinstance(obj, (np.integer, int)) and not isinstance(obj, bool):
        return int(obj)
    if isinstance(obj, np.ndarray):
        return [jsonable(v) for v in obj.tolist()]
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# Pure columnar reductions (parity-tested vs per-trial reference loops)
# ---------------------------------------------------------------------------


def running_best(
    numbers: np.ndarray, values: np.ndarray, states: np.ndarray, minimize: bool
) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
    """``(numbers, values, best)`` over COMPLETE finite trials in number
    order — the optimization-history view.  ``best[i]`` is the best value
    among the first ``i+1`` usable trials (NaN-free prefix scan)."""
    mask = (states == _COMPLETE) & np.isfinite(values)
    y = values[mask].astype(float)
    op = np.fmin if minimize else np.fmax
    best = op.accumulate(y) if y.size else y
    return numbers[mask], y, best


def contour_reduction(
    x: np.ndarray,
    y: np.ndarray,
    z: np.ndarray,
    mask: np.ndarray,
    nx: int = 24,
    ny: int = 24,
    minimize: bool = True,
) -> "tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]":
    """2-D grid binning of objective ``z`` over two model-space parameter
    columns: ``(x_edges, y_edges, grid, counts)`` where ``grid[r, c]`` is the
    best ``z`` among masked points falling in cell (r, c) (NaN when empty).

    One ``minimum.at``/``maximum.at`` scatter — no per-trial Python loop."""
    m = mask & np.isfinite(x) & np.isfinite(y) & np.isfinite(z)
    xs, ys, zs = x[m].astype(float), y[m].astype(float), z[m].astype(float)
    if xs.size == 0:
        return np.zeros(nx + 1), np.zeros(ny + 1), np.full((ny, nx), np.nan), np.zeros((ny, nx), dtype=np.int64)
    xlo, xhi = float(xs.min()), float(xs.max())
    ylo, yhi = float(ys.min()), float(ys.max())
    if xhi <= xlo:
        xhi = xlo + 1.0
    if yhi <= ylo:
        yhi = ylo + 1.0
    xe = np.linspace(xlo, xhi, nx + 1)
    ye = np.linspace(ylo, yhi, ny + 1)
    ix = np.minimum(((xs - xlo) / (xhi - xlo) * nx).astype(np.int64), nx - 1)
    iy = np.minimum(((ys - ylo) / (yhi - ylo) * ny).astype(np.int64), ny - 1)
    flat = iy * nx + ix
    init = np.inf if minimize else -np.inf
    acc = np.full(nx * ny, init)
    (np.minimum if minimize else np.maximum).at(acc, flat, zs)
    counts = np.zeros(nx * ny, dtype=np.int64)
    np.add.at(counts, flat, 1)
    grid = np.where(counts > 0, acc, np.nan).reshape(ny, nx)
    return xe, ye, grid, counts.reshape(ny, nx)


def slice_reduction(
    x: np.ndarray,
    z: np.ndarray,
    mask: np.ndarray,
    n_bins: int = 10,
) -> dict:
    """Per-parameter slice view: the masked ``(x, z)`` scatter plus a binned
    median/p25/p75 band (``centers``/``med``/``lo``/``hi``/``counts``)."""
    m = mask & np.isfinite(x) & np.isfinite(z)
    xs, zs = x[m].astype(float), z[m].astype(float)
    out = {"x": xs, "z": zs}
    if xs.size == 0:
        out["bins"] = {"centers": np.empty(0), "med": np.empty(0),
                       "lo": np.empty(0), "hi": np.empty(0),
                       "counts": np.empty(0, dtype=np.int64)}
        return out
    blo, bhi = float(xs.min()), float(xs.max())
    if bhi <= blo:
        bhi = blo + 1.0
    ib = np.minimum(((xs - blo) / (bhi - blo) * n_bins).astype(np.int64), n_bins - 1)
    centers, med, lo_q, hi_q, counts = [], [], [], [], []
    width = (bhi - blo) / n_bins
    for b in range(n_bins):
        sel = zs[ib == b]
        if sel.size == 0:
            continue
        centers.append(blo + (b + 0.5) * width)
        med.append(float(np.median(sel)))
        lo_q.append(float(np.percentile(sel, 25)))
        hi_q.append(float(np.percentile(sel, 75)))
        counts.append(int(sel.size))
    out["bins"] = {
        "centers": np.asarray(centers),
        "med": np.asarray(med),
        "lo": np.asarray(lo_q),
        "hi": np.asarray(hi_q),
        "counts": np.asarray(counts, dtype=np.int64),
    }
    return out


def learning_curves(
    store: "IntermediateValueStore",
    max_curves: int = 64,
    objective: "int | None" = None,
) -> dict:
    """The last ``max_curves`` reporting trials' curves off the IV matrix:
    ``(steps, numbers, states, matrix)`` (rows aligned with numbers).  With
    ``objective=k`` the per-objective tensor slice is used instead of the
    scalar (pruner-facing) matrix."""
    with store.lock():
        matrix = store.matrix if objective is None else store.objective_matrix(objective)
        states = store.states
        steps = store.steps
        has = np.isfinite(matrix).any(axis=1) if matrix.size else np.zeros(0, dtype=bool)
        rows = np.flatnonzero(has)[-max_curves:]
        return {
            "steps": steps.copy(),
            "numbers": rows,
            "states": states[rows] if rows.size else rows,
            "matrix": matrix[rows] if rows.size else np.empty((0, steps.size)),
        }


# ---------------------------------------------------------------------------
# Revision-gated polling (shared by dashboard --live and the HTTP service)
# ---------------------------------------------------------------------------


class RevisionPoller:
    """The one revision-gated poll loop: ``poll()`` costs exactly one
    ``get_trials_revision`` call and reports whether anything changed since
    the previous poll.  Both the ``--live`` terminal dashboard and every
    HTTP delta endpoint go through this class, so "idle study = zero
    refetch" is pinned in one place (telemetry counters
    ``dashboard.poll.idle`` / ``dashboard.poll.changed``)."""

    def __init__(self, storage, study_id: int):
        self._storage = storage
        self._study_id = study_id
        self.rev = -1
        self.ticks = 0
        self.changes = 0

    def poll(self) -> bool:
        """True iff the study mutated since the last poll (always True on
        the first)."""
        rev = int(self._storage.get_trials_revision(self._study_id))
        self.ticks += 1
        if rev != self.rev:
            self.rev = rev
            self.changes += 1
            telemetry.inc("dashboard.poll.changed")
            return True
        telemetry.inc("dashboard.poll.idle")
        return False


# ---------------------------------------------------------------------------
# Per-study analytics engine
# ---------------------------------------------------------------------------


class StudyAnalytics:
    """All five dashboard views for one study, as version-cached columnar
    reductions.  Payloads are plain JSON-safe dicts (see :func:`jsonable`)
    ready for the HTTP service; an unchanged store serves the cached payload
    with zero recomputation."""

    def __init__(
        self,
        study: "Study",
        contour_bins: int = 24,
        slice_bins: int = 10,
        max_curves: int = 48,
        max_slice_params: int = 8,
    ):
        self._study = study
        self._contour_bins = contour_bins
        self._slice_bins = slice_bins
        self._max_curves = max_curves
        self._max_slice_params = max_slice_params
        self._views_cache: "tuple[tuple, dict] | None" = None
        self._imp_cache: "tuple[int, dict] | None" = None

    @property
    def study(self) -> "Study":
        return self._study

    # -- incremental rows (delta endpoint) -----------------------------------

    def delta_rows(self, since_number: int) -> dict:
        """Finished-trial rows with ``number > since_number`` — O(new
        trials): the store refresh is watermark-incremental and the row walk
        starts at a ``searchsorted`` offset."""
        store = self._study.observations()
        _, states, Vm, arity, numbers, cols = store.snapshot_mo()
        dists = {name: store.distribution(name) for name in cols}
        start = int(np.searchsorted(numbers, int(since_number), side="right"))
        values_first = store.values
        m = Vm.shape[1]
        rows = []
        for i in range(start, numbers.size):
            params = {}
            for name, col in cols.items():
                xv = col[i]
                if np.isfinite(xv):
                    d = dists.get(name)
                    params[name] = d.to_external_repr(float(xv)) if d is not None else float(xv)
            if int(arity[i]) == m:
                vals = list(Vm[i])
            elif np.isfinite(values_first[i]):
                vals = [float(values_first[i])]
            else:
                vals = []
            rows.append(
                {
                    "number": int(numbers[i]),
                    "state": TrialState(int(states[i])).name,
                    "values": jsonable(vals),
                    "params": jsonable(params),
                }
            )
        return {
            "rows": rows,
            "last_number": int(numbers[-1]) if numbers.size else int(since_number),
            "n_finished": int(numbers.size),
        }

    # -- full views ----------------------------------------------------------

    def importances(self) -> dict:
        """fANOVA + Spearman importances, cached on the observation store's
        version so an idle study never re-fits the tree ensemble."""
        store = self._study.observations()
        version = store.version
        if self._imp_cache is not None and self._imp_cache[0] == version:
            return self._imp_cache[1]
        n_obj = len(self._study.directions)

        def flatten(res) -> dict:
            # per-objective dicts keyed by stringified index for JSON
            if n_obj > 1:
                return {str(k): jsonable(v) for k, v in res.items()}
            return {"0": jsonable(res)}

        payload = {
            "fanova": flatten(fanova_importances(self._study)),
            "spearman": flatten(spearman_importances(self._study)),
        }
        self._imp_cache = (version, payload)
        return payload

    def views(self) -> dict:
        """All five views as one JSON-safe payload, cached on the
        (observation version, IV version) pair."""
        study = self._study
        store = study.observations()
        iv = study.intermediate_values()
        key = (store.version, iv.version)
        if self._views_cache is not None and self._views_cache[0] == key:
            return self._views_cache[1]

        directions = study.directions
        n_obj = len(directions)
        _, states, Vm, arity, numbers, cols = store.snapshot_mo()
        values_first = store.values

        # optimization history, per objective
        history = []
        for k in range(n_obj):
            col = Vm[:, k] if Vm.shape[1] > k else values_first
            if n_obj == 1:
                col = values_first
            nums, vals, best = running_best(
                numbers, col, states, minimize=(int(directions[k]) == 0)
            )
            history.append(
                {"numbers": jsonable(nums), "values": jsonable(vals), "best": jsonable(best)}
            )

        # contour over the two most important params (fallback: first two)
        names = store.param_names()
        imp = self.importances()["fanova"].get("0", {})
        ranked = [n for n in imp if n in names] + [n for n in names if n not in imp]
        contour = None
        if len(ranked) >= 2 and numbers.size:
            xn, yn = ranked[0], ranked[1]
            xcol, ycol = cols.get(xn), cols.get(yn)
            if xcol is not None and ycol is not None:
                mask = states == _COMPLETE
                xe, ye, grid, counts = contour_reduction(
                    xcol, ycol, values_first, mask,
                    nx=self._contour_bins, ny=self._contour_bins,
                    minimize=(int(directions[0]) == 0),
                )
                contour = {
                    "x_param": xn, "y_param": yn,
                    "x_edges": jsonable(xe), "y_edges": jsonable(ye),
                    "grid": jsonable(grid), "counts": jsonable(counts),
                }

        # slice view per parameter (model space), capped
        slices = []
        mask = states == _COMPLETE
        for name in ranked[: self._max_slice_params]:
            col = cols.get(name)
            if col is None:
                continue
            s = slice_reduction(col, values_first, mask, n_bins=self._slice_bins)
            slices.append({"param": name, **{k: jsonable(v) for k, v in s.items()}})

        # Pareto front (2-objective view)
        pareto = None
        if n_obj == 2:
            pmask = (states == _COMPLETE) & (arity == n_obj)
            front = moo.pareto_front_mask(
                moo.loss_matrix(Vm, directions), mask=pmask
            )
            pareto = {
                "numbers": jsonable(numbers[pmask]),
                "values": jsonable(Vm[pmask]),
                "front_numbers": jsonable(numbers[front]),
            }

        # learning curves (per-objective on vector-reporting studies)
        curves = {"objectives": []}
        iv_obj = iv.n_objectives
        for k in range(iv_obj if iv_obj > 1 else 1):
            lc = learning_curves(
                iv, max_curves=self._max_curves,
                objective=(k if iv_obj > 1 else None),
            )
            curves["objectives"].append(
                {
                    "steps": jsonable(lc["steps"]),
                    "numbers": jsonable(lc["numbers"]),
                    "states": jsonable(lc["states"]),
                    "matrix": jsonable(lc["matrix"]),
                }
            )

        n_by_state: dict[str, int] = {}
        for s in states:
            name = TrialState(int(s)).name
            n_by_state[name] = n_by_state.get(name, 0) + 1
        payload = {
            "study": study.study_name,
            "directions": [d.name.lower() for d in directions],
            "n_finished": int(numbers.size),
            "by_state": n_by_state,
            "history": history,
            "contour": contour,
            "slices": slices,
            "pareto": pareto,
            "curves": curves,
            "importance": self.importances(),
        }
        self._views_cache = (key, payload)
        return payload
