"""``repro.core`` — a define-by-run hyperparameter optimization engine.

The paper's contribution (Optuna, KDD'19), reimplemented: live trial objects
with a suggest API, TPE/CMA-ES/GP samplers over dynamically constructed
search spaces, ASHA pruning (paper Algorithm 1), and storage-mediated
distributed execution.

    import repro.core as hpo

    def objective(trial):
        x = trial.suggest_float("x", -10, 10)
        return (x - 2) ** 2

    study = hpo.create_study()
    study.optimize(objective, n_trials=100)
    print(study.best_params)
"""

from __future__ import annotations

from .dashboard import render_dashboard, save_dashboard
from .distributed import RetryFailedTrialCallback, run_workers, worker_main
from .distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from .exceptions import DuplicatedStudyError, StorageInternalError, TrialPruned
from .frozen import FrozenTrial, StudyDirection, TrialState
from .importance import fanova_importances, param_importances, spearman_importances
from . import moo
from . import telemetry
from .records import ObservationStore
from .pruners import (
    BasePruner,
    HyperbandPruner,
    MedianPruner,
    NopPruner,
    ParetoPruner,
    PatientPruner,
    PercentilePruner,
    SuccessiveHalvingPruner,
    ThresholdPruner,
    make_pruner,
)
from .samplers import (
    CMA,
    BaseSampler,
    CmaEsSampler,
    GPSampler,
    GridSampler,
    NSGAIISampler,
    RandomSampler,
    TPESampler,
    make_sampler,
)
from .search_space import IntersectionSearchSpace, intersection_search_space
from .storage import (
    BaseStorage,
    CachedStorage,
    InMemoryStorage,
    JournalStorage,
    RemoteStorage,
    SQLiteStorage,
    StorageServer,
    get_storage,
)
from .study import Study, create_study, delete_study, load_study
from .trial import FixedTrial, Trial

__all__ = [
    # study / trial
    "Study", "create_study", "load_study", "delete_study",
    "Trial", "FixedTrial", "FrozenTrial", "TrialState", "StudyDirection",
    # distributions
    "BaseDistribution", "FloatDistribution", "IntDistribution", "CategoricalDistribution",
    # samplers
    "BaseSampler", "RandomSampler", "GridSampler", "TPESampler", "CmaEsSampler",
    "CMA", "GPSampler", "NSGAIISampler", "make_sampler",
    # pruners
    "BasePruner", "NopPruner", "SuccessiveHalvingPruner", "MedianPruner",
    "PercentilePruner", "HyperbandPruner", "ThresholdPruner", "PatientPruner",
    "ParetoPruner", "make_pruner",
    # multi-objective engine
    "moo",
    # observability
    "telemetry",
    # storage
    "BaseStorage", "InMemoryStorage", "SQLiteStorage", "JournalStorage",
    "RemoteStorage", "CachedStorage", "StorageServer", "get_storage",
    # distributed / misc
    "run_workers", "worker_main", "RetryFailedTrialCallback",
    "TrialPruned", "DuplicatedStudyError", "StorageInternalError",
    "intersection_search_space", "IntersectionSearchSpace",
    "ObservationStore",
    "param_importances", "spearman_importances", "fanova_importances",
    "render_dashboard", "save_dashboard",
]
