"""One configured logger tree for ``repro.core`` with study/worker context.

Module loggers keep their stdlib names (``repro.core.study`` etc. — pinned by
caplog tests), but are obtained through :func:`get_logger` so they all hang
off one configured ``repro`` root: a :class:`logging.NullHandler` by default
(library-quiet), upgraded to a context-rich stream handler by
:func:`configure` for CLIs and worker fleets.  Every record passing through
gets a ``worker`` attribute (``host:pid``, or the remote peer inside server
handlers) from :mod:`repro.core.telemetry`.

Fallback warnings that would otherwise fire per-trial are funneled through
:func:`log_once` (exactly once per key, e.g. once per study) and
:class:`RateLimiter` (at most once per interval per key).
"""

from __future__ import annotations

import logging
import threading
import time
from typing import Any

from . import telemetry

__all__ = ["get_logger", "configure", "log_once", "reset_once", "RateLimiter"]

_FORMAT = "%(asctime)s %(levelname)s [%(worker)s] %(name)s: %(message)s"

_setup_lock = threading.Lock()
_configured = False


class _WorkerContextFilter(logging.Filter):
    """Stamp each record with the emitting worker's identity."""

    def filter(self, record: logging.LogRecord) -> bool:
        record.worker = telemetry.worker_id()
        return True


def _ensure_root() -> logging.Logger:
    """Attach a NullHandler + worker filter to the ``repro`` root exactly once
    (library default: quiet, but records still flow to caplog/user handlers)."""
    global _configured
    root = logging.getLogger("repro")
    if not _configured:
        with _setup_lock:
            if not _configured:
                root.addFilter(_WorkerContextFilter())
                if not root.handlers:
                    root.addHandler(logging.NullHandler())
                _configured = True
    return root


def get_logger(name: str) -> logging.Logger:
    """Module logger under the configured ``repro`` root; same stdlib names
    as ``logging.getLogger(__name__)`` so caplog filters keep working."""
    _ensure_root()
    return logging.getLogger(name)


def configure(level: int = logging.INFO) -> logging.Logger:
    """Opt-in CLI/worker setup: stream handler with worker context on the
    ``repro`` root.  Idempotent — repeated calls only adjust the level."""
    root = _ensure_root()
    root.setLevel(level)
    for h in root.handlers:
        if isinstance(h, logging.StreamHandler) and not isinstance(
            h, logging.NullHandler
        ):
            h.setLevel(level)
            return root
    handler = logging.StreamHandler()
    handler.setLevel(level)
    handler.setFormatter(logging.Formatter(_FORMAT))
    root.addHandler(handler)
    return root


# ---------------------------------------------------------------------------
# once-per-key / rate-limited emission
# ---------------------------------------------------------------------------

_once_lock = threading.Lock()
_once_seen: set = set()


def log_once(
    logger: logging.Logger, key: Any, level: int, msg: str, *args: Any
) -> bool:
    """Emit ``msg`` at ``level`` exactly once per ``key`` per process.

    The key carries the dedup scope — e.g. ``("joint_miss", id(study))`` for
    the once-per-study joint-sampling fallback.  Returns True when the record
    was actually emitted.
    """
    with _once_lock:
        if key in _once_seen:
            return False
        _once_seen.add(key)
    logger.log(level, msg, *args)
    return True


def reset_once(key: Any = None) -> None:
    """Forget one dedup key (or all of them) — test isolation hook."""
    with _once_lock:
        if key is None:
            _once_seen.clear()
        else:
            _once_seen.discard(key)


class RateLimiter:
    """At most one emission per ``interval`` seconds per key; drops (and
    counts) the rest.  For chatty retry/fallback paths in worker fleets."""

    def __init__(self, interval: float = 30.0):
        self.interval = float(interval)
        self._lock = threading.Lock()
        self._last: dict[Any, float] = {}
        self._dropped: dict[Any, int] = {}

    def log(
        self, logger: logging.Logger, key: Any, level: int, msg: str, *args: Any
    ) -> bool:
        now = time.monotonic()
        with self._lock:
            last = self._last.get(key)
            if last is not None and now - last < self.interval:
                self._dropped[key] = self._dropped.get(key, 0) + 1
                return False
            dropped = self._dropped.pop(key, 0)
            self._last[key] = now
        if dropped:
            msg = msg + " (%d similar suppressed)"
            args = args + (dropped,)
        logger.log(level, msg, *args)
        return True
