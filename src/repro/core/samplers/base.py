"""Sampler interface.

The split mirrors the paper's two sampling families (§3.1):

* ``sample_independent`` — per-parameter sampling (random, TPE), invoked for
  every parameter not covered by the relational stage.
* ``infer_relative_search_space`` + ``sample_relative`` — relational sampling
  over the inferred concurrence relations (CMA-ES, GP), invoked once per
  trial before any suggest call resolves.
* ``sample_joint`` — block sampling: one call covers **all pending trials**
  of a batched ``Study.ask(n)`` for one co-observed parameter group
  (``search_space.ParamGroup``), returning an ``(n, len(group))`` matrix of
  model-space rows.  The define-by-run ``suggest_*`` API then *slices* the
  precomputed block instead of sampling per (trial, parameter); trials whose
  runtime search space diverges from the group prediction fall back to
  scalar sampling (see ``Trial._sample``).
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import BaseDistribution
from ..frozen import FrozenTrial

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["BaseSampler", "sample_uniform_internal"]


class BaseSampler:
    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        return {}

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        return {}

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        raise NotImplementedError

    # -- block (joint) sampling -------------------------------------------------

    def joint_enabled(self) -> bool:
        """Whether ``Study.ask(n)`` should presample joint blocks with this
        sampler at all.  The default detects a ``sample_joint`` override, so
        custom samplers keep the per-trial path untouched; samplers with a
        mode switch (TPE's ``multivariate=``) override this with the flag."""
        return type(self).sample_joint is not BaseSampler.sample_joint

    def sample_joint(
        self,
        study: "Study",
        group: "ParamGroup",
        n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """Sample one ``(n, len(group.names))`` block of **model-space** rows
        for ``n`` pending trials of one co-observed parameter group.

        Return ``None`` to decline the whole group (no joint model yet —
        startup, warmup, ...): those parameters then go through the ordinary
        per-trial relational/independent path.  A returned block may carry
        ``NaN`` cells to decline individual columns (e.g. CMA-ES excludes
        categoricals); NaN cells silently fall back to scalar sampling
        without counting as a group-prediction miss.

        ``trial_ids`` are the storage ids of the pending trials, for
        samplers whose joint draw has per-trial side effects (the grid
        sampler claims one cell per trial).  ``first_number`` is the first
        pending trial's storage-assigned number — the wave's RNG key for
        samplers that derive per-wave streams deterministically (CMA-ES):
        concurrent workers hold disjoint numbers, so identical histories no
        longer yield identical blocks.  Column order is ``group.names``;
        row ``i`` belongs to pending trial ``i``.
        """
        return None

    def joint_wave_size(self, study: "Study", requested: int) -> int:
        """Preferred ``ask(n)`` wave size, given the caller wants up to
        ``requested`` trials.  Generation-based samplers (CMA-ES, NSGA-II)
        cap this at their population size so every wave maps onto exactly one
        generation — asking past it would draw from a stale replayed state
        that a between-wave refit will contradict.  Batched drivers
        (``Study.optimize(ask_batch=)``, the tune scheduler's backfill waves)
        consult this before each ``ask(n)``; plain callers of ``ask(n)``
        are unaffected."""
        return requested

    def reseed_rng(self, seed: int | None = None) -> None:
        """Re-seed internal RNGs.  Workers call this with a distinct per-worker
        seed so exploration streams are deterministic but non-overlapping;
        ``None`` reseeds from OS entropy."""

    def after_trial(self, study: "Study", trial: FrozenTrial, state, values) -> None:
        pass


def sample_uniform_internal(rng: np.random.RandomState, dist: BaseDistribution) -> float:
    """Uniform sample in *internal* representation, honoring log/step.

    Thin scalar wrapper over the vectorized ``BaseDistribution.sample_uniform``
    codec — the ``size=1`` draw consumes the RNG stream exactly as the
    historical scalar implementation did, so seeded studies reproduce."""
    return float(dist.sample_uniform(rng, 1)[0])
