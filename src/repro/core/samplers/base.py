"""Sampler interface.

The split mirrors the paper's two sampling families (§3.1):

* ``sample_independent`` — per-parameter sampling (random, TPE), invoked for
  every parameter not covered by the relational stage.
* ``infer_relative_search_space`` + ``sample_relative`` — relational sampling
  over the inferred concurrence relations (CMA-ES, GP), invoked once per
  trial before any suggest call resolves.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import BaseDistribution
from ..frozen import FrozenTrial

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["BaseSampler", "sample_uniform_internal"]


class BaseSampler:
    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        return {}

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        return {}

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        raise NotImplementedError

    def reseed_rng(self, seed: int | None = None) -> None:
        """Re-seed internal RNGs.  Workers call this with a distinct per-worker
        seed so exploration streams are deterministic but non-overlapping;
        ``None`` reseeds from OS entropy."""

    def after_trial(self, study: "Study", trial: FrozenTrial, state, values) -> None:
        pass


def sample_uniform_internal(rng: np.random.RandomState, dist: BaseDistribution) -> float:
    """Uniform sample in *internal* representation, honoring log/step.

    Thin scalar wrapper over the vectorized ``BaseDistribution.sample_uniform``
    codec — the ``size=1`` draw consumes the RNG stream exactly as the
    historical scalar implementation did, so seeded studies reproduce."""
    return float(dist.sample_uniform(rng, 1)[0])
