"""Sampler interface.

The split mirrors the paper's two sampling families (§3.1):

* ``sample_independent`` — per-parameter sampling (random, TPE), invoked for
  every parameter not covered by the relational stage.
* ``infer_relative_search_space`` + ``sample_relative`` — relational sampling
  over the inferred concurrence relations (CMA-ES, GP), invoked once per
  trial before any suggest call resolves.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from ..frozen import FrozenTrial

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["BaseSampler", "sample_uniform_internal"]


class BaseSampler:
    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        return {}

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        return {}

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        raise NotImplementedError

    def reseed_rng(self, seed: int | None = None) -> None:
        """Re-seed internal RNGs.  Workers call this with a distinct per-worker
        seed so exploration streams are deterministic but non-overlapping;
        ``None`` reseeds from OS entropy."""

    def after_trial(self, study: "Study", trial: FrozenTrial, state, values) -> None:
        pass


def sample_uniform_internal(rng: np.random.RandomState, dist: BaseDistribution) -> float:
    """Uniform sample in *internal* representation, honoring log/step."""
    if isinstance(dist, FloatDistribution):
        if dist.log:
            return float(np.exp(rng.uniform(np.log(dist.low), np.log(dist.high))))
        if dist.step is not None:
            n = int(np.floor((dist.high - dist.low) / dist.step + 1e-12)) + 1
            return float(dist.low + rng.randint(n) * dist.step)
        return float(rng.uniform(dist.low, dist.high))
    if isinstance(dist, IntDistribution):
        if dist.log:
            lo, hi = np.log(dist.low - 0.5), np.log(dist.high + 0.5)
            v = int(np.clip(np.round(np.exp(rng.uniform(lo, hi))), dist.low, dist.high))
            return float(v)
        n = (dist.high - dist.low) // dist.step + 1
        return float(dist.low + rng.randint(n) * dist.step)
    if isinstance(dist, CategoricalDistribution):
        return float(rng.randint(len(dist.choices)))
    raise TypeError(f"unknown distribution {dist!r}")
