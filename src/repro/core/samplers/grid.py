"""Exhaustive grid sampler.

The grid is declared up front (it cannot be define-by-run by nature), but the
objective remains define-by-run: parameters outside the grid fall back to the
independent sampler.  Grid slots are claimed through study system attrs so
distributed workers never evaluate the same cell twice.
"""

from __future__ import annotations

import itertools
from typing import TYPE_CHECKING, Any, Mapping, Sequence

import numpy as np

from ..distributions import BaseDistribution
from ..frozen import FrozenTrial, TrialState
from ..records import _GRID_ATTR as _GRID_KEY  # one key, shared with the store
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["GridSampler"]


class GridSampler(BaseSampler):
    def __init__(self, search_space: Mapping[str, Sequence[Any]], seed: int | None = None):
        self._space = {k: list(v) for k, v in sorted(search_space.items())}
        self._grid = list(itertools.product(*self._space.values()))
        self._rng = np.random.RandomState(seed)

    def __len__(self) -> int:
        return len(self._grid)

    def _taken(self, study: "Study") -> set[int]:
        """Claimed grid cells: finished trials' ids come straight off the
        observation store's ``grid_ids`` column (one vector op, incremental);
        only the handful of live RUNNING trials still need a per-trial look."""
        obs = getattr(study, "observations", None)
        if not callable(obs):  # duck-typed study: scalar fallback
            taken: set[int] = set()
            for t in study.get_trials(deepcopy=False):
                gid = t.system_attrs.get(_GRID_KEY)
                if gid is not None and (t.state.is_finished() or t.state == TrialState.RUNNING):
                    taken.add(int(gid))
            return taken
        gids = obs().grid_ids
        taken = set(np.unique(gids[gids >= 0]).tolist())
        for t in study.get_trials(deepcopy=False, states=(TrialState.RUNNING,)):
            gid = t.system_attrs.get(_GRID_KEY)
            if gid is not None:
                taken.add(int(gid))
        return taken

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """Claim ``n`` distinct free cells with **one** ``_taken`` scan and
        one batched attr write, instead of n independent scan+claim rounds.
        Only the grid's own parameters are filled; co-observed off-grid
        columns stay NaN (scalar uniform fallback, matching
        ``sample_independent``)."""
        gnames = list(self._space.keys())
        cols = {name: j for j, name in enumerate(group.names)}
        if trial_ids is None or not all(name in cols for name in gnames):
            # the grid is claimed all-or-nothing: a group covering only part
            # of it (can't happen for self-consistent objectives) or a caller
            # without trial ids falls back to the per-trial claim path
            return None
        taken = self._taken(study)
        free = [i for i in range(len(self._grid)) if i not in taken]
        gids = free[:n]
        while len(gids) < n:  # exhausted: re-visit at random (keeps totals)
            gids.append(int(self._rng.randint(len(self._grid))))
        storage = study._storage
        call_batch = getattr(storage, "call_batch", None)
        claims = [
            ("set_trial_system_attr", (tid, _GRID_KEY, gid))
            for tid, gid in zip(trial_ids, gids)
        ]
        if call_batch is not None and len(claims) > 1:
            call_batch(claims)  # one frame claims the whole wave
        else:
            for method, params in claims:
                getattr(storage, method)(*params)
        block = np.full((n, len(group.names)), np.nan)
        for k, name in enumerate(gnames):
            dist = group.dists[name]
            values = [self._grid[gid][k] for gid in gids]
            block[:, cols[name]] = dist.to_internal(values)
        return block

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        taken = self._taken(study)
        free = [i for i in range(len(self._grid)) if i not in taken]
        if not free:
            # grid exhausted: re-visit at random (keeps optimize(n_trials=...) total)
            gid = int(self._rng.randint(len(self._grid)))
        else:
            gid = free[0]
        study._storage.set_trial_system_attr(trial.trial_id, _GRID_KEY, gid)
        return dict(zip(self._space.keys(), self._grid[gid]))

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        # the relative params are injected by value; no distribution needed
        return {}

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        internal = sample_uniform_internal(self._rng, param_distribution)
        return param_distribution.to_external_repr(internal)

    def is_exhausted(self, study: "Study") -> bool:
        return len(self._taken(study)) >= len(self._grid)
