"""Tree-structured Parzen Estimator sampler (Bergstra et al., 2011).

The paper's default independent sampler (§3.1).  For each parameter:

1. split the observed (value, loss) history at the gamma-quantile into
   "below" (good) and "above" (bad) sets,
2. fit a Parzen estimator (truncated-Gaussian mixture + uniform prior
   component) to each set,
3. draw ``n_ei_candidates`` from the *below* estimator and keep the candidate
   maximizing ``log l(x) - log g(x)`` (the EI-equivalent ratio).

Numeric parameters with ``log=True`` are modeled in log space; ints are
modeled continuously and rounded; categoricals use smoothed weighted counts.

Hot path
--------
Observations come from the study's **columnar observation store**
(``core/records.py``): one ``(n_trials, n_params)`` model-space matrix
instead of a per-``ask`` re-walk of ``FrozenTrial`` lists.  On the first
suggest of each trial the sampler splits the loss vector once and slices
below/above observations for *all* parameters out of the matrix (the split,
weights, and gather are shared numpy ops — the old path redid them per
parameter in interpreted loops).  Candidate scoring evaluates both mixture
log-pdfs in one broadcasted matrix op (optionally jitted via jax with
``jit_scoring=True``).  Sampling draws are RNG-stream-identical to the
pre-refactor scalar path, so seeded studies reproduce bit-for-bit (see
``samplers/_legacy.py`` and ``tests/test_vectorized_parity.py``).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..records import ObservationStore
    from ..study import Study

__all__ = ["TPESampler", "default_gamma", "default_weights"]

EPS = 1e-12

try:  # vectorized C erf; the portable fallback loops math.erf per element
    from scipy.special import erf as _erf
except ImportError:  # pragma: no cover - scipy is an optional accelerator
    _erf = np.vectorize(math.erf)


def default_gamma(n: int) -> int:
    """Size of the 'below' (good) set (Optuna's default)."""
    return min(int(np.ceil(0.1 * n)), 25)


def default_weights(n: int) -> np.ndarray:
    """Older observations get linearly down-weighted past the 25 most recent."""
    if n == 0:
        return np.asarray([])
    if n < 25:
        return np.ones(n)
    ramp = np.linspace(1.0 / n, 1.0, n - 25)
    flat = np.ones(25)
    return np.concatenate([ramp, flat])


class _ParzenEstimator:
    """1-D truncated-Gaussian mixture over [low, high] (+ a wide prior)."""

    def __init__(
        self,
        mus: np.ndarray,
        low: float,
        high: float,
        weights: np.ndarray,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        mus = np.asarray(mus, dtype=float)
        order = np.argsort(mus)
        mus = mus[order]
        weights = np.asarray(weights, dtype=float)[order]

        if consider_prior or len(mus) == 0:
            prior_mu = 0.5 * (low + high)
            prior_sigma = high - low if high > low else 1.0
            # place the prior into sorted position
            idx = np.searchsorted(mus, prior_mu)
            mus = np.insert(mus, idx, prior_mu)
            weights = np.insert(weights, idx, prior_weight)
            prior_pos = idx
        else:
            prior_pos = None

        n = len(mus)
        sigmas = np.empty(n)
        if n == 1:
            sigmas[0] = high - low if high > low else 1.0
        else:
            padded = np.concatenate([[low], mus, [high]])
            left = mus - padded[:-2]
            right = padded[2:] - mus
            sigmas = np.maximum(left, right)
        if prior_pos is not None:
            sigmas[prior_pos] = high - low if high > low else 1.0
        maxsigma = high - low if high > low else 1.0
        minsigma = (
            maxsigma / min(100.0, 1.0 + n) if magic_clip else EPS
        )
        self.mus = mus
        self.sigmas = np.clip(sigmas, minsigma, maxsigma)
        self.weights = weights / max(weights.sum(), EPS)
        self.low = low
        self.high = high
        # truncated-normal normalization + log component constants, computed
        # once per fit: log_pdf then reduces to one broadcasted quadratic
        z = _normal_cdf((high - self.mus) / self.sigmas) - _normal_cdf(
            (low - self.mus) / self.sigmas
        )
        self._log_norm = (
            -np.log(self.sigmas)
            - 0.5 * math.log(2 * math.pi)
            - np.log(np.maximum(z, EPS))
            + np.log(self.weights + EPS)
        )

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        comp = rng.choice(len(self.mus), size=size, p=self.weights)
        mus, sigmas = self.mus, self.sigmas
        low, high = self.low, self.high
        out = np.empty(size)
        for i, c in enumerate(comp):
            # rejection-free truncated normal via clipped resampling (bounded loops)
            v = float(rng.normal(mus[c], sigmas[c]))
            for _ in range(16):
                if low <= v <= high:
                    break
                v = float(rng.normal(mus[c], sigmas[c]))
            out[i] = min(max(v, low), high)
        return out

    def log_pdf(self, xs: np.ndarray) -> np.ndarray:
        return _mixture_log_pdf(
            np.asarray(xs, dtype=float), self.mus, self.sigmas, self._log_norm
        )


def _normal_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(np.asarray(x) / math.sqrt(2.0)))


def _logsumexp(a: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(a - m), axis=axis, keepdims=True))).squeeze(axis)


def _mixture_log_pdf(
    cands: np.ndarray, mus: np.ndarray, sigmas: np.ndarray, log_norm: np.ndarray
) -> np.ndarray:
    """Mixture log-pdf over all candidates in one broadcasted matrix op.

    Works in-place on a single ``(n_cands, n_components)`` buffer.  The
    max-shifted exponent is floored at -700 before ``exp``: the shifted
    maximum is exactly 0, so the per-row sum is >= 1 and any term below
    ``exp(-700) ~ 1e-304`` is absorbed with no effect on the result — but
    flooring keeps ``exp`` out of the subnormal range, which costs ~30x on
    common hardware (far candidates in log-space domains land there
    constantly)."""
    z = cands[:, None] - mus[None, :]
    z /= sigmas[None, :]
    np.square(z, out=z)
    z *= -0.5
    z += log_norm[None, :]
    m = z.max(axis=1)
    z -= m[:, None]
    np.maximum(z, -700.0, out=z)
    np.exp(z, out=z)
    return m + np.log(z.sum(axis=1))


def _score_numpy(
    cands: np.ndarray,
    l_mus: np.ndarray, l_sigmas: np.ndarray, l_log_norm: np.ndarray,
    g_mus: np.ndarray, g_sigmas: np.ndarray, g_log_norm: np.ndarray,
) -> np.ndarray:
    """``log l(x) - log g(x)`` for all candidates, two batched mixture ops."""
    return _mixture_log_pdf(cands, l_mus, l_sigmas, l_log_norm) - _mixture_log_pdf(
        cands, g_mus, g_sigmas, g_log_norm
    )


_jax_score = None
#: number of XLA traces taken so far (the traced python body increments it);
#: tests assert it stays bounded while the observation count grows
_jax_trace_count = 0


def _get_jax_score():
    """Jitted scorer, built lazily.  Component arrays arrive padded to
    power-of-two buckets (see :func:`_pad_pow2`), so the set of shapes XLA
    ever sees — and hence the number of retraces — stays logarithmic in the
    observation count instead of linear."""
    global _jax_score
    if _jax_score is None:
        import jax
        import jax.numpy as jnp

        def score(cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm):
            global _jax_trace_count
            _jax_trace_count += 1  # body runs once per trace, not per call

            def lse(a):
                m = jnp.max(a, axis=1, keepdims=True)
                return (m + jnp.log(jnp.sum(jnp.exp(a - m), axis=1, keepdims=True)))[:, 0]

            xs = cands[:, None]
            log_l = lse(-0.5 * ((xs - l_mus[None, :]) / l_sigmas[None, :]) ** 2 + l_log_norm[None, :])
            log_g = lse(-0.5 * ((xs - g_mus[None, :]) / g_sigmas[None, :]) ** 2 + g_log_norm[None, :])
            return log_l - log_g

        _jax_score = jax.jit(score)
    return _jax_score


_MIN_PAD = 8


def _pad_pow2(mus: np.ndarray, sigmas: np.ndarray, log_norm: np.ndarray):
    """Pad one estimator's component arrays to the next power-of-two length.

    Padding components carry ``log_norm = -inf``: they contribute
    ``exp(-inf) = 0`` to the logsumexp row sums, so the score is exactly the
    unpadded one (adding 0.0 to a float sum is exact) while the shape only
    changes when the component count crosses a power of two."""
    n = len(mus)
    size = _MIN_PAD
    while size < n:
        size *= 2
    if size == n:
        return mus, sigmas, log_norm

    def pad(arr: np.ndarray, fill: float) -> np.ndarray:
        out = np.full(size, fill)
        out[:n] = arr
        return out

    return pad(mus, 0.0), pad(sigmas, 1.0), pad(log_norm, -np.inf)


class _TrialFit:
    """Per-trial batched observation split, shared by every suggest call of
    one trial: the loss vector, its argsort, and the recency weights are
    computed once; per-parameter below/above slices are cut lazily from the
    store's matrix columns."""

    __slots__ = (
        "store", "valid", "loss", "full_order", "w_by_n", "splits",
        "gamma", "weights_fn",
    )

    def __init__(self, store, valid, loss, gamma, weights_fn):
        self.store: "ObservationStore" = store
        self.valid: np.ndarray = valid
        self.loss: np.ndarray = loss
        self.full_order: np.ndarray | None = None
        self.w_by_n: dict[int, np.ndarray] = {}
        self.splits: dict[str, "tuple | None"] = {}
        self.gamma = gamma
        self.weights_fn = weights_fn

    def split(self, param_name: str) -> "tuple | None":
        """(n, below, above, w_below, w_above) in model space, or None when
        the parameter has never been observed."""
        if param_name in self.splits:
            return self.splits[param_name]
        col = self.store.column(param_name)
        if col is None:
            self.splits[param_name] = None
            return None
        present = self.valid & ~np.isnan(col)
        idx = np.flatnonzero(present)
        n = len(idx)
        if n == 0:
            self.splits[param_name] = None
            return None
        vals = col[idx]
        losses = self.loss[idx]
        if np.array_equal(present, self.valid):
            # unconditional parameter: every such column shares one argsort
            if self.full_order is None:
                self.full_order = np.argsort(losses, kind="stable")
            order = self.full_order
        else:
            order = np.argsort(losses, kind="stable")
        n_below = self.gamma(n)
        w_all = self.w_by_n.get(n)
        if w_all is None:
            w_all = np.asarray(self.weights_fn(n), dtype=float)
            self.w_by_n[n] = w_all
        below_idx, above_idx = order[:n_below], order[n_below:]
        out = (n, vals[below_idx], vals[above_idx], w_all[below_idx], w_all[above_idx])
        self.splits[param_name] = out
        return out


class TPESampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_ei_candidates: int = 24,
        gamma: Callable[[int], int] = default_gamma,
        weights: Callable[[int], np.ndarray] = default_weights,
        seed: int | None = None,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        consider_magic_clip: bool = True,
        consider_pruned_trials: bool = False,
        jit_scoring: bool = False,
    ):
        self._n_startup = n_startup_trials
        self._n_ei = n_ei_candidates
        self._gamma = gamma
        self._weights = weights
        self._rng = np.random.RandomState(seed)
        self._consider_prior = consider_prior
        self._prior_weight = prior_weight
        self._magic_clip = consider_magic_clip
        self._consider_pruned = consider_pruned_trials
        self._jit_scoring = jit_scoring
        self._fit: tuple[Any, _TrialFit] | None = None  # (cache key, fit)
        # fitted estimators are deterministic functions of (observations,
        # bounds); memoize them per store version so back-to-back asks with
        # an unchanged history (batched ask, fixed-history scoring) skip the
        # refit entirely
        self._est_cache: tuple[Any, dict] | None = None

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    # -- observation collection ------------------------------------------------

    def _trial_fit(self, study: "Study", trial: FrozenTrial) -> _TrialFit:
        """The batched split for this trial, built on first use and reused by
        every subsequent suggest of the same trial."""
        store = study.observations()
        key = (id(study), trial.number, store.version)
        cached = self._fit
        if cached is not None and cached[0] == key:
            return cached[1]
        states = store.states
        values = store.values
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        complete = states == int(TrialState.COMPLETE)
        with np.errstate(invalid="ignore"):
            valid = complete & np.isfinite(values)
            loss = sign * values
            if self._consider_pruned:
                last_iv = store.last_intermediate_values
                pruned = (states == int(TrialState.PRUNED)) & np.isfinite(last_iv)
                valid = valid | pruned
                loss = np.where(complete, loss, sign * last_iv)
        fit = _TrialFit(store, valid, loss, self._gamma, self._weights)
        self._fit = (key, fit)
        return fit

    # -- sampling -----------------------------------------------------------------

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        if len(study.directions) > 1:
            # TPE is single-objective; multi-objective studies fall back to
            # uniform sampling (use a Pareto-aware sampler for real MO work)
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        fit = self._trial_fit(study, trial)
        split = fit.split(param_name)
        if split is None or split[0] < self._n_startup:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        _, below, above, w_below, w_above = split

        version = (id(study), fit.store.version)
        if self._est_cache is None or self._est_cache[0] != version:
            self._est_cache = (version, {})
        cache = self._est_cache[1]

        if isinstance(param_distribution, CategoricalDistribution):
            internal = self._sample_categorical(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        else:
            internal = self._sample_numeric(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        return param_distribution.to_external_repr(internal)

    def _score(self, l_est: _ParzenEstimator, g_est: _ParzenEstimator, cands: np.ndarray) -> np.ndarray:
        if self._jit_scoring:
            try:
                return np.asarray(
                    _get_jax_score()(
                        cands,
                        *_pad_pow2(l_est.mus, l_est.sigmas, l_est._log_norm),
                        *_pad_pow2(g_est.mus, g_est.sigmas, g_est._log_norm),
                    )
                )
            except ImportError:
                self._jit_scoring = False
        return _score_numpy(
            cands,
            l_est.mus, l_est.sigmas, l_est._log_norm,
            g_est.mus, g_est.sigmas, g_est._log_norm,
        )

    def _sample_numeric(
        self,
        dist: BaseDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        low, high = dist.internal_bounds(expand_int=True)
        key = (param_name, low, high)
        ests = cache.get(key)
        if ests is None:
            l_est = _ParzenEstimator(
                below, low, high, w_below,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            g_est = _ParzenEstimator(
                above, low, high, w_above,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            cache[key] = ests = (l_est, g_est)
        l_est, g_est = ests
        cands = l_est.sample(self._rng, self._n_ei)
        score = self._score(l_est, g_est, cands)
        best = cands[int(np.argmax(score))]
        return float(dist.from_internal(np.asarray([best]))[0])

    def _sample_categorical(
        self,
        dist: CategoricalDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        k = len(dist.choices)
        key = (param_name, "categorical", k)
        probs = cache.get(key)
        if probs is None:

            def weighted_probs(idxs: np.ndarray, ws: np.ndarray) -> np.ndarray:
                counts = np.full(k, self._prior_weight)
                # np.add.at accumulates in element order, matching a scalar loop
                np.add.at(counts, idxs.astype(int), ws)
                return counts / counts.sum()

            cache[key] = probs = (
                weighted_probs(below, w_below),
                weighted_probs(above, w_above),
            )
        p_l, p_g = probs
        cands = self._rng.choice(k, size=self._n_ei, p=p_l)
        score = np.log(p_l[cands] + EPS) - np.log(p_g[cands] + EPS)
        return float(cands[int(np.argmax(score))])
