"""Tree-structured Parzen Estimator sampler (Bergstra et al., 2011).

The paper's default independent sampler (§3.1).  For each parameter:

1. split the observed (value, loss) history at the gamma-quantile into
   "below" (good) and "above" (bad) sets,
2. fit a Parzen estimator (truncated-Gaussian mixture + uniform prior
   component) to each set,
3. draw ``n_ei_candidates`` from the *below* estimator and keep the candidate
   maximizing ``log l(x) - log g(x)`` (the EI-equivalent ratio).

Numeric parameters with ``log=True`` are modeled in log space; ints are
modeled continuously and rounded; categoricals use smoothed weighted counts.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from ..distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
)
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..study import Study

__all__ = ["TPESampler", "default_gamma", "default_weights"]

EPS = 1e-12


def default_gamma(n: int) -> int:
    """Size of the 'below' (good) set (Optuna's default)."""
    return min(int(np.ceil(0.1 * n)), 25)


def default_weights(n: int) -> np.ndarray:
    """Older observations get linearly down-weighted past the 25 most recent."""
    if n == 0:
        return np.asarray([])
    if n < 25:
        return np.ones(n)
    ramp = np.linspace(1.0 / n, 1.0, n - 25)
    flat = np.ones(25)
    return np.concatenate([ramp, flat])


class _ParzenEstimator:
    """1-D truncated-Gaussian mixture over [low, high] (+ a wide prior)."""

    def __init__(
        self,
        mus: np.ndarray,
        low: float,
        high: float,
        weights: np.ndarray,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        mus = np.asarray(mus, dtype=float)
        order = np.argsort(mus)
        mus = mus[order]
        weights = np.asarray(weights, dtype=float)[order]

        if consider_prior or len(mus) == 0:
            prior_mu = 0.5 * (low + high)
            prior_sigma = high - low if high > low else 1.0
            # place the prior into sorted position
            idx = np.searchsorted(mus, prior_mu)
            mus = np.insert(mus, idx, prior_mu)
            weights = np.insert(weights, idx, prior_weight)
            prior_pos = idx
        else:
            prior_pos = None

        n = len(mus)
        sigmas = np.empty(n)
        if n == 1:
            sigmas[0] = high - low if high > low else 1.0
        else:
            padded = np.concatenate([[low], mus, [high]])
            left = mus - padded[:-2]
            right = padded[2:] - mus
            sigmas = np.maximum(left, right)
        if prior_pos is not None:
            sigmas[prior_pos] = high - low if high > low else 1.0
        maxsigma = high - low if high > low else 1.0
        minsigma = (
            maxsigma / min(100.0, 1.0 + n) if magic_clip else EPS
        )
        self.mus = mus
        self.sigmas = np.clip(sigmas, minsigma, maxsigma)
        self.weights = weights / max(weights.sum(), EPS)
        self.low = low
        self.high = high

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        comp = rng.choice(len(self.mus), size=size, p=self.weights)
        out = np.empty(size)
        for i, c in enumerate(comp):
            # rejection-free truncated normal via clipped resampling (bounded loops)
            v = rng.normal(self.mus[c], self.sigmas[c])
            for _ in range(16):
                if self.low <= v <= self.high:
                    break
                v = rng.normal(self.mus[c], self.sigmas[c])
            out[i] = float(np.clip(v, self.low, self.high))
        return out

    def log_pdf(self, xs: np.ndarray) -> np.ndarray:
        xs = np.asarray(xs, dtype=float)[:, None]
        mus = self.mus[None, :]
        sigmas = self.sigmas[None, :]
        # truncated-normal normalization over [low, high]
        z = _normal_cdf((self.high - mus) / sigmas) - _normal_cdf((self.low - mus) / sigmas)
        z = np.maximum(z, EPS)
        log_comp = (
            -0.5 * ((xs - mus) / sigmas) ** 2
            - np.log(sigmas)
            - 0.5 * math.log(2 * math.pi)
            - np.log(z)
        )
        log_w = np.log(self.weights[None, :] + EPS)
        return _logsumexp(log_comp + log_w, axis=1)


def _normal_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(np.asarray(x) / math.sqrt(2.0)))


def _logsumexp(a: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(a - m), axis=axis, keepdims=True))).squeeze(axis)


class TPESampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_ei_candidates: int = 24,
        gamma: Callable[[int], int] = default_gamma,
        weights: Callable[[int], np.ndarray] = default_weights,
        seed: int | None = None,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        consider_magic_clip: bool = True,
        consider_pruned_trials: bool = False,
    ):
        self._n_startup = n_startup_trials
        self._n_ei = n_ei_candidates
        self._gamma = gamma
        self._weights = weights
        self._rng = np.random.RandomState(seed)
        self._consider_prior = consider_prior
        self._prior_weight = prior_weight
        self._magic_clip = consider_magic_clip
        self._consider_pruned = consider_pruned_trials

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    # -- observation collection ------------------------------------------------

    def _observations(
        self, study: "Study", param_name: str
    ) -> tuple[np.ndarray, np.ndarray, list[BaseDistribution]]:
        """(internal values, losses) for trials that suggested param_name."""
        values, losses, dists = [], [], []
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        states = (
            (TrialState.COMPLETE, TrialState.PRUNED)
            if self._consider_pruned
            else (TrialState.COMPLETE,)
        )
        for t in study.get_trials(deepcopy=False, states=states):
            if param_name not in t.params:
                continue
            if t.state == TrialState.COMPLETE:
                if t.values is None:
                    continue
                loss = sign * t.values[0]
            else:  # PRUNED: use last intermediate value (pessimistic)
                if not t.intermediate_values:
                    continue
                loss = sign * t.intermediate_values[t.last_step]
            if not np.isfinite(loss):
                continue
            dist = t.distributions[param_name]
            values.append(dist.to_internal_repr(t.params[param_name]))
            losses.append(loss)
            dists.append(dist)
        return np.asarray(values), np.asarray(losses), dists

    # -- sampling -----------------------------------------------------------------

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        if len(study.directions) > 1:
            # TPE is single-objective; multi-objective studies fall back to
            # uniform sampling (use a Pareto-aware sampler for real MO work)
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        values, losses, _ = self._observations(study, param_name)
        if len(values) < self._n_startup:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)

        n = len(values)
        n_below = self._gamma(n)
        order = np.argsort(losses, kind="stable")
        below_idx, above_idx = order[:n_below], order[n_below:]
        below, above = values[below_idx], values[above_idx]
        w_all = self._weights(n)

        # the weights function is defined over recency order; map via index
        w_below = np.asarray([w_all[i] for i in below_idx])
        w_above = np.asarray([w_all[i] for i in above_idx])

        if isinstance(param_distribution, CategoricalDistribution):
            internal = self._sample_categorical(param_distribution, below, above, w_below, w_above)
        else:
            internal = self._sample_numeric(param_distribution, below, above, w_below, w_above)
        return param_distribution.to_external_repr(internal)

    def _transform(self, dist: BaseDistribution, xs: np.ndarray) -> np.ndarray:
        if getattr(dist, "log", False):
            return np.log(np.maximum(xs, EPS))
        return xs

    def _untransform(self, dist: BaseDistribution, xs: np.ndarray) -> np.ndarray:
        if getattr(dist, "log", False):
            return np.exp(xs)
        return xs

    def _bounds(self, dist: BaseDistribution) -> tuple[float, float]:
        low, high = float(dist.low), float(dist.high)
        if isinstance(dist, IntDistribution):
            low, high = low - 0.5, high + 0.5
            if dist.log:
                low = max(low, 0.5)
        if getattr(dist, "log", False):
            return math.log(low), math.log(high)
        return low, high

    def _sample_numeric(
        self,
        dist: BaseDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
    ) -> float:
        low, high = self._bounds(dist)
        l_est = _ParzenEstimator(
            self._transform(dist, below), low, high, w_below,
            self._consider_prior, self._prior_weight, self._magic_clip,
        )
        g_est = _ParzenEstimator(
            self._transform(dist, above), low, high, w_above,
            self._consider_prior, self._prior_weight, self._magic_clip,
        )
        cands = l_est.sample(self._rng, self._n_ei)
        score = l_est.log_pdf(cands) - g_est.log_pdf(cands)
        best = cands[int(np.argmax(score))]
        x = float(self._untransform(dist, np.asarray([best]))[0])
        if isinstance(dist, IntDistribution):
            x = float(np.clip(round_to_step(x, dist.low, dist.high, dist.step), dist.low, dist.high))
        elif isinstance(dist, FloatDistribution):
            if dist.step is not None:
                x = float(np.clip(round_to_step(x, dist.low, dist.high, dist.step), dist.low, dist.high))
            else:
                x = float(np.clip(x, dist.low, dist.high))
        return x

    def _sample_categorical(
        self,
        dist: CategoricalDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
    ) -> float:
        k = len(dist.choices)

        def weighted_probs(idxs: np.ndarray, ws: np.ndarray) -> np.ndarray:
            counts = np.full(k, self._prior_weight)
            for i, w in zip(idxs.astype(int), ws):
                counts[i] += w
            return counts / counts.sum()

        p_l = weighted_probs(below, w_below)
        p_g = weighted_probs(above, w_above)
        cands = self._rng.choice(k, size=self._n_ei, p=p_l)
        score = np.log(p_l[cands] + EPS) - np.log(p_g[cands] + EPS)
        return float(cands[int(np.argmax(score))])


def round_to_step(x: float, low: float, high: float, step: float | int) -> float:
    return low + round((x - low) / step) * step
