"""Tree-structured Parzen Estimator sampler (Bergstra et al., 2011).

The paper's default independent sampler (§3.1).  For each parameter:

1. split the observed (value, loss) history at the gamma-quantile into
   "below" (good) and "above" (bad) sets,
2. fit a Parzen estimator (truncated-Gaussian mixture + uniform prior
   component) to each set,
3. draw ``n_ei_candidates`` from the *below* estimator and keep the candidate
   maximizing ``log l(x) - log g(x)`` (the EI-equivalent ratio).

Numeric parameters with ``log=True`` are modeled in log space; ints are
modeled continuously and rounded; categoricals use smoothed weighted counts.

Hot path
--------
Observations come from the study's **columnar observation store**
(``core/records.py``): one ``(n_trials, n_params)`` model-space matrix
instead of a per-``ask`` re-walk of ``FrozenTrial`` lists.  On the first
suggest of each trial the sampler splits the loss vector once and slices
below/above observations for *all* parameters out of the matrix (the split,
weights, and gather are shared numpy ops — the old path redid them per
parameter in interpreted loops).  Candidate scoring evaluates both mixture
log-pdfs in one broadcasted matrix op (optionally jitted via jax with
``jit_scoring=True``).  Sampling draws are RNG-stream-identical to the
pre-refactor scalar path, so seeded studies reproduce bit-for-bit (see
``samplers/_legacy.py`` and ``tests/test_vectorized_parity.py``).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from .. import telemetry
from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial, StudyDirection, TrialState
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..records import ObservationStore
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["TPESampler", "default_gamma", "default_weights"]

EPS = 1e-12

try:  # vectorized C erf; the portable fallback loops math.erf per element
    from scipy.special import erf as _erf
except ImportError:  # pragma: no cover - scipy is an optional accelerator
    _erf = np.vectorize(math.erf)


def default_gamma(n: int) -> int:
    """Size of the 'below' (good) set (Optuna's default)."""
    return min(int(np.ceil(0.1 * n)), 25)


def default_weights(n: int) -> np.ndarray:
    """Older observations get linearly down-weighted past the 25 most recent."""
    if n == 0:
        return np.asarray([])
    if n < 25:
        return np.ones(n)
    ramp = np.linspace(1.0 / n, 1.0, n - 25)
    flat = np.ones(25)
    return np.concatenate([ramp, flat])


class _ParzenEstimator:
    """1-D truncated-Gaussian mixture over [low, high] (+ a wide prior)."""

    def __init__(
        self,
        mus: np.ndarray,
        low: float,
        high: float,
        weights: np.ndarray,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        mus = np.asarray(mus, dtype=float)
        order = np.argsort(mus)
        mus = mus[order]
        weights = np.asarray(weights, dtype=float)[order]

        if consider_prior or len(mus) == 0:
            prior_mu = 0.5 * (low + high)
            prior_sigma = high - low if high > low else 1.0
            # place the prior into sorted position
            idx = np.searchsorted(mus, prior_mu)
            mus = np.insert(mus, idx, prior_mu)
            weights = np.insert(weights, idx, prior_weight)
            prior_pos = idx
        else:
            prior_pos = None

        n = len(mus)
        sigmas = np.empty(n)
        if n == 1:
            sigmas[0] = high - low if high > low else 1.0
        else:
            padded = np.concatenate([[low], mus, [high]])
            left = mus - padded[:-2]
            right = padded[2:] - mus
            sigmas = np.maximum(left, right)
        if prior_pos is not None:
            sigmas[prior_pos] = high - low if high > low else 1.0
        maxsigma = high - low if high > low else 1.0
        minsigma = (
            maxsigma / min(100.0, 1.0 + n) if magic_clip else EPS
        )
        self.mus = mus
        self.sigmas = np.clip(sigmas, minsigma, maxsigma)
        self.weights = weights / max(weights.sum(), EPS)
        self.low = low
        self.high = high
        # truncated-normal normalization + log component constants, computed
        # once per fit: log_pdf then reduces to one broadcasted quadratic
        z = _normal_cdf((high - self.mus) / self.sigmas) - _normal_cdf(
            (low - self.mus) / self.sigmas
        )
        self._log_norm = (
            -np.log(self.sigmas)
            - 0.5 * math.log(2 * math.pi)
            - np.log(np.maximum(z, EPS))
            + np.log(self.weights + EPS)
        )

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        comp = rng.choice(len(self.mus), size=size, p=self.weights)
        mus, sigmas = self.mus, self.sigmas
        low, high = self.low, self.high
        out = np.empty(size)
        for i, c in enumerate(comp):
            # rejection-free truncated normal via clipped resampling (bounded loops)
            v = float(rng.normal(mus[c], sigmas[c]))
            for _ in range(16):
                if low <= v <= high:
                    break
                v = float(rng.normal(mus[c], sigmas[c]))
            out[i] = min(max(v, low), high)
        return out

    def log_pdf(self, xs: np.ndarray) -> np.ndarray:
        return _mixture_log_pdf(
            np.asarray(xs, dtype=float), self.mus, self.sigmas, self._log_norm
        )


def _normal_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(np.asarray(x) / math.sqrt(2.0)))


def _logsumexp(a: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(a - m), axis=axis, keepdims=True))).squeeze(axis)


def _mixture_log_pdf(
    cands: np.ndarray, mus: np.ndarray, sigmas: np.ndarray, log_norm: np.ndarray
) -> np.ndarray:
    """Mixture log-pdf over all candidates in one broadcasted matrix op.

    Works in-place on a single ``(n_cands, n_components)`` buffer.  The
    max-shifted exponent is floored at -700 before ``exp``: the shifted
    maximum is exactly 0, so the per-row sum is >= 1 and any term below
    ``exp(-700) ~ 1e-304`` is absorbed with no effect on the result — but
    flooring keeps ``exp`` out of the subnormal range, which costs ~30x on
    common hardware (far candidates in log-space domains land there
    constantly)."""
    z = cands[:, None] - mus[None, :]
    z /= sigmas[None, :]
    np.square(z, out=z)
    z *= -0.5
    z += log_norm[None, :]
    m = z.max(axis=1)
    z -= m[:, None]
    np.maximum(z, -700.0, out=z)
    np.exp(z, out=z)
    return m + np.log(z.sum(axis=1))


def _score_numpy(
    cands: np.ndarray,
    l_mus: np.ndarray, l_sigmas: np.ndarray, l_log_norm: np.ndarray,
    g_mus: np.ndarray, g_sigmas: np.ndarray, g_log_norm: np.ndarray,
) -> np.ndarray:
    """``log l(x) - log g(x)`` for all candidates, two batched mixture ops."""
    return _mixture_log_pdf(cands, l_mus, l_sigmas, l_log_norm) - _mixture_log_pdf(
        cands, g_mus, g_sigmas, g_log_norm
    )


_jax_score = None
#: number of XLA traces taken so far (the traced python body increments it);
#: tests assert it stays bounded while the observation count grows
_jax_trace_count = 0


def _get_jax_score():
    """Jitted scorer, built lazily.  Component arrays arrive padded to
    power-of-two buckets (see :func:`_pad_pow2`), so the set of shapes XLA
    ever sees — and hence the number of retraces — stays logarithmic in the
    observation count instead of linear."""
    global _jax_score
    if _jax_score is None:
        import jax
        import jax.numpy as jnp

        def score(cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm):
            global _jax_trace_count
            _jax_trace_count += 1  # body runs once per trace, not per call

            def lse(a):
                m = jnp.max(a, axis=1, keepdims=True)
                return (m + jnp.log(jnp.sum(jnp.exp(a - m), axis=1, keepdims=True)))[:, 0]

            xs = cands[:, None]
            log_l = lse(-0.5 * ((xs - l_mus[None, :]) / l_sigmas[None, :]) ** 2 + l_log_norm[None, :])
            log_g = lse(-0.5 * ((xs - g_mus[None, :]) / g_sigmas[None, :]) ** 2 + g_log_norm[None, :])
            return log_l - log_g

        _jax_score = jax.jit(score)
    return _jax_score


_MIN_PAD = 8


def _pad_pow2(mus: np.ndarray, sigmas: np.ndarray, log_norm: np.ndarray):
    """Pad one estimator's component arrays to the next power-of-two length.

    Padding components carry ``log_norm = -inf``: they contribute
    ``exp(-inf) = 0`` to the logsumexp row sums, so the score is exactly the
    unpadded one (adding 0.0 to a float sum is exact) while the shape only
    changes when the component count crosses a power of two."""
    n = len(mus)
    size = _MIN_PAD
    while size < n:
        size *= 2
    if size == n:
        return mus, sigmas, log_norm

    def pad(arr: np.ndarray, fill: float) -> np.ndarray:
        out = np.full(size, fill)
        out[:n] = arr
        return out

    return pad(mus, 0.0), pad(sigmas, 1.0), pad(log_norm, -np.inf)


def _get_jax_joint_score():
    """Jitted multivariate scorer (numeric groups).  Component axes arrive
    padded to power-of-two buckets with ``log_w = -inf`` (see
    :func:`_pad_pow2`), so the trace count stays logarithmic in the
    observation count — same policy as the univariate scorer."""
    global _jax_joint_score
    if _jax_joint_score is None:
        import jax
        import jax.numpy as jnp

        def score(cands, l_mus, l_sigmas, l_log_norm, l_log_w,
                  g_mus, g_sigmas, g_log_norm, g_log_w):
            global _jax_trace_count
            _jax_trace_count += 1  # body runs once per trace, not per call

            def side(mus, sigmas, log_norm, log_w):
                z = (cands[:, None, :] - mus[None, :, :]) / sigmas[None, :, :]
                e = jnp.sum(-0.5 * z * z + log_norm[None, :, :], axis=2)
                e = e + log_w[None, :]
                m = jnp.max(e, axis=1, keepdims=True)
                return (m + jnp.log(jnp.sum(jnp.exp(e - m), axis=1, keepdims=True)))[:, 0]

            return side(l_mus, l_sigmas, l_log_norm, l_log_w) - side(
                g_mus, g_sigmas, g_log_norm, g_log_w
            )

        _jax_joint_score = jax.jit(score)
    return _jax_joint_score


_jax_joint_score = None

#: joint-cache sentinel distinguishing "never fitted" from "fitted: declined"
_UNFIT = object()


def _pad_pow2_rows(arr2d: np.ndarray, fill: float) -> np.ndarray:
    """Pad a ``(n_comp, d)`` array to a power-of-two component count."""
    n = len(arr2d)
    size = _MIN_PAD
    while size < n:
        size *= 2
    if size == n:
        return arr2d
    out = np.full((size, arr2d.shape[1]), fill)
    out[:n] = arr2d
    return out


def _pad_pow2_vec(vec: np.ndarray, fill: float) -> np.ndarray:
    n = len(vec)
    size = _MIN_PAD
    while size < n:
        size *= 2
    if size == n:
        return vec
    out = np.full(size, fill)
    out[:n] = vec
    return out


class _GroupParzen:
    """d-dimensional Parzen estimator over one co-observed parameter group.

    One mixture component per observed trial **row** (plus an optional wide
    prior), each component a *product* kernel: per-dim truncated Gaussians
    for numeric parameters (Scott-rule bandwidth, magic-clipped) and
    smoothed point-mass kernels for categoricals.  Modeling whole rows is
    what makes the estimator genuinely multivariate — the good-set density
    ``l(x)`` preserves correlations between parameters (a narrow valley
    ``x ≈ y`` stays narrow), which per-parameter univariate TPE marginals
    cannot represent.
    """

    __slots__ = (
        "mus", "sigmas", "log_norm", "log_w", "weights", "lows", "highs",
        "cat_dims", "num_dims", "cat_index", "n_choices", "prior_weight",
        "_inv_var", "_lin", "_const",
    )

    def __init__(
        self,
        rows: np.ndarray,               # (n_obs, d) model-space observations
        dists: "list[BaseDistribution]",
        weights: np.ndarray,            # (n_obs,) recency weights
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        rows = np.asarray(rows, dtype=float)
        n_obs, d = rows.shape
        self.cat_dims = [j for j, ds in enumerate(dists) if isinstance(ds, CategoricalDistribution)]
        self.num_dims = [j for j in range(d) if j not in self.cat_dims]
        self.n_choices = {
            j: len(dists[j].choices) for j in self.cat_dims  # type: ignore[attr-defined]
        }
        self.prior_weight = float(prior_weight)

        lows = np.empty(d)
        highs = np.empty(d)
        for j, ds in enumerate(dists):
            lows[j], highs[j] = ds.internal_bounds(expand_int=True)
        self.lows, self.highs = lows, highs

        n_comp = n_obs + (1 if (consider_prior or n_obs == 0) else 0)
        mus = np.zeros((n_comp, d))
        mus[:n_obs] = rows
        w = np.empty(n_comp)
        w[:n_obs] = np.asarray(weights, dtype=float)
        # categorical index per (component, cat-dim); -1 marks the uniform
        # prior component
        cat_index = np.full((n_comp, len(self.cat_dims)), -1, dtype=np.int64)
        for c, j in enumerate(self.cat_dims):
            cat_index[:n_obs, c] = np.round(rows[:, j]).astype(np.int64)
        self.cat_index = cat_index

        ranges = np.where(highs > lows, highs - lows, 1.0)
        sigmas = np.ones((n_comp, d))
        if n_obs > 0:
            # Scott-rule bandwidth per dim, shared by all data components;
            # the prior keeps the full-range sigma
            scott = np.std(rows, axis=0) * float(n_obs) ** (-1.0 / (d + 4))
            maxsigma = ranges
            minsigma = (
                maxsigma / min(100.0, 1.0 + n_comp) if magic_clip
                else np.full(d, EPS)
            )
            sigmas[:n_obs] = np.clip(scott, minsigma, maxsigma)[None, :]
        if n_comp > n_obs:  # prior component: wide gaussian / uniform pmf
            mus[n_obs] = 0.5 * (lows + highs)
            sigmas[n_obs] = ranges
            w[n_obs] = prior_weight

        self.mus = mus
        self.sigmas = sigmas
        self.weights = w / max(w.sum(), EPS)
        self.log_w = np.log(self.weights + EPS)

        # truncated-normal normalization per (component, numeric dim)
        log_norm = np.zeros((n_comp, d))
        nd = self.num_dims
        if nd:
            z = _normal_cdf((highs[nd][None, :] - mus[:, nd]) / sigmas[:, nd]) - _normal_cdf(
                (lows[nd][None, :] - mus[:, nd]) / sigmas[:, nd]
            )
            log_norm[:, nd] = (
                -np.log(sigmas[:, nd])
                - 0.5 * math.log(2 * math.pi)
                - np.log(np.maximum(z, EPS))
            )
        self.log_norm = log_norm

        # gemm-form coefficients of the Gaussian quadratic (see log_pdf):
        # sum_j -0.5((x_j - mu_ij)/s_ij)^2 expands so candidate scoring is
        # two (n_cands, d) @ (d, n_comp) matmuls instead of a per-dim
        # broadcast loop over (n_cands, n_comp) temporaries
        inv_var = 1.0 / np.square(sigmas[:, nd]) if nd else np.zeros((n_comp, 0))
        self._inv_var = inv_var
        self._lin = mus[:, nd] * inv_var
        self._const = (
            -0.5 * (np.square(mus[:, nd]) * inv_var).sum(axis=1)
            + log_norm[:, nd].sum(axis=1)
            + self.log_w
        )

    # -- sampling ---------------------------------------------------------------

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        """Draw ``size`` model-space rows — fully vectorized (component
        choice, clipped-resample truncated normals, smoothed categorical
        kernels), unlike the univariate estimator's per-candidate loop."""
        comp = rng.choice(len(self.weights), size=size, p=self.weights)
        out = np.empty((size, self.mus.shape[1]))
        nd = self.num_dims
        if nd:
            mu = self.mus[comp][:, nd]
            sigma = self.sigmas[comp][:, nd]
            lo, hi = self.lows[nd][None, :], self.highs[nd][None, :]
            x = rng.normal(mu, sigma)
            for _ in range(16):  # bounded vectorized truncation retries
                bad = (x < lo) | (x > hi)
                if not bad.any():
                    break
                x[bad] = rng.normal(mu[bad], sigma[bad])
            out[:, nd] = np.clip(x, lo, hi)
        pw = self.prior_weight
        for c, j in enumerate(self.cat_dims):
            k = self.n_choices[j]
            m = self.cat_index[comp, c]
            # component pmf (1[c=m] + pw/k)/(1 + pw): keep the observed
            # choice w.p. 1/(1+pw), else uniform; prior component (m = -1)
            # is uniform outright
            keep = (rng.uniform(size=size) < 1.0 / (1.0 + pw)) & (m >= 0)
            out[:, j] = np.where(keep, m, rng.randint(k, size=size)).astype(float)
        return out

    # -- scoring ----------------------------------------------------------------

    def log_pdf(self, X: np.ndarray) -> np.ndarray:
        """Mixture log-density of ``(n_cands, d)`` rows: per-component
        product over dims, logsumexp over components.  The Gaussian block is
        evaluated in expanded quadratic form — two BLAS matmuls against the
        precomputed ``1/sigma^2`` coefficient matrices — so cost scales as a
        gemm instead of a python loop over dims (the expansion's cancellation
        error is ~1e-10 in log space, far below sampling noise)."""
        X = np.asarray(X, dtype=float)
        nd = self.num_dims
        if nd:
            Xn = X[:, nd]
            E = np.square(Xn) @ self._inv_var.T
            E -= 2.0 * (Xn @ self._lin.T)
            E *= -0.5
            E += self._const[None, :]
        else:
            E = np.broadcast_to(self._const[None, :], (len(X), len(self._const))).copy()
        pw = self.prior_weight
        for c, j in enumerate(self.cat_dims):
            k = self.n_choices[j]
            m = self.cat_index[None, :, c]
            hit = np.round(X[:, j, None]).astype(np.int64) == m
            p = np.where(
                m < 0, 1.0 / k,  # uniform prior component
                (hit.astype(float) + pw / k) / (1.0 + pw),
            )
            E += np.log(p + EPS)
        m_ = E.max(axis=1)
        E -= m_[:, None]
        np.maximum(E, -700.0, out=E)
        np.exp(E, out=E)
        return m_ + np.log(E.sum(axis=1))


class _TrialFit:
    """Per-trial batched observation split, shared by every suggest call of
    one trial: the loss vector, its argsort, and the recency weights are
    computed once; per-parameter below/above slices are cut lazily from the
    snapshotted matrix columns.

    Built from one ``ObservationStore.snapshot()`` — never from live store
    properties — so concurrent ``tell``s from other threads (batched
    ``optimize(n_jobs=..)``) cannot grow a column under a mask captured at
    fit time."""

    __slots__ = (
        "version", "cols", "valid", "loss", "full_order", "w_by_n", "splits",
        "gamma", "weights_fn",
    )

    def __init__(self, version, cols, valid, loss, gamma, weights_fn):
        self.version = version
        self.cols: dict[str, np.ndarray] = cols
        self.valid: np.ndarray = valid
        self.loss: np.ndarray = loss
        self.full_order: np.ndarray | None = None
        self.w_by_n: dict[int, np.ndarray] = {}
        self.splits: dict[str, "tuple | None"] = {}
        self.gamma = gamma
        self.weights_fn = weights_fn

    def split(self, param_name: str) -> "tuple | None":
        """(n, below, above, w_below, w_above) in model space, or None when
        the parameter has never been observed."""
        if param_name in self.splits:
            return self.splits[param_name]
        col = self.cols.get(param_name)
        if col is None:
            self.splits[param_name] = None
            return None
        present = self.valid & ~np.isnan(col)
        idx = np.flatnonzero(present)
        n = len(idx)
        if n == 0:
            self.splits[param_name] = None
            return None
        vals = col[idx]
        losses = self.loss[idx]
        if np.array_equal(present, self.valid):
            # unconditional parameter: every such column shares one argsort
            if self.full_order is None:
                self.full_order = np.argsort(losses, kind="stable")
            order = self.full_order
        else:
            order = np.argsort(losses, kind="stable")
        n_below = self.gamma(n)
        w_all = self.w_by_n.get(n)
        if w_all is None:
            w_all = np.asarray(self.weights_fn(n), dtype=float)
            self.w_by_n[n] = w_all
        below_idx, above_idx = order[:n_below], order[n_below:]
        out = (n, vals[below_idx], vals[above_idx], w_all[below_idx], w_all[above_idx])
        self.splits[param_name] = out
        return out


def _motpe_split(L: np.ndarray, n_below: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """MOTPE below/above split of a loss matrix ``L`` (rows = observations,
    already minimize-oriented and finite): fill the below set by
    nondomination rank; break ties on the boundary rank by greedy
    hypervolume subset selection; weight the below rows by their normalized
    hypervolume contributions.  Returns ``(below_pos, above_pos, w_below)``
    with both index arrays sorted (chronological order, so the above set's
    recency weights stay meaningful)."""
    from .. import moo

    n = len(L)
    n_below = int(min(max(n_below, 0), n))
    ranks = moo.nondomination_ranks(L)
    below = np.zeros(0, dtype=np.int64)
    for r in np.unique(ranks):
        members = np.flatnonzero(ranks == r)
        if len(below) + len(members) <= n_below:
            below = np.concatenate([below, members])
            continue
        want = n_below - len(below)
        if want > 0:
            ref = moo.default_reference_point(L[members])
            sel = moo.solve_hssp(L[members], want, ref)
            below = np.concatenate([below, members[sel]])
        break
    below = np.sort(below)
    above = np.setdiff1d(np.arange(n), below)
    if len(below) <= 1:
        w_below = np.ones(len(below))
    else:
        ref = moo.default_reference_point(L[below])
        contrib = moo.hypervolume_contributions(L[below], ref) + EPS
        w_below = np.clip(contrib / contrib.max(), 0.0, 1.0)
    return below, above, w_below


class _MOFit:
    """Multi-objective sibling of :class:`_TrialFit`: one rank+HSSP split of
    the values matrix per store version, shared by every suggest call (and
    every pending trial of a wave) on that history.  Per-parameter
    below/above slices drop NaN cells with their weights kept aligned."""

    __slots__ = ("version", "cols", "below_rows", "above_rows", "w_below", "weights_fn", "splits")

    def __init__(self, version, cols, below_rows, above_rows, w_below, weights_fn):
        self.version = version
        self.cols: dict[str, np.ndarray] = cols
        self.below_rows = below_rows      # absolute store rows, sorted
        self.above_rows = above_rows
        self.w_below = w_below            # aligned with below_rows
        self.weights_fn = weights_fn
        self.splits: dict[str, "tuple | None"] = {}

    def split(self, param_name: str) -> "tuple | None":
        """(n, below, above, w_below, w_above) in model space — the same
        tuple shape the single-objective :class:`_TrialFit` hands out, so
        the numeric/categorical samplers downstream are shared."""
        if param_name in self.splits:
            return self.splits[param_name]
        col = self.cols.get(param_name)
        if col is None:
            self.splits[param_name] = None
            return None
        b_vals = col[self.below_rows]
        b_keep = ~np.isnan(b_vals)
        a_vals = col[self.above_rows]
        a_keep = ~np.isnan(a_vals)
        n = int(b_keep.sum() + a_keep.sum())
        if n == 0:
            self.splits[param_name] = None
            return None
        out = (
            n,
            b_vals[b_keep],
            a_vals[a_keep],
            self.w_below[b_keep],
            np.asarray(self.weights_fn(int(a_keep.sum())), dtype=float),
        )
        self.splits[param_name] = out
        return out


class TPESampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_ei_candidates: int = 24,
        gamma: Callable[[int], int] = default_gamma,
        weights: Callable[[int], np.ndarray] = default_weights,
        seed: int | None = None,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        consider_magic_clip: bool = True,
        consider_pruned_trials: bool = False,
        jit_scoring: bool = False,
        multivariate: bool = False,
        multi_objective: bool = False,
    ):
        """``multivariate=True`` switches batched ``Study.ask(n)`` waves to
        the group-decomposed **joint** TPE: one d-dimensional Parzen fit per
        co-observed parameter group (``sample_joint``), modeling parameter
        correlations the per-parameter univariate path cannot.  The default
        ``False`` keeps the frozen univariate path — bit-identical to the
        historical sampler under a fixed seed (pinned by
        ``tests/test_vectorized_parity.py``).

        ``multi_objective=True`` enables the MOTPE split (Ozaki et al.,
        2020) on studies with several directions: the below/"good" set is
        chosen by nondomination rank over the observation store's values
        matrix, ties on the boundary rank broken by greedy hypervolume
        subset selection, and the below observations are weighted by their
        hypervolume contributions (``core/moo.py``).  Everything downstream
        — Parzen fits, candidate scoring, the joint gemm path — is the
        existing machinery, so it composes with ``multivariate=True`` for
        block-sampled multi-objective waves.  With the default ``False`` a
        multi-objective study falls back to uniform sampling, unchanged."""
        self._n_startup = n_startup_trials
        self._n_ei = n_ei_candidates
        self._gamma = gamma
        self._weights = weights
        self._rng = np.random.RandomState(seed)
        self._consider_prior = consider_prior
        self._prior_weight = prior_weight
        self._magic_clip = consider_magic_clip
        self._consider_pruned = consider_pruned_trials
        self._jit_scoring = jit_scoring
        self._multivariate = multivariate
        self._multi_objective = multi_objective
        self._mo_fit: tuple[Any, "_MOFit"] | None = None  # (cache key, fit)
        self._fit: tuple[Any, _TrialFit] | None = None  # (cache key, fit)
        # fitted estimators are deterministic functions of (observations,
        # bounds); memoize them per store version so back-to-back asks with
        # an unchanged history (batched ask, fixed-history scoring) skip the
        # refit entirely
        self._est_cache: tuple[Any, dict] | None = None
        self._joint_cache: tuple[Any, dict] | None = None  # per store version

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    # -- observation collection ------------------------------------------------

    def _trial_fit(self, study: "Study", trial: FrozenTrial) -> _TrialFit:
        """The batched split for this trial, built on first use and reused by
        every subsequent suggest of the same trial."""
        store = study.observations()
        version, states, values, last_iv, cols = store.snapshot()
        key = (id(study), trial.number, version)
        cached = self._fit
        if cached is not None and cached[0] == key:
            return cached[1]
        with telemetry.span("tpe.fit"):
            sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
            complete = states == int(TrialState.COMPLETE)
            with np.errstate(invalid="ignore"):
                valid = complete & np.isfinite(values)
                loss = sign * values
                if self._consider_pruned:
                    pruned = (states == int(TrialState.PRUNED)) & np.isfinite(last_iv)
                    valid = valid | pruned
                    loss = np.where(complete, loss, sign * last_iv)
            fit = _TrialFit(version, cols, valid, loss, self._gamma, self._weights)
        self._fit = (key, fit)
        return fit

    # -- joint (multivariate) sampling --------------------------------------------

    def joint_enabled(self) -> bool:
        return self._multivariate

    def _group_split(self, study: "Study", names: list[str]):
        """(version, n_obs, below_rows, above_rows, w_below, w_above) over
        trials that observed *every* parameter of the group, or None below
        startup.  Reads one consistent store snapshot (concurrent tells from
        other worker threads replace, never mutate, the snapshot views)."""
        version, states, values, last_iv, cols = study.observations().snapshot()
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        complete = states == int(TrialState.COMPLETE)
        with np.errstate(invalid="ignore"):
            valid = complete & np.isfinite(values)
            loss = sign * values
            if self._consider_pruned:
                pruned = (states == int(TrialState.PRUNED)) & np.isfinite(last_iv)
                valid = valid | pruned
                loss = np.where(complete, loss, sign * last_iv)
        n_rows = len(states)
        M = (
            np.stack([cols.get(n, np.full(n_rows, np.nan)) for n in names], axis=1)
            if names and n_rows else np.empty((n_rows, len(names)))
        )
        rows = valid & ~np.isnan(M).any(axis=1)
        idx = np.flatnonzero(rows)
        n_obs = len(idx)
        if n_obs < self._n_startup:
            return None
        losses = loss[idx]
        order = np.argsort(losses, kind="stable")
        n_below = self._gamma(n_obs)
        w_all = np.asarray(self._weights(n_obs), dtype=float)
        Mi = M[idx]
        below_i, above_i = order[:n_below], order[n_below:]
        return version, n_obs, Mi[below_i], Mi[above_i], w_all[below_i], w_all[above_i]

    def _group_split_mo(self, study: "Study", names: list[str]):
        """Multi-objective sibling of :meth:`_group_split`: same return
        tuple, but the below set is selected by nondomination rank + greedy
        hypervolume subset selection over the values matrix and weighted by
        hypervolume contributions (MOTPE), restricted to trials that
        observed every parameter of the group."""
        from .. import moo

        store = study.observations()
        version, states, Vmat, arity, _, cols = store.snapshot_mo()
        directions = study.directions
        valid = self._mo_valid_rows(states, Vmat, arity, len(directions))
        n_rows = len(states)
        M = (
            np.stack([cols.get(n, np.full(n_rows, np.nan)) for n in names], axis=1)
            if names and n_rows else np.empty((n_rows, len(names)))
        )
        rows = valid & ~np.isnan(M).any(axis=1)
        idx = np.flatnonzero(rows)
        n_obs = len(idx)
        if n_obs < self._n_startup:
            return None
        L = moo.loss_matrix(Vmat[idx], directions)
        below_pos, above_pos, w_below = _motpe_split(L, self._gamma(n_obs))
        Mi = M[idx]
        w_above = np.asarray(self._weights(len(above_pos)), dtype=float)
        return version, n_obs, Mi[below_pos], Mi[above_pos], w_below, w_above

    def _joint_score(self, l_est: _GroupParzen, g_est: _GroupParzen, cands: np.ndarray) -> np.ndarray:
        with telemetry.span("tpe.score"):
            return self._joint_score_inner(l_est, g_est, cands)

    def _joint_score_inner(self, l_est: _GroupParzen, g_est: _GroupParzen, cands: np.ndarray) -> np.ndarray:
        if self._jit_scoring and not l_est.cat_dims:
            try:
                return np.asarray(
                    _get_jax_joint_score()(
                        cands,
                        _pad_pow2_rows(l_est.mus, 0.0),
                        _pad_pow2_rows(l_est.sigmas, 1.0),
                        _pad_pow2_rows(l_est.log_norm, 0.0),
                        _pad_pow2_vec(l_est.log_w, -np.inf),
                        _pad_pow2_rows(g_est.mus, 0.0),
                        _pad_pow2_rows(g_est.sigmas, 1.0),
                        _pad_pow2_rows(g_est.log_norm, 0.0),
                        _pad_pow2_vec(g_est.log_w, -np.inf),
                    )
                )
            except ImportError:
                self._jit_scoring = False
        return l_est.log_pdf(cands) - g_est.log_pdf(cands)

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """Multivariate TPE block: **one** Parzen fit per group covers all
        ``n`` pending trials — ``n * n_ei_candidates`` candidate rows drawn
        from the good-set density, scored with one broadcasted
        ``log l - log g`` matrix op, argmax per pending trial.  On
        multi-objective studies (``multi_objective=True``) the below/above
        split comes from the MOTPE rank+hypervolume machinery instead of the
        gamma-quantile loss split; the fit and scoring are identical."""
        if not self._multivariate:
            return None
        if len(study.directions) > 1 and not self._multi_objective:
            return None
        with telemetry.span("tpe.sample_joint"):
            return self._sample_joint_inner(study, group, n)

    def _sample_joint_inner(
        self, study: "Study", group: "ParamGroup", n: int
    ) -> "np.ndarray | None":
        names = list(group.names)
        # cache lookup first: back-to-back waves on one store version reuse
        # the fitted estimators without re-running the split at all
        version = (id(study), study.observations().version)
        if self._joint_cache is None or self._joint_cache[0] != version:
            self._joint_cache = (version, {})
        cache = self._joint_cache[1]
        key = group.names
        ests = cache.get(key, _UNFIT)
        if ests is _UNFIT:
            if len(study.directions) > 1:
                split = self._group_split_mo(study, names)
            else:
                split = self._group_split(study, names)
            if split is None:
                cache[key] = ests = None  # sub-startup: stays cheap per wave
            else:
                _, n_obs, below, above, w_below, w_above = split
                dists = [group.dists[name] for name in names]
                l_est = _GroupParzen(
                    below, dists, w_below,
                    self._consider_prior, self._prior_weight, self._magic_clip,
                )
                g_est = _GroupParzen(
                    above, dists, w_above,
                    self._consider_prior, self._prior_weight, self._magic_clip,
                )
                cache[key] = ests = (l_est, g_est)
        if ests is None:
            return None
        l_est, g_est = ests

        cands = l_est.sample(self._rng, n * self._n_ei)
        score = self._joint_score(l_est, g_est, cands).reshape(n, self._n_ei)
        best = np.argmax(score, axis=1)
        return cands.reshape(n, self._n_ei, len(names))[np.arange(n), best]

    # -- sampling -----------------------------------------------------------------

    def _mo_valid_rows(
        self, states: np.ndarray, Vmat: np.ndarray, arity: np.ndarray, m: int
    ) -> np.ndarray:
        """Observation mask for the MOTPE split: COMPLETE trials with a
        finite full-arity objective vector.  ``consider_pruned_trials=True``
        additionally admits PRUNED trials that recorded a full vector —
        unlike the single-objective path there is no last-intermediate-value
        substitute (a scalarized report is one number, not an objective
        vector), so partially-reported pruned trials stay excluded."""
        ok = states == int(TrialState.COMPLETE)
        if self._consider_pruned:
            ok = ok | (states == int(TrialState.PRUNED))
        with np.errstate(invalid="ignore"):
            return ok & (arity == m) & np.isfinite(Vmat).all(axis=1)

    def _mo_trial_fit(self, study: "Study") -> "_MOFit | None":
        """The MOTPE split for the study's current history, memoized per
        store version (the split is a function of the values matrix alone,
        so every trial and every suggest on one history shares it)."""
        store = study.observations()
        version, states, Vmat, arity, _, cols = store.snapshot_mo()
        key = (id(study), version)
        cached = self._mo_fit
        if cached is not None and cached[0] == key:
            return cached[1]
        from .. import moo

        directions = study.directions
        valid = self._mo_valid_rows(states, Vmat, arity, len(directions))
        rows = np.flatnonzero(valid)
        if len(rows) == 0:
            return None
        L = moo.loss_matrix(Vmat[rows], directions)
        below_pos, above_pos, w_below = _motpe_split(L, self._gamma(len(rows)))
        fit = _MOFit(
            version, cols, rows[below_pos], rows[above_pos], w_below, self._weights
        )
        self._mo_fit = (key, fit)
        return fit

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        if len(study.directions) > 1:
            if not self._multi_objective:
                # multi-objective study without the MOTPE switch: fall back
                # to uniform sampling, unchanged historical behavior
                internal = sample_uniform_internal(self._rng, param_distribution)
                return param_distribution.to_external_repr(internal)
            fit = self._mo_trial_fit(study)
            split = fit.split(param_name) if fit is not None else None
        else:
            fit = self._trial_fit(study, trial)
            split = fit.split(param_name)
        if split is None or split[0] < self._n_startup:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        _, below, above, w_below, w_above = split

        version = (id(study), fit.version)
        if self._est_cache is None or self._est_cache[0] != version:
            self._est_cache = (version, {})
        cache = self._est_cache[1]

        if isinstance(param_distribution, CategoricalDistribution):
            internal = self._sample_categorical(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        else:
            internal = self._sample_numeric(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        return param_distribution.to_external_repr(internal)

    def _score(self, l_est: _ParzenEstimator, g_est: _ParzenEstimator, cands: np.ndarray) -> np.ndarray:
        with telemetry.span("tpe.score"):
            return self._score_inner(l_est, g_est, cands)

    def _score_inner(self, l_est: _ParzenEstimator, g_est: _ParzenEstimator, cands: np.ndarray) -> np.ndarray:
        if self._jit_scoring:
            try:
                return np.asarray(
                    _get_jax_score()(
                        cands,
                        *_pad_pow2(l_est.mus, l_est.sigmas, l_est._log_norm),
                        *_pad_pow2(g_est.mus, g_est.sigmas, g_est._log_norm),
                    )
                )
            except ImportError:
                self._jit_scoring = False
        return _score_numpy(
            cands,
            l_est.mus, l_est.sigmas, l_est._log_norm,
            g_est.mus, g_est.sigmas, g_est._log_norm,
        )

    def _sample_numeric(
        self,
        dist: BaseDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        low, high = dist.internal_bounds(expand_int=True)
        key = (param_name, low, high)
        ests = cache.get(key)
        if ests is None:
            l_est = _ParzenEstimator(
                below, low, high, w_below,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            g_est = _ParzenEstimator(
                above, low, high, w_above,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            cache[key] = ests = (l_est, g_est)
        l_est, g_est = ests
        cands = l_est.sample(self._rng, self._n_ei)
        score = self._score(l_est, g_est, cands)
        best = cands[int(np.argmax(score))]
        return float(dist.from_internal(np.asarray([best]))[0])

    def _sample_categorical(
        self,
        dist: CategoricalDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        k = len(dist.choices)
        key = (param_name, "categorical", k)
        probs = cache.get(key)
        if probs is None:

            def weighted_probs(idxs: np.ndarray, ws: np.ndarray) -> np.ndarray:
                counts = np.full(k, self._prior_weight)
                # np.add.at accumulates in element order, matching a scalar loop
                np.add.at(counts, idxs.astype(int), ws)
                return counts / counts.sum()

            cache[key] = probs = (
                weighted_probs(below, w_below),
                weighted_probs(above, w_above),
            )
        p_l, p_g = probs
        cands = self._rng.choice(k, size=self._n_ei, p=p_l)
        score = np.log(p_l[cands] + EPS) - np.log(p_g[cands] + EPS)
        return float(cands[int(np.argmax(score))])
