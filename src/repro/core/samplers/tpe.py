"""Tree-structured Parzen Estimator sampler (Bergstra et al., 2011).

The paper's default independent sampler (§3.1).  For each parameter:

1. split the observed (value, loss) history at the gamma-quantile into
   "below" (good) and "above" (bad) sets,
2. fit a Parzen estimator (truncated-Gaussian mixture + uniform prior
   component) to each set,
3. draw ``n_ei_candidates`` from the *below* estimator and keep the candidate
   maximizing ``log l(x) - log g(x)`` (the EI-equivalent ratio).

Numeric parameters with ``log=True`` are modeled in log space; ints are
modeled continuously and rounded; categoricals use smoothed weighted counts.

Hot path
--------
Observations come from the study's **columnar observation store**
(``core/records.py``): one ``(n_trials, n_params)`` model-space matrix
instead of a per-``ask`` re-walk of ``FrozenTrial`` lists.  On the first
suggest of each trial the sampler splits the loss vector once and slices
below/above observations for *all* parameters out of the matrix (the split,
weights, and gather are shared numpy ops — the old path redid them per
parameter in interpreted loops).  Candidate scoring evaluates both mixture
log-pdfs in one broadcasted matrix op; with the default ``engine="auto"``
the scorer moves onto the device (jit / Pallas, see ``kernels/ops.py``) once
``n_candidates x n_components`` crosses the work threshold, and large
histories additionally amortize repeated asks through a device-built score
table (``log l - log g`` on a dense grid, ``np.interp`` per ask).  Sampling
draws are RNG-stream-identical to the pre-refactor scalar path, so seeded
studies reproduce bit-for-bit (see ``samplers/_legacy.py`` and
``tests/test_vectorized_parity.py``).
"""

from __future__ import annotations

import logging
import math
from typing import TYPE_CHECKING, Any, Callable

import numpy as np

from ...kernels import ops as kops
from .. import telemetry
from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial, StudyDirection, TrialState
from ..log import get_logger, log_once
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..records import ObservationStore
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["TPESampler", "default_gamma", "default_weights"]

EPS = 1e-12

_log = get_logger(__name__)

try:  # vectorized C erf; the portable fallback loops math.erf per element
    from scipy.special import erf as _erf
except ImportError:  # pragma: no cover - scipy is an optional accelerator
    _erf = np.vectorize(math.erf)


def default_gamma(n: int) -> int:
    """Size of the 'below' (good) set (Optuna's default)."""
    return min(int(np.ceil(0.1 * n)), 25)


def default_weights(n: int) -> np.ndarray:
    """Older observations get linearly down-weighted past the 25 most recent."""
    if n == 0:
        return np.asarray([])
    if n < 25:
        return np.ones(n)
    ramp = np.linspace(1.0 / n, 1.0, n - 25)
    flat = np.ones(25)
    return np.concatenate([ramp, flat])


class _ParzenEstimator:
    """1-D truncated-Gaussian mixture over [low, high] (+ a wide prior)."""

    def __init__(
        self,
        mus: np.ndarray,
        low: float,
        high: float,
        weights: np.ndarray,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        mus = np.asarray(mus, dtype=float)
        order = np.argsort(mus)
        mus = mus[order]
        weights = np.asarray(weights, dtype=float)[order]

        if consider_prior or len(mus) == 0:
            prior_mu = 0.5 * (low + high)
            prior_sigma = high - low if high > low else 1.0
            # place the prior into sorted position
            idx = np.searchsorted(mus, prior_mu)
            mus = np.insert(mus, idx, prior_mu)
            weights = np.insert(weights, idx, prior_weight)
            prior_pos = idx
        else:
            prior_pos = None

        n = len(mus)
        sigmas = np.empty(n)
        if n == 1:
            sigmas[0] = high - low if high > low else 1.0
        else:
            padded = np.concatenate([[low], mus, [high]])
            left = mus - padded[:-2]
            right = padded[2:] - mus
            sigmas = np.maximum(left, right)
        if prior_pos is not None:
            sigmas[prior_pos] = high - low if high > low else 1.0
        maxsigma = high - low if high > low else 1.0
        minsigma = (
            maxsigma / min(100.0, 1.0 + n) if magic_clip else EPS
        )
        self.mus = mus
        self.sigmas = np.clip(sigmas, minsigma, maxsigma)
        self.weights = weights / max(weights.sum(), EPS)
        self.low = low
        self.high = high
        # truncated-normal normalization + log component constants, computed
        # once per fit: log_pdf then reduces to one broadcasted quadratic
        z = _normal_cdf((high - self.mus) / self.sigmas) - _normal_cdf(
            (low - self.mus) / self.sigmas
        )
        self._log_norm = (
            -np.log(self.sigmas)
            - 0.5 * math.log(2 * math.pi)
            - np.log(np.maximum(z, EPS))
            + np.log(self.weights + EPS)
        )

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        comp = rng.choice(len(self.mus), size=size, p=self.weights)
        mus, sigmas = self.mus, self.sigmas
        low, high = self.low, self.high
        out = np.empty(size)
        for i, c in enumerate(comp):
            # rejection-free truncated normal via clipped resampling (bounded loops)
            v = float(rng.normal(mus[c], sigmas[c]))
            for _ in range(16):
                if low <= v <= high:
                    break
                v = float(rng.normal(mus[c], sigmas[c]))
            out[i] = min(max(v, low), high)
        return out

    def log_pdf(self, xs: np.ndarray) -> np.ndarray:
        return _mixture_log_pdf(
            np.asarray(xs, dtype=float), self.mus, self.sigmas, self._log_norm
        )


def _normal_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(np.asarray(x) / math.sqrt(2.0)))


def _logsumexp(a: np.ndarray, axis: int) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    return (m + np.log(np.sum(np.exp(a - m), axis=axis, keepdims=True))).squeeze(axis)


def _mixture_log_pdf(
    cands: np.ndarray, mus: np.ndarray, sigmas: np.ndarray, log_norm: np.ndarray
) -> np.ndarray:
    """Mixture log-pdf over all candidates in one broadcasted matrix op.

    Works in-place on a single ``(n_cands, n_components)`` buffer.  The
    max-shifted exponent is floored at -700 before ``exp``: the shifted
    maximum is exactly 0, so the per-row sum is >= 1 and any term below
    ``exp(-700) ~ 1e-304`` is absorbed with no effect on the result — but
    flooring keeps ``exp`` out of the subnormal range, which costs ~30x on
    common hardware (far candidates in log-space domains land there
    constantly)."""
    z = cands[:, None] - mus[None, :]
    z /= sigmas[None, :]
    np.square(z, out=z)
    z *= -0.5
    z += log_norm[None, :]
    m = z.max(axis=1)
    z -= m[:, None]
    np.maximum(z, -700.0, out=z)
    np.exp(z, out=z)
    return m + np.log(z.sum(axis=1))


def _score_numpy(
    cands: np.ndarray,
    l_mus: np.ndarray, l_sigmas: np.ndarray, l_log_norm: np.ndarray,
    g_mus: np.ndarray, g_sigmas: np.ndarray, g_log_norm: np.ndarray,
) -> np.ndarray:
    """``log l(x) - log g(x)`` for all candidates, two batched mixture ops."""
    return _mixture_log_pdf(cands, l_mus, l_sigmas, l_log_norm) - _mixture_log_pdf(
        cands, g_mus, g_sigmas, g_log_norm
    )


_jax_score = None


def _get_jax_score():
    """Jitted scorer, built lazily.  Component arrays arrive padded to
    power-of-two buckets (``kernels/ops.pad_pow2_vec`` with ``log_norm =
    -inf``), so the set of shapes XLA ever sees — and hence the number of
    retraces — stays logarithmic in the observation count instead of
    linear (pinned via the ``tpe.score`` trace-registry key)."""
    global _jax_score
    if _jax_score is None:
        import jax
        import jax.numpy as jnp

        def score(cands, l_mus, l_sigmas, l_log_norm, g_mus, g_sigmas, g_log_norm):
            kops.bump_trace("tpe.score")  # body runs once per trace, not per call

            def lse(a):
                m = jnp.max(a, axis=1, keepdims=True)
                return (m + jnp.log(jnp.sum(jnp.exp(a - m), axis=1, keepdims=True)))[:, 0]

            xs = cands[:, None]
            log_l = lse(-0.5 * ((xs - l_mus[None, :]) / l_sigmas[None, :]) ** 2 + l_log_norm[None, :])
            log_g = lse(-0.5 * ((xs - g_mus[None, :]) / g_sigmas[None, :]) ** 2 + g_log_norm[None, :])
            return log_l - log_g

        _jax_score = jax.jit(score)
    return _jax_score


def _pad_est(est: "_ParzenEstimator"):
    """One estimator's component triple, pow2-padded for the device paths."""
    return (
        kops.pad_pow2_vec(est.mus, 0.0),
        kops.pad_pow2_vec(est.sigmas, 1.0),
        kops.pad_pow2_vec(est._log_norm, -np.inf),
    )


_jax_gemm_score = None


def _get_jax_gemm_score():
    """Jitted joint scorer over gemm features (numeric **and** categorical
    groups).  Every mixture — Gaussian quadratics expanded, categorical
    point-mass log-probs one-hot encoded (see ``_GroupParzen.gemm_coeffs``)
    — reduces to ``F @ C.T + const`` followed by a logsumexp over the
    component axis, so the whole acquisition is two MXU matmuls.  Component
    axes arrive padded to power-of-two buckets with ``const = -inf`` and
    candidate rows to power-of-two counts, keeping the trace count
    logarithmic (``tpe.joint`` registry key)."""
    global _jax_gemm_score
    if _jax_gemm_score is None:
        import jax
        import jax.numpy as jnp

        def score(F, l_coeffs, l_const, g_coeffs, g_const):
            kops.bump_trace("tpe.joint")  # body runs once per trace, not per call

            def side(coeffs, const):
                e = F @ coeffs.T + const[None, :]
                m = jnp.max(e, axis=1, keepdims=True)
                return (m + jnp.log(jnp.sum(jnp.exp(e - m), axis=1, keepdims=True)))[:, 0]

            return side(l_coeffs, l_const) - side(g_coeffs, g_const)

        _jax_gemm_score = jax.jit(score)
    return _jax_gemm_score


#: joint-cache sentinel distinguishing "never fitted" from "fitted: declined"
_UNFIT = object()


class _GroupParzen:
    """d-dimensional Parzen estimator over one co-observed parameter group.

    One mixture component per observed trial **row** (plus an optional wide
    prior), each component a *product* kernel: per-dim truncated Gaussians
    for numeric parameters (Scott-rule bandwidth, magic-clipped) and
    smoothed point-mass kernels for categoricals.  Modeling whole rows is
    what makes the estimator genuinely multivariate — the good-set density
    ``l(x)`` preserves correlations between parameters (a narrow valley
    ``x ≈ y`` stays narrow), which per-parameter univariate TPE marginals
    cannot represent.
    """

    __slots__ = (
        "mus", "sigmas", "log_norm", "log_w", "weights", "lows", "highs",
        "cat_dims", "num_dims", "cat_index", "n_choices", "prior_weight",
        "_inv_var", "_lin", "_const", "_gemm",
    )

    def __init__(
        self,
        rows: np.ndarray,               # (n_obs, d) model-space observations
        dists: "list[BaseDistribution]",
        weights: np.ndarray,            # (n_obs,) recency weights
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        magic_clip: bool = True,
    ):
        rows = np.asarray(rows, dtype=float)
        n_obs, d = rows.shape
        self.cat_dims = [j for j, ds in enumerate(dists) if isinstance(ds, CategoricalDistribution)]
        self.num_dims = [j for j in range(d) if j not in self.cat_dims]
        self.n_choices = {
            j: len(dists[j].choices) for j in self.cat_dims  # type: ignore[attr-defined]
        }
        self.prior_weight = float(prior_weight)

        lows = np.empty(d)
        highs = np.empty(d)
        for j, ds in enumerate(dists):
            lows[j], highs[j] = ds.internal_bounds(expand_int=True)
        self.lows, self.highs = lows, highs

        n_comp = n_obs + (1 if (consider_prior or n_obs == 0) else 0)
        mus = np.zeros((n_comp, d))
        mus[:n_obs] = rows
        w = np.empty(n_comp)
        w[:n_obs] = np.asarray(weights, dtype=float)
        # categorical index per (component, cat-dim); -1 marks the uniform
        # prior component
        cat_index = np.full((n_comp, len(self.cat_dims)), -1, dtype=np.int64)
        for c, j in enumerate(self.cat_dims):
            cat_index[:n_obs, c] = np.round(rows[:, j]).astype(np.int64)
        self.cat_index = cat_index

        ranges = np.where(highs > lows, highs - lows, 1.0)
        sigmas = np.ones((n_comp, d))
        if n_obs > 0:
            # Scott-rule bandwidth per dim, shared by all data components;
            # the prior keeps the full-range sigma
            scott = np.std(rows, axis=0) * float(n_obs) ** (-1.0 / (d + 4))
            maxsigma = ranges
            minsigma = (
                maxsigma / min(100.0, 1.0 + n_comp) if magic_clip
                else np.full(d, EPS)
            )
            sigmas[:n_obs] = np.clip(scott, minsigma, maxsigma)[None, :]
        if n_comp > n_obs:  # prior component: wide gaussian / uniform pmf
            mus[n_obs] = 0.5 * (lows + highs)
            sigmas[n_obs] = ranges
            w[n_obs] = prior_weight

        self.mus = mus
        self.sigmas = sigmas
        self.weights = w / max(w.sum(), EPS)
        self.log_w = np.log(self.weights + EPS)

        # truncated-normal normalization per (component, numeric dim)
        log_norm = np.zeros((n_comp, d))
        nd = self.num_dims
        if nd:
            z = _normal_cdf((highs[nd][None, :] - mus[:, nd]) / sigmas[:, nd]) - _normal_cdf(
                (lows[nd][None, :] - mus[:, nd]) / sigmas[:, nd]
            )
            log_norm[:, nd] = (
                -np.log(sigmas[:, nd])
                - 0.5 * math.log(2 * math.pi)
                - np.log(np.maximum(z, EPS))
            )
        self.log_norm = log_norm

        # gemm-form coefficients of the Gaussian quadratic (see log_pdf):
        # sum_j -0.5((x_j - mu_ij)/s_ij)^2 expands so candidate scoring is
        # two (n_cands, d) @ (d, n_comp) matmuls instead of a per-dim
        # broadcast loop over (n_cands, n_comp) temporaries
        inv_var = 1.0 / np.square(sigmas[:, nd]) if nd else np.zeros((n_comp, 0))
        self._inv_var = inv_var
        self._lin = mus[:, nd] * inv_var
        self._const = (
            -0.5 * (np.square(mus[:, nd]) * inv_var).sum(axis=1)
            + log_norm[:, nd].sum(axis=1)
            + self.log_w
        )
        self._gemm: "tuple[np.ndarray, np.ndarray] | None" = None

    # -- sampling ---------------------------------------------------------------

    def sample(self, rng: np.random.RandomState, size: int) -> np.ndarray:
        """Draw ``size`` model-space rows — fully vectorized (component
        choice, clipped-resample truncated normals, smoothed categorical
        kernels), unlike the univariate estimator's per-candidate loop."""
        comp = rng.choice(len(self.weights), size=size, p=self.weights)
        out = np.empty((size, self.mus.shape[1]))
        nd = self.num_dims
        if nd:
            mu = self.mus[comp][:, nd]
            sigma = self.sigmas[comp][:, nd]
            lo, hi = self.lows[nd][None, :], self.highs[nd][None, :]
            x = rng.normal(mu, sigma)
            for _ in range(16):  # bounded vectorized truncation retries
                bad = (x < lo) | (x > hi)
                if not bad.any():
                    break
                x[bad] = rng.normal(mu[bad], sigma[bad])
            out[:, nd] = np.clip(x, lo, hi)
        pw = self.prior_weight
        for c, j in enumerate(self.cat_dims):
            k = self.n_choices[j]
            m = self.cat_index[comp, c]
            # component pmf (1[c=m] + pw/k)/(1 + pw): keep the observed
            # choice w.p. 1/(1+pw), else uniform; prior component (m = -1)
            # is uniform outright
            keep = (rng.uniform(size=size) < 1.0 / (1.0 + pw)) & (m >= 0)
            out[:, j] = np.where(keep, m, rng.randint(k, size=size)).astype(float)
        return out

    # -- scoring ----------------------------------------------------------------

    def log_pdf(self, X: np.ndarray) -> np.ndarray:
        """Mixture log-density of ``(n_cands, d)`` rows: per-component
        product over dims, logsumexp over components.  The Gaussian block is
        evaluated in expanded quadratic form — two BLAS matmuls against the
        precomputed ``1/sigma^2`` coefficient matrices — so cost scales as a
        gemm instead of a python loop over dims (the expansion's cancellation
        error is ~1e-10 in log space, far below sampling noise)."""
        X = np.asarray(X, dtype=float)
        nd = self.num_dims
        if nd:
            Xn = X[:, nd]
            E = np.square(Xn) @ self._inv_var.T
            E -= 2.0 * (Xn @ self._lin.T)
            E *= -0.5
            E += self._const[None, :]
        else:
            E = np.broadcast_to(self._const[None, :], (len(X), len(self._const))).copy()
        pw = self.prior_weight
        for c, j in enumerate(self.cat_dims):
            k = self.n_choices[j]
            m = self.cat_index[None, :, c]
            hit = np.round(X[:, j, None]).astype(np.int64) == m
            p = np.where(
                m < 0, 1.0 / k,  # uniform prior component
                (hit.astype(float) + pw / k) / (1.0 + pw),
            )
            E += np.log(p + EPS)
        m_ = E.max(axis=1)
        E -= m_[:, None]
        np.maximum(E, -700.0, out=E)
        np.exp(E, out=E)
        return m_ + np.log(E.sum(axis=1))

    def gemm_coeffs(self) -> tuple[np.ndarray, np.ndarray]:
        """``(coeffs (n_comp, f), const (n_comp,))`` such that the exponent
        matrix of :meth:`log_pdf` is exactly ``gemm_features(X) @ coeffs.T +
        const`` — the device-friendly form covering **mixed** groups.

        Feature layout (matching :meth:`gemm_features`): the numeric block
        ``[x_j^2 | x_j]`` carries the expanded Gaussian quadratic, then one
        one-hot block per categorical dim whose coefficients are the
        component's point-mass log-probs ``log((1[c=m] + pw/k)/(1+pw) +
        EPS)`` (uniform ``log(1/k + EPS)`` for the prior component) — a
        one-hot feature dotted against that row *selects* the same
        ``log p`` term the numpy path adds elementwise."""
        cached = self._gemm
        if cached is not None:
            return cached
        pw = self.prior_weight
        blocks = [-0.5 * self._inv_var, self._lin]
        for c, j in enumerate(self.cat_dims):
            k = self.n_choices[j]
            m = self.cat_index[:, c][:, None]  # (n_comp, 1)
            hit = (m == np.arange(k)[None, :]).astype(float)
            p = np.where(m < 0, 1.0 / k, (hit + pw / k) / (1.0 + pw))
            blocks.append(np.log(p + EPS))
        self._gemm = cached = (np.concatenate(blocks, axis=1), self._const)
        return cached

    def gemm_features(self, X: np.ndarray) -> np.ndarray:
        """Candidate rows expanded to the :meth:`gemm_coeffs` feature layout:
        ``[X_num^2 | X_num | one-hot(cat_0) | one-hot(cat_1) | ...]``."""
        X = np.asarray(X, dtype=float)
        Xn = X[:, self.num_dims]
        blocks = [np.square(Xn), Xn]
        rows = np.arange(len(X))
        for j in self.cat_dims:
            k = self.n_choices[j]
            onehot = np.zeros((len(X), k))
            onehot[rows, np.round(X[:, j]).astype(np.int64)] = 1.0
            blocks.append(onehot)
        return np.concatenate(blocks, axis=1)


class _TrialFit:
    """Per-trial batched observation split, shared by every suggest call of
    one trial: the loss vector, its argsort, and the recency weights are
    computed once; per-parameter below/above slices are cut lazily from the
    snapshotted matrix columns.

    Built from one ``ObservationStore.snapshot()`` — never from live store
    properties — so concurrent ``tell``s from other threads (batched
    ``optimize(n_jobs=..)``) cannot grow a column under a mask captured at
    fit time."""

    __slots__ = (
        "version", "cols", "valid", "loss", "full_order", "w_by_n", "splits",
        "gamma", "weights_fn",
    )

    def __init__(self, version, cols, valid, loss, gamma, weights_fn):
        self.version = version
        self.cols: dict[str, np.ndarray] = cols
        self.valid: np.ndarray = valid
        self.loss: np.ndarray = loss
        self.full_order: np.ndarray | None = None
        self.w_by_n: dict[int, np.ndarray] = {}
        self.splits: dict[str, "tuple | None"] = {}
        self.gamma = gamma
        self.weights_fn = weights_fn

    def split(self, param_name: str) -> "tuple | None":
        """(n, below, above, w_below, w_above) in model space, or None when
        the parameter has never been observed."""
        if param_name in self.splits:
            return self.splits[param_name]
        col = self.cols.get(param_name)
        if col is None:
            self.splits[param_name] = None
            return None
        present = self.valid & ~np.isnan(col)
        idx = np.flatnonzero(present)
        n = len(idx)
        if n == 0:
            self.splits[param_name] = None
            return None
        vals = col[idx]
        losses = self.loss[idx]
        if np.array_equal(present, self.valid):
            # unconditional parameter: every such column shares one argsort
            if self.full_order is None:
                self.full_order = np.argsort(losses, kind="stable")
            order = self.full_order
        else:
            order = np.argsort(losses, kind="stable")
        n_below = self.gamma(n)
        w_all = self.w_by_n.get(n)
        if w_all is None:
            w_all = np.asarray(self.weights_fn(n), dtype=float)
            self.w_by_n[n] = w_all
        below_idx, above_idx = order[:n_below], order[n_below:]
        out = (n, vals[below_idx], vals[above_idx], w_all[below_idx], w_all[above_idx])
        self.splits[param_name] = out
        return out


def _motpe_split(
    L: np.ndarray, n_below: int, engine: str = "auto"
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """MOTPE below/above split of a loss matrix ``L`` (rows = observations,
    already minimize-oriented and finite): fill the below set by
    nondomination rank; break ties on the boundary rank by greedy
    hypervolume subset selection; weight the below rows by their normalized
    hypervolume contributions.  Returns ``(below_pos, above_pos, w_below)``
    with both index arrays sorted (chronological order, so the above set's
    recency weights stay meaningful).

    Hypervolume evaluations route through a ``HypervolumeEstimator``
    (exact WFG for m <= 4, seeded Monte-Carlo counting above — the exact
    recursion is exponential in m, which used to cap MOTPE at few-objective
    studies)."""
    from .. import moo

    n = len(L)
    n_below = int(min(max(n_below, 0), n))
    est = moo.HypervolumeEstimator(engine=engine)
    ranks = moo.nondomination_ranks(L, engine=engine)
    below = np.zeros(0, dtype=np.int64)
    for r in np.unique(ranks):
        members = np.flatnonzero(ranks == r)
        if len(below) + len(members) <= n_below:
            below = np.concatenate([below, members])
            continue
        want = n_below - len(below)
        if want > 0:
            ref = moo.default_reference_point(L[members])
            sel = moo.solve_hssp(L[members], want, ref, estimator=est)
            below = np.concatenate([below, members[sel]])
        break
    below = np.sort(below)
    above = np.setdiff1d(np.arange(n), below)
    if len(below) <= 1:
        w_below = np.ones(len(below))
    else:
        ref = moo.default_reference_point(L[below])
        contrib = moo.hypervolume_contributions(L[below], ref, estimator=est) + EPS
        w_below = np.clip(contrib / contrib.max(), 0.0, 1.0)
    return below, above, w_below


class _MOFit:
    """Multi-objective sibling of :class:`_TrialFit`: one rank+HSSP split of
    the values matrix per store version, shared by every suggest call (and
    every pending trial of a wave) on that history.  Per-parameter
    below/above slices drop NaN cells with their weights kept aligned."""

    __slots__ = ("version", "cols", "below_rows", "above_rows", "w_below", "weights_fn", "splits")

    def __init__(self, version, cols, below_rows, above_rows, w_below, weights_fn):
        self.version = version
        self.cols: dict[str, np.ndarray] = cols
        self.below_rows = below_rows      # absolute store rows, sorted
        self.above_rows = above_rows
        self.w_below = w_below            # aligned with below_rows
        self.weights_fn = weights_fn
        self.splits: dict[str, "tuple | None"] = {}

    def split(self, param_name: str) -> "tuple | None":
        """(n, below, above, w_below, w_above) in model space — the same
        tuple shape the single-objective :class:`_TrialFit` hands out, so
        the numeric/categorical samplers downstream are shared."""
        if param_name in self.splits:
            return self.splits[param_name]
        col = self.cols.get(param_name)
        if col is None:
            self.splits[param_name] = None
            return None
        b_vals = col[self.below_rows]
        b_keep = ~np.isnan(b_vals)
        a_vals = col[self.above_rows]
        a_keep = ~np.isnan(a_vals)
        n = int(b_keep.sum() + a_keep.sum())
        if n == 0:
            self.splits[param_name] = None
            return None
        out = (
            n,
            b_vals[b_keep],
            a_vals[a_keep],
            self.w_below[b_keep],
            np.asarray(self.weights_fn(int(a_keep.sum())), dtype=float),
        )
        self.splits[param_name] = out
        return out


class TPESampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_ei_candidates: int = 24,
        gamma: Callable[[int], int] = default_gamma,
        weights: Callable[[int], np.ndarray] = default_weights,
        seed: int | None = None,
        consider_prior: bool = True,
        prior_weight: float = 1.0,
        consider_magic_clip: bool = True,
        consider_pruned_trials: bool = False,
        jit_scoring: bool = False,
        multivariate: bool = False,
        multi_objective: bool = False,
        engine: str = "auto",
    ):
        """``engine`` selects the scoring backend: ``"auto"`` (default)
        dispatches candidate scoring to the device (jax jit, or the Pallas
        kernels when enabled — see ``kernels/ops.resolve_engine``) once
        ``n_candidates x n_components`` crosses the work threshold, staying
        on numpy below it; ``"numpy"`` pins the pure-numpy path; ``"jax"`` /
        ``"pallas"`` force a device path regardless of size (falling back to
        numpy — logged once, counted in the ``sampler.engine_fallbacks``
        telemetry counter — when jax is unavailable or the device call
        fails).  ``jit_scoring=True`` is the historical spelling of
        ``engine="jax"``.

        ``multivariate=True`` switches batched ``Study.ask(n)`` waves to
        the group-decomposed **joint** TPE: one d-dimensional Parzen fit per
        co-observed parameter group (``sample_joint``), modeling parameter
        correlations the per-parameter univariate path cannot.  The default
        ``False`` keeps the frozen univariate path — bit-identical to the
        historical sampler under a fixed seed (pinned by
        ``tests/test_vectorized_parity.py``).

        ``multi_objective=True`` enables the MOTPE split (Ozaki et al.,
        2020) on studies with several directions: the below/"good" set is
        chosen by nondomination rank over the observation store's values
        matrix, ties on the boundary rank broken by greedy hypervolume
        subset selection, and the below observations are weighted by their
        hypervolume contributions (``core/moo.py``).  Everything downstream
        — Parzen fits, candidate scoring, the joint gemm path — is the
        existing machinery, so it composes with ``multivariate=True`` for
        block-sampled multi-objective waves.  With the default ``False`` a
        multi-objective study falls back to uniform sampling, unchanged."""
        self._n_startup = n_startup_trials
        self._n_ei = n_ei_candidates
        self._gamma = gamma
        self._weights = weights
        self._rng = np.random.RandomState(seed)
        self._consider_prior = consider_prior
        self._prior_weight = prior_weight
        self._magic_clip = consider_magic_clip
        self._consider_pruned = consider_pruned_trials
        if jit_scoring and engine == "auto":
            engine = "jax"  # historical opt-in spelling; explicit engine wins
        self._engine = kops.validate_engine(engine)
        self._multivariate = multivariate
        self._multi_objective = multi_objective
        self._mo_fit: tuple[Any, "_MOFit"] | None = None  # (cache key, fit)
        self._fit: tuple[Any, _TrialFit] | None = None  # (cache key, fit)
        # fitted estimators are deterministic functions of (observations,
        # bounds); memoize them per store version so back-to-back asks with
        # an unchanged history (batched ask, fixed-history scoring) skip the
        # refit entirely
        self._est_cache: tuple[Any, dict] | None = None
        self._joint_cache: tuple[Any, dict] | None = None  # per store version

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    # -- engine policy -----------------------------------------------------------

    def _engine_for(self, work: int) -> str:
        """Concrete engine for one scoring call of ``work`` units
        (``n_candidates x n_components``).  A requested device engine that
        cannot run (no jax) downgrades to numpy loudly: once per
        (sampler, reason) in the log, every occurrence in the
        ``sampler.engine_fallbacks`` counter — never silently."""
        eng = self._engine
        if eng == "numpy":
            return "numpy"
        if not kops.jax_available():
            self._note_engine_fallback("jax-unavailable")
            return "numpy"
        return kops.resolve_engine(eng, work, kops.TPE_JIT_THRESHOLD)

    def _note_engine_fallback(self, reason: str) -> None:
        telemetry.inc("sampler.engine_fallbacks")
        log_once(
            _log, ("tpe-engine-fallback", id(self), reason), logging.WARNING,
            "TPESampler engine %r downgraded to numpy scoring: %s (logged "
            "once per sampler; occurrences counted in sampler.engine_fallbacks)",
            self._engine, reason,
        )

    # -- observation collection ------------------------------------------------

    def _trial_fit(self, study: "Study", trial: FrozenTrial) -> _TrialFit:
        """The batched split for this trial, built on first use and reused by
        every subsequent suggest of the same trial."""
        store = study.observations()
        version, states, values, last_iv, cols = store.snapshot()
        # keyed on the snapshot alone (not trial.number): the split is a pure
        # function of the finished history, so every pending trial asking
        # against one store version shares the fit
        key = (id(study), version)
        cached = self._fit
        if cached is not None and cached[0] == key:
            return cached[1]
        with telemetry.span("tpe.fit"):
            sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
            complete = states == int(TrialState.COMPLETE)
            with np.errstate(invalid="ignore"):
                valid = complete & np.isfinite(values)
                loss = sign * values
                if self._consider_pruned:
                    pruned = (states == int(TrialState.PRUNED)) & np.isfinite(last_iv)
                    valid = valid | pruned
                    loss = np.where(complete, loss, sign * last_iv)
            fit = _TrialFit(version, cols, valid, loss, self._gamma, self._weights)
        self._fit = (key, fit)
        return fit

    # -- joint (multivariate) sampling --------------------------------------------

    def joint_enabled(self) -> bool:
        return self._multivariate

    def _group_split(self, study: "Study", names: list[str]):
        """(version, n_obs, below_rows, above_rows, w_below, w_above) over
        trials that observed *every* parameter of the group, or None below
        startup.  Reads one consistent store snapshot (concurrent tells from
        other worker threads replace, never mutate, the snapshot views)."""
        version, states, values, last_iv, cols = study.observations().snapshot()
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        complete = states == int(TrialState.COMPLETE)
        with np.errstate(invalid="ignore"):
            valid = complete & np.isfinite(values)
            loss = sign * values
            if self._consider_pruned:
                pruned = (states == int(TrialState.PRUNED)) & np.isfinite(last_iv)
                valid = valid | pruned
                loss = np.where(complete, loss, sign * last_iv)
        n_rows = len(states)
        M = (
            np.stack([cols.get(n, np.full(n_rows, np.nan)) for n in names], axis=1)
            if names and n_rows else np.empty((n_rows, len(names)))
        )
        rows = valid & ~np.isnan(M).any(axis=1)
        idx = np.flatnonzero(rows)
        n_obs = len(idx)
        if n_obs < self._n_startup:
            return None
        losses = loss[idx]
        order = np.argsort(losses, kind="stable")
        n_below = self._gamma(n_obs)
        w_all = np.asarray(self._weights(n_obs), dtype=float)
        Mi = M[idx]
        below_i, above_i = order[:n_below], order[n_below:]
        return version, n_obs, Mi[below_i], Mi[above_i], w_all[below_i], w_all[above_i]

    def _group_split_mo(self, study: "Study", names: list[str]):
        """Multi-objective sibling of :meth:`_group_split`: same return
        tuple, but the below set is selected by nondomination rank + greedy
        hypervolume subset selection over the values matrix and weighted by
        hypervolume contributions (MOTPE), restricted to trials that
        observed every parameter of the group."""
        from .. import moo

        store = study.observations()
        version, states, Vmat, arity, _, cols = store.snapshot_mo()
        directions = study.directions
        valid = self._mo_valid_rows(states, Vmat, arity, len(directions))
        n_rows = len(states)
        M = (
            np.stack([cols.get(n, np.full(n_rows, np.nan)) for n in names], axis=1)
            if names and n_rows else np.empty((n_rows, len(names)))
        )
        rows = valid & ~np.isnan(M).any(axis=1)
        idx = np.flatnonzero(rows)
        n_obs = len(idx)
        if n_obs < self._n_startup:
            return None
        L = moo.loss_matrix(Vmat[idx], directions)
        below_pos, above_pos, w_below = _motpe_split(
            L, self._gamma(n_obs), engine=self._engine
        )
        Mi = M[idx]
        w_above = np.asarray(self._weights(len(above_pos)), dtype=float)
        return version, n_obs, Mi[below_pos], Mi[above_pos], w_below, w_above

    def _joint_score(self, l_est: _GroupParzen, g_est: _GroupParzen, cands: np.ndarray) -> np.ndarray:
        with telemetry.span("tpe.score"):
            return self._joint_score_inner(l_est, g_est, cands)

    def _joint_score_inner(self, l_est: _GroupParzen, g_est: _GroupParzen, cands: np.ndarray) -> np.ndarray:
        work = len(cands) * (len(l_est.weights) + len(g_est.weights))
        eng = self._engine_for(work)
        if eng != "numpy":
            # mixed numeric+categorical groups ride the same gemm: one-hot
            # features select the categorical point-mass log-probs (see
            # gemm_coeffs), so no group shape disables the device path.  The
            # matmul-bound form is already MXU-shaped, so "pallas" and "jax"
            # share this scorer.
            try:
                n = len(cands)
                F = kops.pad_pow2_rows(l_est.gemm_features(cands), 0.0)
                l_coeffs, l_const = l_est.gemm_coeffs()
                g_coeffs, g_const = g_est.gemm_coeffs()
                return np.asarray(
                    _get_jax_gemm_score()(
                        F,
                        kops.pad_pow2_rows(l_coeffs, 0.0),
                        kops.pad_pow2_vec(l_const, -np.inf),
                        kops.pad_pow2_rows(g_coeffs, 0.0),
                        kops.pad_pow2_vec(g_const, -np.inf),
                    )
                )[:n]
            except Exception as e:  # device dispatch failed: downgrade loudly
                self._note_engine_fallback(f"joint-device-error:{type(e).__name__}")
        return l_est.log_pdf(cands) - g_est.log_pdf(cands)

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """Multivariate TPE block: **one** Parzen fit per group covers all
        ``n`` pending trials — ``n * n_ei_candidates`` candidate rows drawn
        from the good-set density, scored with one broadcasted
        ``log l - log g`` matrix op, argmax per pending trial.  On
        multi-objective studies (``multi_objective=True``) the below/above
        split comes from the MOTPE rank+hypervolume machinery instead of the
        gamma-quantile loss split; the fit and scoring are identical."""
        if not self._multivariate:
            return None
        if len(study.directions) > 1 and not self._multi_objective:
            return None
        with telemetry.span("tpe.sample_joint"):
            return self._sample_joint_inner(study, group, n)

    def _sample_joint_inner(
        self, study: "Study", group: "ParamGroup", n: int
    ) -> "np.ndarray | None":
        names = list(group.names)
        # cache lookup first: back-to-back waves on one store version reuse
        # the fitted estimators without re-running the split at all
        version = (id(study), study.observations().version)
        if self._joint_cache is None or self._joint_cache[0] != version:
            self._joint_cache = (version, {})
        cache = self._joint_cache[1]
        key = group.names
        ests = cache.get(key, _UNFIT)
        if ests is _UNFIT:
            if len(study.directions) > 1:
                split = self._group_split_mo(study, names)
            else:
                split = self._group_split(study, names)
            if split is None:
                cache[key] = ests = None  # sub-startup: stays cheap per wave
            else:
                _, n_obs, below, above, w_below, w_above = split
                dists = [group.dists[name] for name in names]
                l_est = _GroupParzen(
                    below, dists, w_below,
                    self._consider_prior, self._prior_weight, self._magic_clip,
                )
                g_est = _GroupParzen(
                    above, dists, w_above,
                    self._consider_prior, self._prior_weight, self._magic_clip,
                )
                cache[key] = ests = (l_est, g_est)
        if ests is None:
            return None
        l_est, g_est = ests

        cands = l_est.sample(self._rng, n * self._n_ei)
        score = self._joint_score(l_est, g_est, cands).reshape(n, self._n_ei)
        best = np.argmax(score, axis=1)
        return cands.reshape(n, self._n_ei, len(names))[np.arange(n), best]

    # -- sampling -----------------------------------------------------------------

    def _mo_valid_rows(
        self, states: np.ndarray, Vmat: np.ndarray, arity: np.ndarray, m: int
    ) -> np.ndarray:
        """Observation mask for the MOTPE split: COMPLETE trials with a
        finite full-arity objective vector.  ``consider_pruned_trials=True``
        additionally admits PRUNED trials that recorded a full vector —
        unlike the single-objective path there is no last-intermediate-value
        substitute (a scalarized report is one number, not an objective
        vector), so partially-reported pruned trials stay excluded."""
        ok = states == int(TrialState.COMPLETE)
        if self._consider_pruned:
            ok = ok | (states == int(TrialState.PRUNED))
        with np.errstate(invalid="ignore"):
            return ok & (arity == m) & np.isfinite(Vmat).all(axis=1)

    def _mo_trial_fit(self, study: "Study") -> "_MOFit | None":
        """The MOTPE split for the study's current history, memoized per
        store version (the split is a function of the values matrix alone,
        so every trial and every suggest on one history shares it)."""
        store = study.observations()
        version, states, Vmat, arity, _, cols = store.snapshot_mo()
        key = (id(study), version)
        cached = self._mo_fit
        if cached is not None and cached[0] == key:
            return cached[1]
        from .. import moo

        directions = study.directions
        valid = self._mo_valid_rows(states, Vmat, arity, len(directions))
        rows = np.flatnonzero(valid)
        if len(rows) == 0:
            return None
        L = moo.loss_matrix(Vmat[rows], directions)
        below_pos, above_pos, w_below = _motpe_split(
            L, self._gamma(len(rows)), engine=self._engine
        )
        fit = _MOFit(
            version, cols, rows[below_pos], rows[above_pos], w_below, self._weights
        )
        self._mo_fit = (key, fit)
        return fit

    def sample_independent(
        self,
        study: "Study",
        trial: FrozenTrial,
        param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        if len(study.directions) > 1:
            if not self._multi_objective:
                # multi-objective study without the MOTPE switch: fall back
                # to uniform sampling, unchanged historical behavior
                internal = sample_uniform_internal(self._rng, param_distribution)
                return param_distribution.to_external_repr(internal)
            fit = self._mo_trial_fit(study)
            split = fit.split(param_name) if fit is not None else None
        else:
            fit = self._trial_fit(study, trial)
            split = fit.split(param_name)
        if split is None or split[0] < self._n_startup:
            internal = sample_uniform_internal(self._rng, param_distribution)
            return param_distribution.to_external_repr(internal)
        _, below, above, w_below, w_above = split

        version = (id(study), fit.version)
        if self._est_cache is None or self._est_cache[0] != version:
            self._est_cache = (version, {})
        cache = self._est_cache[1]

        if isinstance(param_distribution, CategoricalDistribution):
            internal = self._sample_categorical(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        else:
            internal = self._sample_numeric(
                param_distribution, below, above, w_below, w_above, cache, param_name
            )
        return param_distribution.to_external_repr(internal)

    def _score(self, l_est: _ParzenEstimator, g_est: _ParzenEstimator, cands: np.ndarray) -> np.ndarray:
        with telemetry.span("tpe.score"):
            return self._score_inner(l_est, g_est, cands)

    def _score_inner(self, l_est: _ParzenEstimator, g_est: _ParzenEstimator, cands: np.ndarray) -> np.ndarray:
        work = len(cands) * (len(l_est.mus) + len(g_est.mus))
        eng = self._engine_for(work)
        if eng != "numpy":
            try:
                args = (cands, *_pad_est(l_est), *_pad_est(g_est))
                if eng == "pallas":
                    return np.asarray(kops.parzen_score_op(*args))
                return np.asarray(_get_jax_score()(*args))
            except Exception as e:  # device dispatch failed: downgrade loudly
                self._note_engine_fallback(f"device-error:{type(e).__name__}")
        return _score_numpy(
            cands,
            l_est.mus, l_est.sigmas, l_est._log_norm,
            g_est.mus, g_est.sigmas, g_est._log_norm,
        )

    def _sample_numeric(
        self,
        dist: BaseDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        low, high = dist.internal_bounds(expand_int=True)
        key = (param_name, low, high)
        ests = cache.get(key)
        if ests is None:
            l_est = _ParzenEstimator(
                below, low, high, w_below,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            g_est = _ParzenEstimator(
                above, low, high, w_above,
                self._consider_prior, self._prior_weight, self._magic_clip,
            )
            cache[key] = ests = (l_est, g_est)
        l_est, g_est = ests
        cands = l_est.sample(self._rng, self._n_ei)
        table = cache.get((param_name, "table"))
        if table is not None:
            score = np.interp(cands, table[0], table[1])
        else:
            score = self._score(l_est, g_est, cands)
            self._maybe_build_table(cache, param_name, l_est, g_est, low, high)
        best = cands[int(np.argmax(score))]
        return float(dist.from_internal(np.asarray([best]))[0])

    def _maybe_build_table(
        self,
        cache: dict,
        param_name: str,
        l_est: _ParzenEstimator,
        g_est: _ParzenEstimator,
        low: float,
        high: float,
    ) -> None:
        """Amortize device scoring for repeat asks at one observation version.

        On the second score against the same ``(l_est, g_est)`` pair, the
        acquisition ``log l - log g`` is evaluated once on a dense
        ``SCORE_TABLE_SIZE``-point grid (a single large device call — the
        Pallas kernel's target shape) and later asks interpolate it on the
        host in O(n_ei).  Gated on ``magic_clip``: it guarantees every
        component has ``sigma >= (high - low) / 101``, so the acquisition is
        smooth at the grid scale and the piecewise-linear error is bounded by
        ``(101 / SCORE_TABLE_SIZE)^2 / 8 ~ 7.6e-5`` in log space — far below
        sampling noise.  Workloads that finish a trial per ask bump the
        observation version each time, never reach two hits, and keep direct
        scoring."""
        if not self._magic_clip or not np.isfinite([low, high]).all() or high <= low:
            return
        work = kops.SCORE_TABLE_SIZE * (len(l_est.mus) + len(g_est.mus))
        if self._engine_for(work) == "numpy":
            return
        hits_key = (param_name, "score_hits")
        hits = cache.get(hits_key, 0) + 1
        cache[hits_key] = hits
        if hits < 2:
            return
        xs = np.linspace(low, high, kops.SCORE_TABLE_SIZE)
        ys = np.asarray(self._score(l_est, g_est, xs))
        cache[(param_name, "table")] = (xs, ys)

    def _sample_categorical(
        self,
        dist: CategoricalDistribution,
        below: np.ndarray,
        above: np.ndarray,
        w_below: np.ndarray,
        w_above: np.ndarray,
        cache: dict,
        param_name: str,
    ) -> float:
        k = len(dist.choices)
        key = (param_name, "categorical", k)
        probs = cache.get(key)
        if probs is None:

            def weighted_probs(idxs: np.ndarray, ws: np.ndarray) -> np.ndarray:
                counts = np.full(k, self._prior_weight)
                # np.add.at accumulates in element order, matching a scalar loop
                np.add.at(counts, idxs.astype(int), ws)
                return counts / counts.sum()

            cache[key] = probs = (
                weighted_probs(below, w_below),
                weighted_probs(above, w_above),
            )
        p_l, p_g = probs
        cands = self._rng.choice(k, size=self._n_ei, p=p_l)
        score = np.log(p_l[cands] + EPS) - np.log(p_g[cands] + EPS)
        return float(cands[int(np.argmax(score))])
