"""NSGA-II multi-objective sampler (Deb et al., 2002) on the columnar engine.

Selection runs entirely on the observation store's ``(n_trials,
n_objectives)`` values matrix: one vectorized non-dominated sort + per-front
crowding distances (``core/moo.py``) rank the whole history, the best
``population_size`` rows form the elite pool, and parents come from binary
rank/crowding tournaments.  Variation happens in **model space** on the
store's parameter matrix — simulated binary crossover (SBX) + polynomial
mutation for numeric columns, uniform crossover + resample mutation for
categorical columns — so offspring feed straight back through the joint
block contract with no external-repr round trip.

The sampler implements ``sample_joint`` natively: one ``Study.ask(n)`` wave
is one generation (``joint_wave_size`` caps waves at ``population_size``),
produced by a single ranking + ``n`` vectorized tournaments/crossovers,
instead of n independent selection rounds.  The scalar path
(``sample_relative`` over the intersection space) produces one offspring per
trial through the same machinery.  Below ``population_size`` observations
the sampler declines and the uniform fallback seeds generation zero.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ...kernels import ops as kops
from .. import moo
from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial, TrialState
from ..search_space import IntersectionSearchSpace
from .base import BaseSampler, sample_uniform_internal

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["NSGAIISampler"]


class NSGAIISampler(BaseSampler):
    def __init__(
        self,
        population_size: int = 24,
        crossover_prob: float = 0.9,
        swapping_prob: float = 0.5,
        mutation_prob: "float | None" = None,
        eta_crossover: float = 20.0,
        eta_mutation: float = 20.0,
        seed: int | None = None,
        engine: str = "auto",
    ):
        """Args:
            population_size: elite pool size; also the generation (wave) size.
            crossover_prob: probability an offspring is crossed at all
                (otherwise it clones its first parent before mutation).
            swapping_prob: per-dimension probability of taking the second
                parent's SBX child / categorical gene.
            mutation_prob: per-dimension mutation probability
                (default ``1 / n_dims``).
            eta_crossover / eta_mutation: SBX / polynomial distribution
                indices (larger = offspring closer to parents).
            engine: ``"auto"`` (default) dispatches the non-dominated sort
                to the jitted device reduction once the history crosses the
                shared work threshold; ``"numpy"``/``"jax"``/``"pallas"``
                force a path (see ``kernels/ops.py``).
        """
        if population_size < 2:
            raise ValueError("population_size must be >= 2")
        if not 0.0 <= crossover_prob <= 1.0:
            raise ValueError("crossover_prob must be in [0, 1]")
        if not 0.0 <= swapping_prob <= 1.0:
            raise ValueError("swapping_prob must be in [0, 1]")
        if mutation_prob is not None and not 0.0 <= mutation_prob <= 1.0:
            raise ValueError("mutation_prob must be in [0, 1]")
        self._population_size = int(population_size)
        self._crossover_prob = float(crossover_prob)
        self._swapping_prob = float(swapping_prob)
        self._mutation_prob = mutation_prob
        self._eta_x = float(eta_crossover)
        self._eta_m = float(eta_mutation)
        self._engine = kops.validate_engine(engine)
        self._rng = np.random.RandomState(seed)
        self._space_calc = IntersectionSearchSpace()

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)

    # -- selection on the columnar engine ---------------------------------------

    def _elite(self, study: "Study", names: list[str]):
        """``(P, ranks, crowd)`` — the elite pool's model-space parameter
        rows with their nondomination ranks and crowding distances — or
        ``None`` while generation zero is still being seeded.  One store
        snapshot, one dominance reduction, one crowding pass per front."""
        store = study.observations()
        version, states, Vmat, arity, _, cols = store.snapshot_mo()
        directions = study.directions
        with np.errstate(invalid="ignore"):
            valid = (
                (states == int(TrialState.COMPLETE))
                & (arity == len(directions))
                & np.isfinite(Vmat).all(axis=1)
            )
        n_rows = len(states)
        M = (
            np.stack([cols.get(n, np.full(n_rows, np.nan)) for n in names], axis=1)
            if names and n_rows else np.empty((n_rows, len(names)))
        )
        rows = valid & ~np.isnan(M).any(axis=1)
        idx = np.flatnonzero(rows)
        if len(idx) < self._population_size:
            return None
        L = moo.loss_matrix(Vmat[idx], directions)
        ranks = moo.nondomination_ranks(L, engine=self._engine)
        crowd = np.empty(len(idx))
        for r in np.unique(ranks):
            members = ranks == r
            crowd[members] = moo.crowding_distance(L[members])
        # elite = best population_size rows by (rank asc, crowding desc)
        order = np.lexsort((-crowd, ranks))[: self._population_size]
        return M[idx][order], ranks[order], crowd[order]

    def _tournament(self, ranks: np.ndarray, crowd: np.ndarray, n: int) -> np.ndarray:
        """``n`` binary-tournament winners (indices into the elite pool):
        lower rank wins, crowding distance breaks ties — all vectorized."""
        pool = len(ranks)
        a = self._rng.randint(pool, size=n)
        b = self._rng.randint(pool, size=n)
        a_wins = (ranks[a] < ranks[b]) | (
            (ranks[a] == ranks[b]) & (crowd[a] >= crowd[b])
        )
        return np.where(a_wins, a, b)

    # -- variation in model space ------------------------------------------------

    def _offspring(
        self, P: np.ndarray, ranks: np.ndarray, crowd: np.ndarray,
        dists: "list[BaseDistribution]", n: int,
    ) -> np.ndarray:
        """``n`` offspring rows from the elite pool: vectorized tournament
        selection, SBX + polynomial mutation on numeric columns, uniform
        crossover + resample mutation on categorical columns."""
        d = P.shape[1]
        rng = self._rng
        p1 = P[self._tournament(ranks, crowd, n)]
        p2 = P[self._tournament(ranks, crowd, n)]
        cat = np.asarray([isinstance(ds, CategoricalDistribution) for ds in dists])
        lows = np.empty(d)
        highs = np.empty(d)
        for j, ds in enumerate(dists):
            if cat[j]:
                lows[j], highs[j] = 0.0, float(len(ds.choices) - 1)  # type: ignore[attr-defined]
            else:
                lows[j], highs[j] = ds.internal_bounds(expand_int=True)
        span = np.where(highs > lows, highs - lows, 1.0)

        child = p1.copy()
        crossed = rng.uniform(size=n) < self._crossover_prob
        swap = rng.uniform(size=(n, d)) < self._swapping_prob

        # SBX on numeric columns: both children computed per pair, the swap
        # mask picks one per dimension
        u = rng.uniform(size=(n, d))
        beta = np.where(
            u <= 0.5,
            (2.0 * u) ** (1.0 / (self._eta_x + 1.0)),
            (1.0 / np.maximum(2.0 * (1.0 - u), 1e-12)) ** (1.0 / (self._eta_x + 1.0)),
        )
        c1 = 0.5 * ((1.0 + beta) * p1 + (1.0 - beta) * p2)
        c2 = 0.5 * ((1.0 - beta) * p1 + (1.0 + beta) * p2)
        sbx = np.where(swap, c2, c1)
        num = ~cat
        mix = crossed[:, None] & num[None, :]
        child[mix] = sbx[mix]
        # categorical columns: uniform crossover (take p2's gene where swapped)
        mixc = crossed[:, None] & cat[None, :] & swap
        child[mixc] = p2[mixc]

        # polynomial mutation (numeric) / resample mutation (categorical)
        p_mut = self._mutation_prob if self._mutation_prob is not None else 1.0 / max(d, 1)
        mut = rng.uniform(size=(n, d)) < p_mut
        um = rng.uniform(size=(n, d))
        delta = np.where(
            um < 0.5,
            (2.0 * um) ** (1.0 / (self._eta_m + 1.0)) - 1.0,
            1.0 - (2.0 * (1.0 - um)) ** (1.0 / (self._eta_m + 1.0)),
        )
        mutated = child + delta * span[None, :]
        mn = mut & num[None, :]
        child[mn] = mutated[mn]
        resample = lows[None, :] + rng.uniform(size=(n, d)) * (highs - lows + 1.0)[None, :]
        mc = mut & cat[None, :]
        child[mc] = np.floor(np.minimum(resample, highs[None, :] + 0.999))[mc]
        np.clip(child, lows[None, :], highs[None, :], out=child)
        return child

    # -- block (joint) contract ---------------------------------------------------

    def joint_enabled(self) -> bool:
        return True

    def joint_wave_size(self, study: "Study", requested: int) -> int:
        """One wave = one generation: never hand out more than
        ``population_size`` offspring from a single ranking."""
        return min(requested, self._population_size)

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        names = list(group.names)
        elite = self._elite(study, names)
        if elite is None:
            return None
        P, ranks, crowd = elite
        dists = [group.dists[name] for name in names]
        return self._offspring(P, ranks, crowd, dists, n)

    # -- scalar path ---------------------------------------------------------------

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        return {
            n: d for n, d in self._space_calc.calculate(study).items() if not d.single()
        }

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        if not search_space:
            return {}
        names = sorted(search_space)
        elite = self._elite(study, names)
        if elite is None:
            return {}
        P, ranks, crowd = elite
        dists = [search_space[n] for n in names]
        row = self._offspring(P, ranks, crowd, dists, 1)[0]
        return {
            name: ds.to_external_repr(float(ds.from_internal(np.asarray([v]))[0]))
            for name, ds, v in zip(names, dists, row)
        }

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        # generation zero + conditional params: uniform exploration
        internal = sample_uniform_internal(self._rng, param_distribution)
        return param_distribution.to_external_repr(internal)
