from __future__ import annotations

from .base import BaseSampler
from .cmaes import CMA, CmaEsSampler
from .gp import GPSampler
from .grid import GridSampler
from .nsga2 import NSGAIISampler
from .random import RandomSampler
from .tpe import TPESampler

__all__ = [
    "BaseSampler",
    "RandomSampler",
    "GridSampler",
    "TPESampler",
    "CmaEsSampler",
    "CMA",
    "GPSampler",
    "NSGAIISampler",
    "make_sampler",
]


def make_sampler(
    name: str,
    seed: int | None = None,
    search_space: "dict | None" = None,
) -> BaseSampler:
    """Factory used by CLIs and benchmarks (``--sampler tpe+cmaes`` etc.).

    ``grid`` needs the grid declared up front (it cannot be define-by-run):
    pass ``search_space={"param": [choices, ...], ...}``.
    """
    name = name.lower()
    if name == "random":
        return RandomSampler(seed=seed)
    if name == "tpe":
        return TPESampler(seed=seed)
    if name == "cmaes":
        return CmaEsSampler(seed=seed, warmup_trials=10)
    if name in ("tpe+cmaes", "tpe_cmaes"):
        # the paper's §5.1 mixture: TPE for the first 40 trials, CMA-ES after
        return CmaEsSampler(
            warmup_trials=40, independent_sampler=TPESampler(seed=seed), seed=seed
        )
    if name == "gp":
        return GPSampler(seed=seed)
    if name == "nsga2":
        return NSGAIISampler(seed=seed)
    if name == "motpe":
        # MOTPE rides the multivariate joint path so batched waves get the
        # one-fit-per-group treatment on multi-objective studies too
        return TPESampler(seed=seed, multi_objective=True, multivariate=True)
    if name == "grid":
        if search_space is None:
            raise ValueError(
                "the grid sampler needs its cells declared up front: "
                "make_sampler('grid', search_space={'param': [values, ...]})"
            )
        return GridSampler(search_space, seed=seed)
    raise ValueError(f"unknown sampler {name!r}")
