"""CMA-ES relational sampler over the inferred concurrence relations.

Implements the full (mu/mu_w, lambda)-CMA-ES of Hansen & Ostermeier (2001)
with rank-one + rank-mu covariance updates and step-size control (CSA), on
the intersection search space (paper §3.1): after enough independently
sampled trials reveal which parameters co-occur in every trial, CMA-ES takes
over those parameters; anything conditional falls back to the independent
sampler.

Distributed-safety: instead of persisting mutable optimizer state (which
races under async workers), the CMA state is *deterministically replayed*
from the completed-trial history in generation batches of ``popsize`` — every
worker reconstructs the same state from the same storage contents, so no
coordination beyond the storage is needed.  Replay is O(n_trials · d²),
negligible next to a training trial.

``TPESampler`` + ``CmaEsSampler(warmup_trials=40)`` reproduces the paper's
§5.1 "TPE+CMA-ES" mixture: TPE explores for the first 40 trials, CMA-ES
exploits after.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import (
    BaseDistribution,
    CategoricalDistribution,
    FloatDistribution,
    IntDistribution,
    round_to_step,
)
from ..frozen import FrozenTrial, StudyDirection
from ..search_space import IntersectionSearchSpace
from .base import BaseSampler
from .random import RandomSampler

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["CmaEsSampler", "CMA"]


class CMA:
    """Minimal-state CMA-ES engine on [0,1]^d (normalized coordinates)."""

    def __init__(self, mean: np.ndarray, sigma: float, seed: int | None = None):
        d = len(mean)
        self.dim = d
        self.mean = mean.astype(float).copy()
        self.sigma = float(sigma)
        self.C = np.eye(d)
        self.pc = np.zeros(d)
        self.ps = np.zeros(d)
        self.generation = 0

        self.popsize = 4 + int(3 * math.log(d)) if d > 0 else 4
        mu = self.popsize // 2
        w = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
        self.weights = w / w.sum()
        self.mu_eff = 1.0 / np.sum(self.weights**2)

        self.c_sigma = (self.mu_eff + 2) / (d + self.mu_eff + 5)
        self.d_sigma = (
            1 + 2 * max(0.0, math.sqrt((self.mu_eff - 1) / (d + 1)) - 1) + self.c_sigma
        )
        self.c_c = (4 + self.mu_eff / d) / (d + 4 + 2 * self.mu_eff / d)
        self.c_1 = 2 / ((d + 1.3) ** 2 + self.mu_eff)
        self.c_mu = min(
            1 - self.c_1,
            2 * (self.mu_eff - 2 + 1 / self.mu_eff) / ((d + 2) ** 2 + self.mu_eff),
        )
        self.chi_n = math.sqrt(d) * (1 - 1 / (4 * d) + 1 / (21 * d * d))
        self._eig_cache: tuple[np.ndarray, np.ndarray] | None = None

    def _eig(self) -> tuple[np.ndarray, np.ndarray]:
        if self._eig_cache is None:
            self.C = 0.5 * (self.C + self.C.T)
            vals, vecs = np.linalg.eigh(self.C)
            vals = np.maximum(vals, 1e-20)
            self._eig_cache = (vals, vecs)
        return self._eig_cache

    def ask(self, rng: np.random.RandomState) -> np.ndarray:
        vals, vecs = self._eig()
        z = rng.standard_normal(self.dim)
        y = vecs @ (np.sqrt(vals) * z)
        x = self.mean + self.sigma * y
        return np.clip(x, 0.0, 1.0)

    def tell(self, solutions: list[tuple[np.ndarray, float]]) -> None:
        """Update with one full generation: [(x in [0,1]^d, loss)], len==popsize."""
        solutions = sorted(solutions, key=lambda s: s[1])
        mu = len(self.weights)
        xs = np.stack([s[0] for s in solutions[:mu]])
        y_w = (xs - self.mean[None, :]) / max(self.sigma, 1e-30)
        y_mean = self.weights @ y_w

        vals, vecs = self._eig()
        inv_sqrt = vecs @ np.diag(1.0 / np.sqrt(vals)) @ vecs.T

        self.mean = self.mean + self.sigma * y_mean
        self.ps = (1 - self.c_sigma) * self.ps + math.sqrt(
            self.c_sigma * (2 - self.c_sigma) * self.mu_eff
        ) * (inv_sqrt @ y_mean)
        ps_norm = float(np.linalg.norm(self.ps))
        h_sigma = ps_norm / math.sqrt(
            1 - (1 - self.c_sigma) ** (2 * (self.generation + 1))
        ) < (1.4 + 2 / (self.dim + 1)) * self.chi_n
        self.pc = (1 - self.c_c) * self.pc + (
            math.sqrt(self.c_c * (2 - self.c_c) * self.mu_eff) * y_mean if h_sigma else 0.0
        )
        delta_h = (1 - h_sigma) * self.c_c * (2 - self.c_c)
        rank_one = np.outer(self.pc, self.pc)
        rank_mu = (y_w * self.weights[:, None]).T @ y_w
        self.C = (
            (1 + self.c_1 * delta_h - self.c_1 - self.c_mu) * self.C
            + self.c_1 * rank_one
            + self.c_mu * rank_mu
        )
        self.sigma = self.sigma * math.exp(
            (self.c_sigma / self.d_sigma) * (ps_norm / self.chi_n - 1)
        )
        self.sigma = float(np.clip(self.sigma, 1e-8, 1e3))
        self.generation += 1
        self._eig_cache = None


class CmaEsSampler(BaseSampler):
    def __init__(
        self,
        warmup_trials: int = 40,
        independent_sampler: BaseSampler | None = None,
        seed: int | None = None,
        sigma0: float = 0.25,
    ):
        """Args:
            warmup_trials: trials sampled by ``independent_sampler`` before
                CMA-ES engages (the paper used TPE for the first 40 steps).
            independent_sampler: fallback for warmup + conditional params
                (defaults to :class:`RandomSampler`).
        """
        self._warmup = warmup_trials
        self._independent = independent_sampler or RandomSampler(seed=seed)
        self._seed = seed
        self._sigma0 = sigma0
        self._space_calc = IntersectionSearchSpace()

    def reseed_rng(self, seed: int | None = None) -> None:
        self._seed = seed
        self._independent.reseed_rng(seed)

    # -- relational interface ----------------------------------------------------

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        space = self._space_calc.calculate(study)
        # CMA-ES needs >= 2 numeric dims; categoricals are excluded (handled
        # independently), single-point domains carry no information.
        out = {}
        for name, dist in space.items():
            if isinstance(dist, CategoricalDistribution) or dist.single():
                continue
            out[name] = dist
        return out if len(out) >= 2 else {}

    def _replayed_cma(
        self, study: "Study", names: list[str], search_space: dict[str, BaseDistribution]
    ) -> "tuple[CMA, int] | None":
        """Deterministically replay the completed-trial history into a CMA
        state (see the module docstring), or None while still in warmup.
        Returns ``(cma, n_observations)``; the observation count keys the
        joint path's per-wave RNG."""
        # the design matrix comes straight from the columnar observation
        # store (model space, trial-number order) — no FrozenTrial re-walk
        Xi, y0 = study.observations().design_matrix(names)
        if len(Xi) < self._warmup:
            return None

        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        U = np.empty_like(Xi)
        for j, n in enumerate(names):
            U[:, j] = search_space[n].internal_to_unit(Xi[:, j])
        losses = sign * y0

        # feed completed post-warmup trials to CMA in generation batches of
        # popsize, in trial-number order
        cma = CMA(
            mean=np.full(len(names), 0.5),
            sigma=self._sigma0,
            seed=self._seed,
        )
        start = self._warmup - 1 if self._warmup > 0 else 0
        batch: list[tuple[np.ndarray, float]] = []
        for i in range(start, len(U)):
            batch.append((U[i], float(losses[i])))
            if len(batch) == cma.popsize:
                cma.tell(batch)
                batch = []
        return cma, len(U)

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        if not search_space:
            return {}
        names = sorted(search_space.keys())
        replayed = self._replayed_cma(study, names, search_space)
        if replayed is None:
            return {}
        cma, _ = replayed
        rng = np.random.RandomState(
            None if self._seed is None else (self._seed + 7919 * trial.number)
        )
        x = cma.ask(rng)
        return {n: _from_unit(search_space[n], float(v)) for n, v in zip(names, x)}

    def _cma_space(self, study: "Study") -> dict[str, BaseDistribution]:
        return {
            name: dist
            for name, dist in self._space_calc.calculate(study).items()
            if not isinstance(dist, CategoricalDistribution) and not dist.single()
        }

    def joint_wave_size(self, study: "Study", requested: int) -> int:
        """Cap batched waves at the CMA population size so each ``ask(n)``
        block is one generation: a wave larger than popsize would draw its
        surplus rows from the same replayed state, even though the first
        popsize results will move the mean/covariance before those rows
        could have been sampled in sequential CMA-ES (ROADMAP PR-4
        follow-up).  The popsize formula needs only the space dimension, so
        no history replay happens here."""
        d = len(self._cma_space(study))
        if d < 2:
            return requested  # CMA not engaged: no generation structure
        popsize = 4 + int(3 * math.log(d))
        return min(requested, popsize)

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """One history replay per wave (instead of per trial), then ``n``
        population draws.  Columns outside the CMA space — categoricals,
        single-point domains, conditional params — stay NaN and fall back to
        per-trial independent sampling, mirroring the scalar path."""
        space = self._cma_space(study)
        if len(space) < 2 or not set(space) <= set(group.names):
            return None
        names = sorted(space.keys())
        replayed = self._replayed_cma(study, names, space)
        if replayed is None:
            return None
        cma, n_obs = replayed
        # wave-deterministic stream keyed on the first pending trial's number
        # (the same 7919 multiplier the scalar path applies per trial):
        # concurrent workers claim disjoint numbers, so identical histories
        # no longer collapse into identical blocks.  History length remains
        # the fallback for callers that invoke the block contract directly.
        key = first_number if first_number is not None else n_obs
        rng = np.random.RandomState(
            None if self._seed is None else (self._seed + 7919 * key)
        )
        cols = {name: j for j, name in enumerate(group.names)}
        block = np.full((n, len(group.names)), np.nan)
        for i in range(n):
            x = cma.ask(rng)
            for name, u in zip(names, x):
                dist = space[name]
                ext = _from_unit(dist, float(u))
                block[i, cols[name]] = float(dist.to_internal([ext])[0])
        return block

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        return self._independent.sample_independent(
            study, trial, param_name, param_distribution
        )


def _to_unit(dist: BaseDistribution, external: Any) -> float:
    """Scalar external -> [0,1].  The batched path goes through the
    observation store + ``BaseDistribution.internal_to_unit`` instead."""
    v = dist.to_internal_repr(external)
    if isinstance(dist, (FloatDistribution, IntDistribution)):
        lo, hi = float(dist.low), float(dist.high)
        if dist.log:
            lo, hi = math.log(lo), math.log(hi)
            v = math.log(max(v, 1e-300))
        return (v - lo) / (hi - lo) if hi > lo else 0.5
    return v


def _from_unit(dist: BaseDistribution, u: float) -> Any:
    u = float(np.clip(u, 0.0, 1.0))
    lo, hi = float(dist.low), float(dist.high)
    if dist.log:
        lo_, hi_ = math.log(lo), math.log(hi)
        v = math.exp(lo_ + u * (hi_ - lo_))
    else:
        v = lo + u * (hi - lo)
    if isinstance(dist, IntDistribution):
        return int(np.clip(round_to_step(v, dist.low, dist.high, dist.step), dist.low, dist.high))
    if isinstance(dist, FloatDistribution) and dist.step is not None:
        return float(np.clip(round_to_step(v, dist.low, dist.high, dist.step), dist.low, dist.high))
    return float(np.clip(v, lo, hi))
