"""Gaussian-process BO sampler (the paper's GPyOpt adversary, §5.1).

A compact GP-EI implementation: Matérn-5/2 kernel on [0,1]^d normalized
coordinates, cholesky posterior, expected-improvement acquisition optimized
by random multistart + coordinate refinement.  Sample-efficient but an order
of magnitude slower per suggest than TPE — exactly the trade-off the paper
measures (Fig. 10).
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Any

import numpy as np

from ..distributions import BaseDistribution, CategoricalDistribution
from ..frozen import FrozenTrial, StudyDirection
from ..search_space import IntersectionSearchSpace
from .base import BaseSampler
from .cmaes import _from_unit
from .random import RandomSampler

if TYPE_CHECKING:
    from ..search_space import ParamGroup
    from ..study import Study

__all__ = ["GPSampler"]


def _matern52(X: np.ndarray, Y: np.ndarray, ls: float) -> np.ndarray:
    d = np.sqrt(np.maximum(((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1), 1e-30)) / ls
    s5 = math.sqrt(5.0)
    return (1 + s5 * d + 5.0 / 3.0 * d * d) * np.exp(-s5 * d)


class GPSampler(BaseSampler):
    def __init__(
        self,
        n_startup_trials: int = 10,
        n_candidates: int = 512,
        seed: int | None = None,
        noise: float = 1e-6,
    ):
        self._n_startup = n_startup_trials
        self._n_candidates = n_candidates
        self._rng = np.random.RandomState(seed)
        self._noise = noise
        self._fallback = RandomSampler(seed=seed)
        self._space_calc = IntersectionSearchSpace()

    def reseed_rng(self, seed: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)
        self._fallback.reseed_rng(seed)

    def infer_relative_search_space(
        self, study: "Study", trial: FrozenTrial
    ) -> dict[str, BaseDistribution]:
        space = self._space_calc.calculate(study)
        return {
            n: d
            for n, d in space.items()
            if not isinstance(d, CategoricalDistribution) and not d.single()
        }

    def _ei_candidates(
        self, study: "Study", names: list[str], search_space: dict[str, BaseDistribution]
    ) -> "tuple[np.ndarray, np.ndarray] | None":
        """Fit the GP once and return ``(candidates, ei)`` over the random
        candidate set in [0,1]^d, or None while still in startup."""
        sign = 1.0 if study.direction == StudyDirection.MINIMIZE else -1.0
        # design matrix straight from the columnar observation store: model
        # space -> [0,1] via the vectorized per-distribution codec
        Xi, y0 = study.observations().design_matrix(names)
        if len(Xi) < self._n_startup:
            return None
        X = np.empty_like(Xi)
        for j, n in enumerate(names):
            X[:, j] = search_space[n].internal_to_unit(Xi[:, j])
        y = sign * y0
        # standardize targets
        mu, std = y.mean(), max(y.std(), 1e-12)
        yz = (y - mu) / std

        # lightweight lengthscale selection by marginal likelihood over a grid
        best_ls, best_ml = 0.5, -np.inf
        for ls in (0.1, 0.2, 0.5, 1.0):
            K = _matern52(X, X, ls) + self._noise * np.eye(len(X))
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yz))
            ml = -0.5 * yz @ alpha - np.log(np.diag(L)).sum()
            if ml > best_ml:
                best_ml, best_ls = ml, ls
        ls = best_ls
        K = _matern52(X, X, ls) + self._noise * np.eye(len(X))
        L = np.linalg.cholesky(K + 1e-10 * np.eye(len(X)))
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, yz))

        # EI over random candidates
        C = self._rng.uniform(size=(self._n_candidates, len(names)))
        Ks = _matern52(C, X, ls)
        mean = Ks @ alpha
        v = np.linalg.solve(L, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(0), 1e-12)
        sd = np.sqrt(var)
        best = yz.min()
        z = (best - mean) / sd
        ei = sd * (z * _ncdf(z) + _npdf(z))
        return C, ei

    def sample_relative(
        self, study: "Study", trial: FrozenTrial, search_space: dict[str, BaseDistribution]
    ) -> dict[str, Any]:
        if not search_space:
            return {}
        names = sorted(search_space)
        fitted = self._ei_candidates(study, names, search_space)
        if fitted is None:
            return {}
        C, ei = fitted
        x = C[int(np.argmax(ei))]
        return {n: _from_unit(search_space[n], float(u)) for n, u in zip(names, x)}

    def sample_joint(
        self, study: "Study", group: "ParamGroup", n: int,
        trial_ids: "list[int] | None" = None,
        first_number: "int | None" = None,
    ) -> "np.ndarray | None":
        """One GP fit per wave; the ``n`` pending trials take the top-n EI
        candidates (distinct acquisition optima) instead of re-fitting the
        posterior per trial.  Columns outside the GP space stay NaN."""
        space = {
            name: dist
            for name, dist in self._space_calc.calculate(study).items()
            if not isinstance(dist, CategoricalDistribution) and not dist.single()
        }
        if not space or not set(space) <= set(group.names):
            return None
        names = sorted(space)
        fitted = self._ei_candidates(study, names, space)
        if fitted is None:
            return None
        C, ei = fitted
        top = np.argsort(ei, kind="stable")[::-1][:n]
        cols = {name: j for j, name in enumerate(group.names)}
        block = np.full((n, len(group.names)), np.nan)
        for i, c in enumerate(top):
            for name, u in zip(names, C[c]):
                dist = space[name]
                ext = _from_unit(dist, float(u))
                block[i, cols[name]] = float(dist.to_internal([ext])[0])
        # fewer candidates than pending trials: recycle the best row
        for i in range(len(top), n):
            block[i] = block[i % max(len(top), 1)]
        return block

    def sample_independent(
        self, study: "Study", trial: FrozenTrial, param_name: str,
        param_distribution: BaseDistribution,
    ) -> Any:
        return self._fallback.sample_independent(study, trial, param_name, param_distribution)


def _ncdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1 + np.vectorize(math.erf)(x / math.sqrt(2)))


def _npdf(x: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * x * x) / math.sqrt(2 * math.pi)
